"""Int8 gradient compression with error feedback for the pod axis.

Cross-pod (DCN-class) all-reduces are the slowest collective in a multi-pod
mesh.  This implements the standard 1-bit-Adam-family trick at int8: scale
per-tensor, quantize, all-reduce the int8 payload (4x fewer DCN bytes than
fp32, 2x fewer than bf16), dequantize, and carry the quantization residual
into the next step (error feedback keeps convergence unbiased).

Used by train/loop.py when the mesh has a "pod" axis and the config enables
``compress_pod_grads`` — a distributed-optimization feature for the 1000+
node posture (docs/ARCHITECTURE.md#design-6).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Tree, residual: Tree | None):
    """Quantize grads (+carry residual).  Returns (q_tree, scales, new_resid)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    gq, scales, resid = [], [], []
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    for g, r in zip(flat_g, flat_r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        gq.append(q)
        scales.append(s)
        resid.append(x - dequantize_int8(q, s))
    return (jax.tree.unflatten(treedef, gq),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, resid))


def psum_compressed(grads: Tree, residual: Tree | None, axis: str):
    """Error-feedback int8 psum over ``axis`` (inside shard_map)."""
    q, s, resid = compress_tree(grads, residual)
    # int8 payloads all-reduce in int32 to avoid overflow across pods.
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis), q)
    # scales are per-tensor; max-combine keeps dequantization conservative.
    s_max = jax.tree.map(lambda ss: jax.lax.pmax(ss, axis), s)
    n = jax.lax.psum(1, axis)
    deq = jax.tree.map(lambda qq, ss: (qq.astype(jnp.float32) * ss) / n,
                       summed, s_max)
    return deq, resid
