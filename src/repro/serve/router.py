"""Multi-tenant SpMV serving router with persistent warm-start artifacts.

One :class:`SparseMatrixEngine` hosts *many* ingested matrices behind a
single ``spmv(name, x)`` entry point.  Three fleet-scale behaviours live
here (the single-matrix mechanics — autotune, lowering, rebalance — are
unchanged from the drift-aware engine this router refactors):

* **Warm-start ingest** (``artifact_dir=``): every cold ingest persists
  its lowered :class:`~repro.core.program.SpmvProgram` as a versioned
  bundle (:mod:`repro.core.artifacts`); a later ingest of the same bytes
  — typically a process restart — digest-hits the bundle and skips the
  autotune grid, the Emu probe *and* the re-lower, loading device-ready
  slabs whose ``execute()`` outputs are bitwise identical to a fresh
  lower.  Any mismatch (schema bump, changed values) silently falls back
  to the cold path.
* **Per-tenant rebalance state**: each tenant gets its own
  :class:`~repro.serve.rebalance.RebalanceConfig` (``ingest(...,
  rebalance=)`` overrides the engine default) and
  :class:`~repro.serve.rebalance.LoadMonitor`, so a bursty tenant's
  re-plans never reset a stable tenant's baselines.  A rebalance swap
  atomically invalidates and rewrites the tenant's artifact (manifest
  removed first, rewritten last), so disk never disagrees with the live
  program: a restart warm-loads the *post-drift* plan.
* **Cross-request micro-batching** (``micro_batch=``): concurrent
  single-vector requests for the same tenant are gathered — leader /
  follower, bounded by ``max_batch``/``max_wait_ms`` — into one
  multi-RHS ``(N, B)`` execute, whose columns are bitwise-equal to
  per-vector calls (the batched-numpy invariant the tests pin), then
  scattered back to each waiter.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.artifacts import ArtifactError, load_program, save_program
from repro.core.plan import PlanCache, PlanChoice, autotune, feature_key
from repro.core.program import SpmvProgram, execute, lower
from repro.core.sparse_matrix import CSRMatrix
from repro.core.spmv import SpmvPlan
from repro.serve.rebalance import LoadMonitor, RebalanceConfig, \
    RebalanceEvent, replan

__all__ = ["SparseMatrixEngine", "IngestedMatrix", "MicroBatchConfig"]


@dataclasses.dataclass(frozen=True)
class MicroBatchConfig:
    """Cross-request micro-batching knobs.

    The first request to arrive for an idle tenant becomes the *leader*:
    it waits up to ``max_wait_ms`` (polling every ``poll_ms``) for up to
    ``max_batch - 1`` followers, runs one batched ``(N, B)`` execute, and
    hands each follower its column.  ``max_wait_ms=0`` still batches
    whatever is already queued — pure piggybacking with no added latency.
    """

    max_batch: int = 8
    max_wait_ms: float = 2.0
    poll_ms: float = 0.1


class _MicroBatcher:
    """Leader/follower gatherer for one tenant (thread-safe)."""

    def __init__(self, cfg: MicroBatchConfig, compute):
        self.cfg = cfg
        self._compute = compute          # (N, B) ndarray, n_requests -> (M, B)
        self._lock = threading.Lock()
        self._pending: list = []         # (x, slot, event)
        self._leading = False
        self.batches = 0
        self.requests = 0
        self.widest = 0

    def submit(self, x: np.ndarray, timeout: float = 60.0) -> np.ndarray:
        evt = threading.Event()
        slot: dict = {}
        with self._lock:
            self._pending.append((x, slot, evt))
            self.requests += 1
            lead = not self._leading
            if lead:
                self._leading = True
        if not lead:
            if not evt.wait(timeout):
                raise RuntimeError("micro-batch leader never delivered "
                                   f"within {timeout}s")
            if "err" in slot:
                raise slot["err"]
            return slot["y"]
        # Leader: linger for followers, then drain in max_batch waves until
        # the queue is empty (arrivals during compute join the next wave
        # rather than electing a second leader).
        deadline = time.monotonic() + self.cfg.max_wait_ms / 1e3
        while time.monotonic() < deadline:
            with self._lock:
                if len(self._pending) >= self.cfg.max_batch:
                    break
            time.sleep(self.cfg.poll_ms / 1e3)
        while True:
            with self._lock:
                batch = self._pending[: self.cfg.max_batch]
                del self._pending[: self.cfg.max_batch]
                if not batch:
                    self._leading = False
                    break
            try:
                X = np.stack([b[0] for b in batch], axis=1)
                Y = self._compute(X, len(batch))
            except BaseException as err:
                # Fail every waiter (drained and still-queued) rather than
                # leaving followers blocked on a dead leader.
                with self._lock:
                    batch += self._pending
                    self._pending.clear()
                    self._leading = False
                for _, s, e in batch:
                    s["err"] = err
                    e.set()
                raise
            self.batches += 1
            self.widest = max(self.widest, len(batch))
            for i, (_, s, e) in enumerate(batch):
                s["y"] = Y[:, i]
                e.set()
        return slot["y"]

    def stats(self) -> dict:
        return {"requests": self.requests, "batches": self.batches,
                "widest": self.widest}


@dataclasses.dataclass
class IngestedMatrix:
    """One served tenant: its autotuned choice + device-ready program.

    ``csr`` keeps the original (caller-order) matrix so the rebalancer
    can re-derive plans (and the artifact rewrite can re-digest) against
    it; ``monitor``/``rebalance_log`` exist only for tenants with
    rebalancing enabled.  ``plan_cache_hit`` records that ingest skipped
    the autotune grid via the feature-keyed plan cache; ``warm_start``
    that it skipped autotune *and* lowering via an artifact digest hit.
    """

    name: str
    choice: PlanChoice
    dist: SpmvProgram
    # Original caller-order matrix, kept only when rebalancing is enabled
    # (the re-planner re-derives plans from it); None otherwise so a
    # plain serving engine doesn't pin a second copy of every matrix.
    csr: CSRMatrix | None = None
    spmv_count: int = 0
    plan_cache_hit: bool = False
    warm_start: bool = False
    bundle_dir: str | None = None
    rebalance_cfg: RebalanceConfig | None = None
    monitor: LoadMonitor | None = None
    rebalance_log: List[RebalanceEvent] = dataclasses.field(
        default_factory=list)
    replan_thread: threading.Thread | None = None
    replan_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)
    batcher: _MicroBatcher | None = None


class SparseMatrixEngine:
    """Multi-tenant serving router for SpMV: ingest once, serve many.

    ``ingest`` runs the cost-model autotuner (with Emu-simulator probe
    re-ranking by default; pass ``probe=0`` to opt out, or
    ``probe="auto"`` to spend probes adaptively until the
    measured-vs-analytic inversion rate stabilizes) and lowers the
    winning plan — unless a warm path answers first, in cheapness order:

    1. **artifact store** (``artifact_dir=``): same-bytes digest hit
       loads the previously lowered program — no autotune, no lower;
    2. **plan cache** (on by default; ``plan_cache_dir=`` makes it
       disk-backed and shared across engine instances): a structurally
       similar matrix (equal :func:`~repro.core.plan.feature_key`)
       reuses the previously autotuned plan — no autotune, fresh lower.

    ``spmv`` answers y = A @ x requests — ``x`` a single (N,) vector or
    a multi-RHS block (N, B) — in the caller's original index order;
    with ``micro_batch=`` enabled, concurrent single-vector requests for
    one tenant share a batched execute.  ``plans()`` exposes every
    decision as JSON so an operator can audit *why* a tenant got its
    layout/kernel; ``stats()`` adds per-tenant serving counters.

    Per-tenant rebalancing (``rebalance=`` engine-wide default,
    overridable per ingest) watches each tenant's request mix and swaps
    validated re-plans in double-buffered (``serve/rebalance.py``); a
    swap rewrites the tenant's artifact so restarts resume the new plan.
    """

    def __init__(self, *, num_shards: int = 8,
                 probe: int | str | None = None,
                 seed: int = 0,
                 rebalance: RebalanceConfig | bool | None = None,
                 plan_cache: bool = True,
                 plan_cache_dir: str | None = None,
                 artifact_dir: str | None = None,
                 micro_batch: MicroBatchConfig | bool | None = None):
        self.num_shards = num_shards
        self.probe = probe
        self.seed = seed
        if rebalance is True:
            rebalance = RebalanceConfig()
        self.rebalance_cfg: RebalanceConfig | None = rebalance or None
        if micro_batch is True:
            micro_batch = MicroBatchConfig()
        self.micro_batch: MicroBatchConfig | None = micro_batch or None
        self._matrices: Dict[str, IngestedMatrix] = {}
        self._plan_cache: PlanCache | None = \
            PlanCache(plan_cache_dir) if (plan_cache or plan_cache_dir) \
            else None
        self.artifact_dir = artifact_dir
        self.plan_cache_hits = 0
        self.warm_starts = 0
        self.artifact_write_errors = 0
        #: Engine-wide served-request count — the denominator of each
        #: tenant's traffic share, which scales the amortization horizon
        #: the re-plan gate sees (``RebalanceConfig.amortization_lookahead``).
        self.total_requests = 0

    # -- ingest ------------------------------------------------------------

    def _bundle_dir(self, name: str) -> str:
        safe = re.sub(r"[^A-Za-z0-9._-]", "_", name)
        if safe != name:
            # collision-proof distinct raw names that sanitize identically
            safe += "-" + hashlib.sha256(name.encode()).hexdigest()[:8]
        return os.path.join(self.artifact_dir, safe)

    def _warm_ingest(self, name: str, csr: CSRMatrix):
        """Artifact-path ingest: (program, choice, bundle_dir) or None."""
        if self.artifact_dir is None:
            return None
        bundle = self._bundle_dir(name)
        try:
            prog, choice = load_program(bundle, expect=csr)
        except ArtifactError:
            return None
        if prog.plan.num_shards != self.num_shards:
            return None                # deployment reshaped: re-lower cold
        if choice is None:
            from repro.core.oracle import DEFAULT_ORACLE as oracle
            from repro.core.plan import RankedPlan, extract_features
            features = extract_features(csr, num_shards=self.num_shards)
            choice = PlanChoice(
                features=features,
                ranking=(RankedPlan(plan=prog.plan,
                                    cost=oracle.plan_cost(csr, prog.plan)),),
                probed=0, bottleneck=oracle.classify(features))
        return prog, choice, bundle

    def ingest(self, name: str, csr: CSRMatrix,
               plan: SpmvPlan | None = None, *,
               rebalance: RebalanceConfig | bool | None = None
               ) -> PlanChoice:
        """Register ``csr`` under ``name`` with a load-time-tuned plan.

        Pass an explicit ``plan`` to bypass the autotuner (the choice is
        then recorded as a single-candidate ranking with its model cost).
        The engine's shard count is authoritative: an explicit plan is
        re-targeted to ``self.num_shards`` so the built program, its cost,
        and the recorded features all describe the same deployment.
        Re-ingesting a name replaces the previous tenant.

        ``rebalance`` overrides the engine-wide default for this tenant:
        a :class:`RebalanceConfig` (or ``True`` for defaults) enables it,
        ``False`` disables it, ``None`` inherits the engine default.

        With ``artifact_dir`` set, a digest-identical re-ingest warm
        starts from the saved bundle (no autotune, no lower) and a cold
        ingest persists its program for the next restart.
        """
        from repro.core.oracle import DEFAULT_ORACLE as oracle
        from repro.core.plan import RankedPlan, extract_features
        if rebalance is None:
            rebalance = self.rebalance_cfg
        elif rebalance is True:
            rebalance = RebalanceConfig()
        elif rebalance is False:
            rebalance = None

        warm = None if plan is not None else self._warm_ingest(name, csr)
        cache_hit = False
        bundle = None
        if warm is not None:
            dist, choice, bundle = warm
            self.warm_starts += 1
        else:
            features = extract_features(csr, num_shards=self.num_shards)
            cache_key = (feature_key(features), self.num_shards)
            if plan is None and self._plan_cache is not None:
                cached = self._plan_cache.get(cache_key)
                if cached is not None:
                    plan = cached
                    cache_hit = True
                    self.plan_cache_hits += 1
            if plan is None:
                choice = autotune(csr, num_shards=self.num_shards,
                                  seed=self.seed, probe=self.probe)
                if self._plan_cache is not None:
                    self._plan_cache.put(cache_key, choice.plan)
            else:
                # retarget (not replace): a per-shard kernel tuple tuned
                # for a different shard count is dropped rather than kept
                # unlowerable.
                plan = plan.retarget(self.num_shards)
                choice = PlanChoice(
                    features=features,
                    ranking=(RankedPlan(plan=plan,
                                        cost=oracle.plan_cost(csr, plan)),),
                    probed=0, bottleneck=oracle.classify(features))
            dist = lower(csr, choice.plan)
            if self.artifact_dir is not None:
                bundle = self._bundle_dir(name)
                try:
                    save_program(dist, bundle, source=csr, choice=choice)
                except OSError:
                    self.artifact_write_errors += 1
                    bundle = None
        monitor = LoadMonitor(dist, rebalance) \
            if rebalance is not None else None
        m = IngestedMatrix(
            name=name, choice=choice, dist=dist,
            csr=csr if monitor is not None else None,
            plan_cache_hit=cache_hit, warm_start=warm is not None,
            bundle_dir=bundle, rebalance_cfg=rebalance, monitor=monitor)
        if self.micro_batch is not None:
            m.batcher = _MicroBatcher(
                self.micro_batch,
                lambda X, n, _m=m: self._serve_block(_m, X, n))
        self._matrices[name] = m
        return choice

    # -- serving -----------------------------------------------------------

    def _lookup(self, name: str) -> IngestedMatrix:
        m = self._matrices.get(name)
        if m is None:
            raise KeyError(
                f"no matrix ingested under {name!r}; ingested names: "
                f"{sorted(self._matrices) or '(none)'} — call "
                f"engine.ingest({name!r}, csr) first")
        return m

    def _serve_block(self, m: IngestedMatrix, x: np.ndarray,
                     n_requests: int = 1) -> np.ndarray:
        y = execute(m.dist, x)
        m.spmv_count += n_requests
        self.total_requests += n_requests
        if m.monitor is not None and m.monitor.observe(x):
            self._try_rebalance(m)
        return y

    def spmv(self, name: str, x: np.ndarray) -> np.ndarray:
        """y = A @ x for the ingested tenant ``name`` (original order).

        ``x``: (N,) or multi-RHS (N, B) → (M,) or (M, B); batched columns
        are bitwise-equal to per-vector calls — which is also why
        micro-batched single-vector requests (``micro_batch=``) return
        exactly what a solo call would.  Unknown names raise an
        actionable :class:`KeyError` *before* any stats are touched, so
        ``stats()`` counts successful calls only.
        """
        m = self._lookup(name)
        if m.batcher is not None and np.ndim(x) == 1:
            return m.batcher.submit(np.asarray(x))
        return self._serve_block(m, x)

    # -- rebalancing -------------------------------------------------------

    def _try_rebalance(self, m: IngestedMatrix) -> None:
        """Detector tripped: budgeted re-plan, validated double-buffered swap.

        Callers keep reading ``m.dist`` (the old program) until the
        candidate is built and validated; the swap itself is one attribute
        rebind (atomic under the GIL).  Rejected candidates only start the
        monitor's cooldown — serving never degrades on a failed re-plan.

        With ``async_replan`` the whole re-plan runs on a daemon worker
        thread and this method returns immediately — requests served in
        the meantime use the old program, and at most one worker per
        tenant is in flight.  The default is inline (deterministic, but
        the triggering request absorbs the re-plan latency).
        """
        if m.rebalance_cfg.async_replan:
            # check-then-spawn under the per-tenant lock: two request
            # threads closing hot windows near-simultaneously must not
            # both launch workers.
            with m.replan_lock:
                if m.replan_thread is not None and m.replan_thread.is_alive():
                    return             # a re-plan is already in flight
                m.replan_thread = threading.Thread(
                    target=self._replan_and_swap, args=(m,), daemon=True)
                m.replan_thread.start()
        else:
            self._replan_and_swap(m)

    def _amortization_horizon(self, m: IngestedMatrix) -> float | None:
        """Projected SpMVs tenant ``m`` will issue against a new plan.

        The Asudeh gate's volume estimate: the tenant's observed share of
        engine traffic, projected over the next
        ``cfg.amortization_lookahead`` engine requests.  A tenant taking
        2% of a 1000-request lookahead projects 20 SpMVs — not enough to
        amortize a full re-plan — while a tenant taking 60% projects 600.
        ``None`` (lookahead unset) keeps the legacy volume-blind gate.
        """
        lookahead = m.rebalance_cfg.amortization_lookahead
        if lookahead is None:
            return None
        share = m.spmv_count / max(self.total_requests, 1)
        return float(lookahead) * share

    def _replan_and_swap(self, m: IngestedMatrix) -> None:
        new_dist, new_choice, event = replan(
            m.csr, m.monitor, m.choice, num_shards=self.num_shards,
            seed=self.seed, cfg=m.rebalance_cfg,
            request_index=m.spmv_count, program=m.dist,
            amortization_horizon=self._amortization_horizon(m))
        m.rebalance_log.append(event)
        if new_dist is not None:
            m.dist = new_dist          # the double-buffer swing
            m.choice = new_choice
            m.monitor.attach(new_dist)
            self._persist(m)
        m.monitor.cooldown()

    def _persist(self, m: IngestedMatrix) -> None:
        """Invalidate + rewrite the tenant's artifact after a swap.

        ``save_program`` removes the old manifest before touching bytes
        and writes the new one last, so at every instant the bundle reads
        either as the *new* program or as "no artifact" — never as the
        stale pre-swap plan.
        """
        if m.bundle_dir is None or m.csr is None:
            return
        try:
            save_program(m.dist, m.bundle_dir, source=m.csr, choice=m.choice)
        except OSError:
            self.artifact_write_errors += 1

    # -- introspection -----------------------------------------------------

    def plan(self, name: str) -> SpmvPlan:
        """The plan serving ``name``."""
        return self._lookup(name).choice.plan

    def plans(self) -> Dict[str, str]:
        """name -> PlanChoice JSON for every ingested tenant."""
        return {n: m.choice.to_json() for n, m in self._matrices.items()}

    def tenants(self) -> List[str]:
        """Names of every ingested tenant (sorted)."""
        return sorted(self._matrices)

    def rebalance_log(self, name: str) -> List[RebalanceEvent]:
        """Every detector trip for ``name`` (swapped or rejected)."""
        return list(self._lookup(name).rebalance_log)

    def stats(self) -> Dict[str, dict]:
        """Lightweight per-tenant serving stats (JSON-serializable)."""
        out = {}
        for n, m in self._matrices.items():
            s = {"plan": dataclasses.asdict(m.choice.plan),
                 "bottleneck": m.choice.bottleneck,
                 "shard_kernels": list(m.dist.shard_kernels()),
                 "shard_exchanges":
                     list(m.choice.plan.resolved_shard_exchanges()),
                 "nnz": m.dist.matrix.nnz,
                 "migrations": m.dist.traffic.migrations,
                 "hotspot_share": m.dist.traffic.hotspot_share,
                 "spmv_count": m.spmv_count,
                 "plan_cache_hit": m.plan_cache_hit,
                 "warm_start": m.warm_start}
            if m.monitor is not None:
                s["rebalance"] = {
                    **m.monitor.stats(),
                    "replans": sum(e.swapped for e in m.rebalance_log),
                    "rejected": sum(not e.swapped for e in m.rebalance_log)}
            if m.batcher is not None:
                s["micro_batch"] = m.batcher.stats()
            out[n] = s
        return out
