"""Production training launcher.

On a real pod this is the per-host entry point (jax.distributed handles the
coordinator); in this container it runs the same code path on the host mesh
(or the 512-device production mesh with --dry-run for the compile proof).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b \
        --steps 100 --smoke           # reduced config, runnable on CPU
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="straggler deadline per step (0 = off)")
    args = ap.parse_args()

    import jax
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.synthetic import DataConfig, TokenStream
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.train import checkpoint as ckpt, elastic
    from repro.train.loop import RunConfig, train_loop

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh()
    run = RunConfig(fsdp=False, remat=True, donate=True,
                    grad_accum=args.grad_accum,
                    step_deadline_s=args.deadline_s)
    stream = TokenStream(cfg, DataConfig(seed=0, batch=args.batch,
                                         seq_len=args.seq))
    opt_cfg = adamw.AdamWConfig(total_steps=args.steps)

    params = opt_state = None
    start = 0
    if args.resume and ckpt.latest_step(args.ckpt) is not None:
        params, opt_state, start = elastic.resume(cfg, opt_cfg, args.ckpt,
                                                  mesh, run)
        print(f"resumed from step {start}")

    def report(step, m):
        if step % 10 == 0:
            extra = " STRAGGLER" if "straggler" in m else ""
            print(f"step {step:5d} loss={m['loss']:.4f} lr={m['lr']:.2e}"
                  f"{extra}")

    train_loop(cfg, opt_cfg, mesh, stream, args.steps, run,
               checkpoint_dir=args.ckpt, checkpoint_every=50,
               start_step=start, params=params, opt_state=opt_state,
               on_metrics=report)
    ckpt.wait_for_writes()
    print("training complete")


if __name__ == "__main__":
    main()
