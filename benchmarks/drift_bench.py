"""Drift-aware serving benchmark: does online re-planning earn its keep?

Replays a drifting request mix against two :class:`SparseMatrixEngine`
instances serving the same matrix — one frozen on its ingest-time plan
(the pre-PR-4 behaviour), one with the online rebalancer enabled — and
reports what the paper's Fig. 8 story predicts: once traffic converges on
columns owned by a single shard, only re-arranging the work restores
balance.

Workload: ``phase 1`` draws sparse request vectors with uniformly random
column support; ``phase 2`` drifts the support onto a power-law
(zipf-weighted) mix concentrated on the columns the active program placed
on one shard — the serving analogue of the paper's cop20k_A nodelet-0
convergence (§IV-D).

Reported:

* per-shard traffic-weighted load CV for the frozen and rebalanced
  engines at the end of the stream, plus the **fresh-autotune reference**
  (a from-scratch traffic-weighted autotune on the final workload) — the
  acceptance bar is rebalanced CV <= 2x fresh CV;
* Emu-modeled seconds per served SpMV under the drifted traffic for the
  frozen plan vs the swapped-in plan (the vectorized tick engine on the
  traffic-thinned matrix — the same drift oracle the rebalancer gates
  swaps with), and the modeled throughput uplift;
* host wall-clock serving throughput (requests/s) for both engines over
  the steady-state tail, for reference (the host numpy path mostly
  measures slab shapes, not migration behaviour — the modeled number is
  the paper-grounded one).

Usage::

    PYTHONPATH=src python -m benchmarks.drift_bench            # full
    PYTHONPATH=src python -m benchmarks.drift_bench --fast     # CI smoke
    PYTHONPATH=src python -m benchmarks.perf_probe --drift     # + record
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.plan import autotune
from repro.core.spmv import build_distributed
from repro.data.matrices import make_matrix
from repro.serve.engine import SparseMatrixEngine
from repro.serve.rebalance import LoadMonitor, RebalanceConfig, \
    probe_plan_seconds, weighted_shard_load


def make_request_stream(N: int, hot_cols: np.ndarray, *, k: int,
                        n_uniform: int, n_hot: int, zipf_a: float = 1.6,
                        seed: int = 0):
    """Yield (phase, x) request vectors: uniform support, then skewed.

    Hot-phase supports are zipf-ranked over ``hot_cols`` (heaviest column
    first), so the drifted mix is a power-law over a shard-concentrated
    column set — uniform → power-law skew, as the acceptance criterion
    asks.
    """
    rng = np.random.default_rng(seed)
    for _ in range(n_uniform):
        x = np.zeros(N)
        x[rng.integers(0, N, k)] = rng.standard_normal(k)
        yield "uniform", x
    for _ in range(n_hot):
        x = np.zeros(N)
        ranks = np.minimum(rng.zipf(zipf_a, k) - 1, hot_cols.size - 1)
        x[hot_cols[ranks]] = rng.standard_normal(k)
        yield "hot", x


def _weighted_cv(dist, w_caller: np.ndarray) -> float:
    load = weighted_shard_load(dist, w_caller)
    mu = load.mean()
    return float(load.std() / mu) if mu else 0.0


def run_drift_bench(*, matrix: str = "cop20k_A", scale: float = 0.005,
                    shards: int = 4, window: int = 32, k_frac: float = 0.05,
                    hot_windows: int = 10, seed: int = 0,
                    probe: int = 2) -> dict:
    """Run the scenario; returns the headline dict (printed by main)."""
    A = make_matrix(matrix, scale=scale)
    N = A.ncols
    cfg = RebalanceConfig(window=window, patience=2, cooldown=2, probe=probe,
                          seed=seed)

    frozen = SparseMatrixEngine(num_shards=shards, rebalance=None)
    live = SparseMatrixEngine(num_shards=shards, rebalance=cfg)
    frozen.ingest("A", A)
    live.ingest("A", A)
    ingest_plan = live.plan("A")

    # Observer on the frozen engine (never triggers anything — the frozen
    # engine has no monitor by construction; this just measures its CV).
    frozen_mon = LoadMonitor(frozen._matrices["A"].dist, cfg)

    # Hot set: the columns the *active program* placed on shard 0.
    d = live._matrices["A"].dist
    order = np.arange(N) if d.perm is None else d.perm
    hot_cols = np.flatnonzero(d.x_layout.owner_of(order) == 0)

    k = max(int(N * k_frac), 8)
    n_uniform, n_hot = 2 * window, hot_windows * window
    stream = list(make_request_stream(N, hot_cols, k=k,
                                      n_uniform=n_uniform, n_hot=n_hot,
                                      seed=seed))

    tail = window            # steady-state tail for wall-clock throughput
    t_frozen = t_live = 0.0
    for i, (_, x) in enumerate(stream):
        timed = i >= len(stream) - tail
        t0 = time.perf_counter()
        frozen.spmv("A", x)
        t1 = time.perf_counter()
        live.spmv("A", x)
        t2 = time.perf_counter()
        frozen_mon.observe(x)
        if timed:
            t_frozen += t1 - t0
            t_live += t2 - t1

    m = live._matrices["A"]
    w_final = m.monitor.activity()          # caller order, mean 1
    served_plan = live.plan("A")

    # Fresh-autotune reference: what a from-scratch traffic-weighted tune
    # would pick for the final workload, and the CV it would achieve.
    fresh = autotune(A, num_shards=shards, seed=seed, probe=probe,
                     col_weight=w_final)
    fresh_dist = build_distributed(A, fresh.plan)
    cv_fresh = _weighted_cv(fresh_dist, w_final)
    cv_frozen = _weighted_cv(frozen._matrices["A"].dist, w_final)
    cv_live = _weighted_cv(m.dist, w_final)

    sec_frozen = probe_plan_seconds(A, ingest_plan, w_final)
    sec_live = probe_plan_seconds(A, served_plan, w_final)

    swaps = [e for e in m.rebalance_log if e.swapped]
    return {
        "workload": f"drift/{matrix}", "scale": scale, "shards": shards,
        "window": window, "requests": len(stream),
        "ingest_plan": f"{ingest_plan.reordering}/{ingest_plan.layout}/"
                       f"{ingest_plan.distribution}/{ingest_plan.kernel}",
        "served_plan": f"{served_plan.reordering}/{served_plan.layout}/"
                       f"{served_plan.distribution}/{served_plan.kernel}",
        "swaps": len(swaps),
        "rejected": sum(not e.swapped for e in m.rebalance_log),
        "load_cv": {"frozen": round(cv_frozen, 4),
                    "rebalanced": round(cv_live, 4),
                    "fresh_autotune": round(cv_fresh, 4),
                    "ratio_vs_fresh": round(cv_live / max(cv_fresh, 1e-12),
                                            3)},
        "modeled_spmv_seconds": {"frozen": sec_frozen,
                                 "rebalanced": sec_live,
                                 "speedup": round(sec_frozen /
                                                  max(sec_live, 1e-12), 3)},
        "host_requests_per_sec": {
            "frozen": round(tail / max(t_frozen, 1e-9)),
            "rebalanced": round(tail / max(t_live, 1e-9))},
    }


def check(entry: dict) -> bool:
    """The acceptance gates CI smoke-tests: swap happened, CV restored to
    within 2x of the fresh-autotune reference, modeled throughput up."""
    return (entry["swaps"] >= 1 and
            entry["load_cv"]["ratio_vs_fresh"] <= 2.0 and
            entry["modeled_spmv_seconds"]["speedup"] > 1.0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="cop20k_A")
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--hot-windows", type=int, default=10)
    ap.add_argument("--probe", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller matrix/stream, same gates")
    ap.add_argument("--json", action="store_true",
                    help="print the entry as JSON only")
    args = ap.parse_args()

    kw = dict(matrix=args.matrix, scale=args.scale, shards=args.shards,
              window=args.window, hot_windows=args.hot_windows,
              probe=args.probe, seed=args.seed)
    if args.fast:
        kw.update(scale=min(args.scale, 0.003), window=16, hot_windows=6)
    entry = run_drift_bench(**kw)
    ok = check(entry)

    if args.json:
        print(json.dumps(entry, indent=2))
    else:
        print(f"drift bench: {entry['workload']} scale={entry['scale']} "
              f"shards={entry['shards']} requests={entry['requests']}")
        print(f"  plan      : {entry['ingest_plan']} -> "
              f"{entry['served_plan']} "
              f"({entry['swaps']} swap(s), {entry['rejected']} rejected)")
        cv = entry["load_cv"]
        print(f"  load CV   : frozen {cv['frozen']:.3f} | rebalanced "
              f"{cv['rebalanced']:.3f} | fresh autotune "
              f"{cv['fresh_autotune']:.3f} "
              f"(ratio {cv['ratio_vs_fresh']:.2f}, bar 2.0)")
        s = entry["modeled_spmv_seconds"]
        print(f"  modeled   : {s['frozen']:.3e}s -> {s['rebalanced']:.3e}s "
              f"per served SpMV ({s['speedup']:.2f}x)")
        h = entry["host_requests_per_sec"]
        print(f"  host      : {h['frozen']} -> {h['rebalanced']} req/s "
              f"(steady-state tail; reference only)")
        print(f"  -> {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
