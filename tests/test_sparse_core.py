"""Core sparse engine: formats, partitioners, layouts, reorderings, traffic."""
import numpy as np
import pytest

from repro.core.layout import block_layout, cyclic_layout, make_layout
from repro.core.migration import count_migrations, remote_access_matrix
from repro.core.partition import make_partition, partition_nonzeros, partition_rows
from repro.core.reorder import REORDERINGS, reorder, reordering_permutation
from repro.core.sparse_matrix import (csr_from_coo, csr_row_nnz, csr_to_bcsr,
                                      csr_to_dense, csr_to_ell)
from repro.data.matrices import PAPER_SUITE, make_matrix


def rand_csr(M=200, N=240, nnz=2000, seed=0):
    rng = np.random.default_rng(seed)
    return csr_from_coo(rng.integers(0, M, nnz), rng.integers(0, N, nnz),
                        rng.standard_normal(nnz), (M, N))


class TestFormats:
    def test_coo_roundtrip_sums_duplicates(self):
        A = csr_from_coo([0, 0, 1], [1, 1, 0], [1.0, 2.0, 5.0], (2, 2))
        D = csr_to_dense(A)
        assert D[0, 1] == 3.0 and D[1, 0] == 5.0 and A.nnz == 2

    def test_row_slice_relative_offsets(self):
        A = rand_csr()
        sub = A.row_slice(10, 20)
        assert sub.row_ptr[0] == 0
        np.testing.assert_allclose(csr_to_dense(sub), csr_to_dense(A)[10:20])

    def test_ell_matches_dense(self):
        A = rand_csr()
        e = csr_to_ell(A)
        x = np.random.default_rng(1).standard_normal(A.ncols)
        y = (e.data * x[e.cols]).sum(1)[: A.nrows]
        np.testing.assert_allclose(y, csr_to_dense(A) @ x, atol=1e-6)

    def test_ell_overflow_capped(self):
        A = rand_csr(nnz=4000)
        e = csr_to_ell(A, lane=4, max_width=4)
        assert e.width == 4
        assert e.overflow_vals.size == A.nnz - (e.data != 0).sum()

    def test_ell_lane_alignment(self):
        e = csr_to_ell(rand_csr(), lane=128, sublane=8)
        assert e.data.shape[1] % 128 == 0 and e.data.shape[0] % 8 == 0

    def test_bcsr_reconstruction(self):
        A = rand_csr(M=64, N=64, nnz=500)
        b = csr_to_bcsr(A, (8, 8))
        dense = np.zeros((64, 64), np.float32)
        Mb = b.block_row_ptr.shape[0] - 1
        for r in range(Mb):
            for i in range(int(b.block_row_ptr[r]), int(b.block_row_ptr[r + 1])):
                c = int(b.block_cols[i])
                dense[r * 8:(r + 1) * 8, c * 8:(c + 1) * 8] += b.blocks[i]
        np.testing.assert_allclose(dense, csr_to_dense(A), atol=1e-5)


class TestPartition:
    def test_row_partition_even(self):
        A = rand_csr()
        p = partition_rows(A, 8)
        sizes = p.rows_per_shard()
        assert sizes.sum() == A.nrows and sizes.max() - sizes.min() <= 1

    def test_nonzero_partition_balances_nnz(self):
        A = make_matrix("cop20k_A", scale=0.01)
        pr = partition_rows(A, 8)
        pn = partition_nonzeros(A, 8)
        cv = lambda v: v.std() / v.mean()
        assert cv(pn.nnz_per_shard(A)) < cv(pr.nnz_per_shard(A)) + 1e-9
        assert cv(pn.nnz_per_shard(A)) < 0.05

    def test_owner_of_rows(self):
        A = rand_csr()
        p = partition_rows(A, 4)
        owners = p.owner_of_rows(A.nrows)
        for s in range(4):
            assert set(owners[list(p.rows_of(s))]) == {s}

    def test_thread_splits_cover(self):
        A = rand_csr()
        for strat in ("row", "nonzero"):
            p = make_partition(A, 4, strat)
            for s in range(4):
                t = p.thread_splits(A, 8)[s]
                assert t[0] == p.starts[s] and t[-1] == p.starts[s + 1]
                assert (np.diff(t) >= 0).all()


class TestLayout:
    @pytest.mark.parametrize("kind", ["block", "cyclic"])
    def test_roundtrip(self, kind):
        lay = make_layout(kind, 103, 8)
        v = np.arange(103, dtype=np.float64)
        np.testing.assert_array_equal(lay.from_sharded(lay.to_sharded(v)), v)

    def test_owner_semantics(self):
        b = block_layout(100, 4)       # block = 25
        assert b.owner_of(np.array([0, 24, 25, 99])).tolist() == [0, 0, 1, 3]
        c = cyclic_layout(100, 4)
        assert c.owner_of(np.array([0, 1, 4, 99])).tolist() == [0, 1, 0, 3]

    def test_local_index(self):
        for kind in ("block", "cyclic"):
            lay = make_layout(kind, 64, 4)
            idx = np.arange(64)
            own, loc = lay.owner_of(idx), lay.local_index(idx)
            # (owner, local) must be a bijection
            assert len({(o, l) for o, l in zip(own, loc)}) == 64


class TestReorder:
    @pytest.mark.parametrize("method", REORDERINGS)
    def test_permutation_valid(self, method):
        A = make_matrix("ford1", scale=0.05)
        perm = reordering_permutation(A, method, seed=1)
        assert sorted(perm) == list(range(A.nrows))

    def test_reorder_preserves_spectrum_sample(self):
        # P A P^T has identical multiset of values and nnz.
        A = make_matrix("ford1", scale=0.05)
        B = reorder(A, "random", seed=3)
        assert B.nnz == A.nnz
        np.testing.assert_allclose(np.sort(B.values), np.sort(A.values))

    def test_bfs_rebands_cop20k(self):
        """The paper's Fig. 9/10 mechanism: BFS pulls nnz to the diagonal."""
        A = make_matrix("cop20k_A", scale=0.02)
        B = reorder(A, "bfs")
        def mean_band(C):
            rows = np.repeat(np.arange(C.nrows), csr_row_nnz(C))
            return np.abs(rows - C.col_index).mean()
        assert mean_band(B) < 0.5 * mean_band(A)


class TestTraffic:
    def test_block_fewer_migrations_than_cyclic(self):
        """Paper Fig. 3: block layout generates 1.42-6.3x fewer migrations."""
        for name in ("ford1", "cop20k_A"):
            A = make_matrix(name, scale=0.02)
            p = make_partition(A, 8, "row")
            mb = count_migrations(A, p, make_layout("block", A.ncols, 8),
                                  make_layout("block", A.nrows, 8)).migrations
            mc = count_migrations(A, p, make_layout("cyclic", A.ncols, 8),
                                  make_layout("cyclic", A.nrows, 8)).migrations
            assert mc > 1.4 * mb

    def test_nonzero_lower_cv(self):
        """Paper Fig. 7: nnz distribution gives lower mem-instr CV."""
        A = make_matrix("cop20k_A", scale=0.02)
        xl = make_layout("block", A.ncols, 8)
        bl = make_layout("block", A.nrows, 8)
        cv_row = count_migrations(A, make_partition(A, 8, "row"), xl, bl).mem_instr_cv
        cv_nnz = count_migrations(A, make_partition(A, 8, "nonzero"), xl, bl).mem_instr_cv
        assert cv_nnz < cv_row

    def test_cop20k_hotspot_share(self):
        """Paper §IV-D: ~25% of x loads target shard 0."""
        A = make_matrix("cop20k_A", scale=0.05)
        p = make_partition(A, 8, "nonzero")
        rep = count_migrations(A, p, make_layout("block", A.ncols, 8),
                               make_layout("block", A.nrows, 8))
        assert 0.15 < rep.hotspot_share < 0.35
        T = remote_access_matrix(A, p, make_layout("block", A.ncols, 8))
        assert T.sum(0).argmax() == 0     # hottest column of traffic = shard 0

    def test_random_kills_hotspot(self):
        A = make_matrix("cop20k_A", scale=0.02)
        B = reorder(A, "random")
        xl = make_layout("block", A.ncols, 8)
        bl = make_layout("block", A.nrows, 8)
        r0 = count_migrations(A, make_partition(A, 8, "nonzero"), xl, bl)
        r1 = count_migrations(B, make_partition(B, 8, "nonzero"), xl, bl)
        assert r1.inbound_cv < 0.3 * r0.inbound_cv
        assert r1.migrations > r0.migrations     # and costs migrations
