"""Backend-equivalence harness for per-shard exchange policies.

Sweeps the full per-shard exchange x kernel grid against the float64
numpy oracle (``csr_matvec``), including batched ``(N, B)`` inputs,
degenerate zero-nnz shards, and single-shard meshes, plus a host-side
invariant on the device executor's exchange tables: rebuilding each
reader's ``[x_local ++ recv]`` buffer from the send tables in numpy must
reproduce the owner's x value at every mapped position — the exchange
machinery validated without a device mesh (the mesh-backed bitwise run
lives in ``test_program.py``'s subprocess tests).

Runs property-based when ``hypothesis`` is installed (the CI
``tier1-with-hypothesis`` job); falls back to a deterministic seeded
sweep of the same property otherwise, so the local environment — which
has no hypothesis — still covers every axis.
"""
import itertools

import numpy as np
import pytest

from repro.core.program import _device_operands, _halo_tables, execute, lower
from repro.core.sparse_matrix import CSRMatrix, csr_from_coo, csr_matvec
from repro.core.spmv import PLAN_EXCHANGES, PLAN_KERNELS, SpmvPlan
from repro.data.matrices import mixed_structure

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_csr(rng, M: int, density: float) -> CSRMatrix:
    n = max(int(M * M * density), 1)
    rows = rng.integers(0, M, n)
    cols = rng.integers(0, M, n)
    vals = rng.standard_normal(n)
    # a few explicit stored zeros — they must not widen the halo
    if n >= 4:
        vals[:2] = 0.0
    return csr_from_coo(rows, cols, vals, (M, M))


def _exchange_buffer_invariant(prog) -> None:
    """Host-side check of the all-to-all tables: every mapped position of
    every reader's augmented buffer holds the owner's x value."""
    S = prog.plan.num_shards
    if S == 1 or all(e == "allgather"
                     for e in prog.plan.resolved_shard_exchanges()):
        return
    lay = prog.x_layout
    rng = np.random.default_rng(99)
    x = rng.standard_normal(prog.matrix.ncols).astype(np.float32)
    xs = prog.x_to_device(x)                     # (S, per)
    send_idx, pos_map, H = _halo_tables(prog)
    per = xs.shape[1]
    for p in range(S):
        recv = np.stack([xs[q, send_idx[q, p]] for q in range(S)])
        aug = np.concatenate([xs[p], recv.reshape(-1)])
        need = np.flatnonzero(pos_map[p] >= per)  # global ids p receives
        if need.size == 0:
            continue
        own = lay.owner_of(need)
        loc = lay.local_index(need)
        np.testing.assert_array_equal(aug[pos_map[p, need]], xs[own, loc])


def _check_plan(A: CSRMatrix, plan: SpmvPlan, *, batch: bool = True) -> None:
    rng = np.random.default_rng(7)
    x = rng.standard_normal(A.ncols)
    prog = lower(A, plan)
    ref = csr_matvec(A, x)                       # float64 oracle
    y = execute(prog, x)
    np.testing.assert_allclose(y, ref, atol=2e-4, rtol=2e-4)
    if batch:
        X = rng.standard_normal((A.ncols, 3))
        Y = execute(prog, X)
        np.testing.assert_allclose(Y, csr_matvec(A, X), atol=2e-4,
                                   rtol=2e-4)
    # the device operand split must cover every stored entry exactly once
    ops = _device_operands(prog)
    loc_nnz = sum(st.nnz for st in prog.stages)
    assert ops["row_remote"].shape[0] == plan.num_shards
    assert loc_nnz == A.nnz
    _exchange_buffer_invariant(prog)


_KERNEL_CONFIGS = [
    ("ell", None), ("seg", None), ("hyb", None), ("split", None),
    ("tile", None),
    ("seg", ("ell", "seg", "hyb", "split")),
    ("tile", ("tile", "split", "tile", "ell")),
]


@pytest.mark.parametrize("exchanges",
                         list(itertools.product(PLAN_EXCHANGES, repeat=4)))
def test_full_per_shard_exchange_grid_vs_oracle(exchanges):
    """All 2^4 per-shard exchange assignments x every kernel config, on a
    structure with both a dense band and scattered rows."""
    A = mixed_structure(256, 256 * 6, seed=0)
    uniform = len(set(exchanges)) == 1
    for kernel, sk in _KERNEL_CONFIGS:
        plan = SpmvPlan(num_shards=4, kernel=kernel, shard_kernels=sk,
                        exchange=exchanges[0],
                        shard_exchanges=None if uniform else exchanges)
        _check_plan(A, plan, batch=(kernel in ("seg", "tile")))


@pytest.mark.parametrize("layout", ["block", "cyclic"])
@pytest.mark.parametrize("distribution", ["row", "nonzero"])
def test_mixed_exchange_all_layouts_distributions(layout, distribution):
    A = mixed_structure(256, 256 * 6, seed=1)
    plan = SpmvPlan(num_shards=4, layout=layout, distribution=distribution,
                    kernel="seg", exchange="halo",
                    shard_exchanges=("halo", "allgather", "allgather",
                                     "halo"))
    _check_plan(A, plan)


@pytest.mark.parametrize("kernel", PLAN_KERNELS)
def test_degenerate_zero_nnz_shards_all_exchange_mixes(kernel):
    """6x6 matrix over 4 shards: at least two shards lower to zero stored
    entries; every exchange mix must still reproduce the oracle."""
    A = csr_from_coo([0, 0, 5], [1, 4, 0], [2.0, -1.0, 3.0], (6, 6))
    for exchanges in [("halo",) * 4, ("allgather",) * 4,
                      ("halo", "allgather", "halo", "allgather")]:
        plan = SpmvPlan(num_shards=4, kernel=kernel,
                        exchange=exchanges[0],
                        shard_exchanges=None if len(set(exchanges)) == 1
                        else exchanges)
        _check_plan(A, plan)


@pytest.mark.parametrize("kernel", PLAN_KERNELS)
@pytest.mark.parametrize("exchange", PLAN_EXCHANGES)
def test_single_shard_mesh(kernel, exchange):
    """num_shards=1: no remote reads exist, every policy must degenerate
    to the same local product."""
    A = mixed_structure(128, 128 * 5, seed=2)
    plan = SpmvPlan(num_shards=1, kernel=kernel, exchange=exchange,
                    shard_exchanges=(exchange,))
    _check_plan(A, plan)


def _property(M, density, num_shards, layout, distribution, kid, seed,
              exchanges):
    rng = np.random.default_rng(seed)
    A = _random_csr(rng, M, density)
    kernel, sk = _KERNEL_CONFIGS[kid % len(_KERNEL_CONFIGS)]
    if sk is not None and num_shards != 4:
        sk = tuple(sk[i % len(sk)] for i in range(num_shards))
    ex = tuple(exchanges[i % len(exchanges)] for i in range(num_shards))
    plan = SpmvPlan(num_shards=num_shards, layout=layout,
                    distribution=distribution, kernel=kernel,
                    shard_kernels=sk, exchange=ex[0],
                    shard_exchanges=None if len(set(ex)) == 1 else ex)
    _check_plan(A, plan)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(M=hst.integers(min_value=8, max_value=160),
           density=hst.floats(min_value=0.002, max_value=0.2),
           num_shards=hst.sampled_from([1, 2, 4]),
           layout=hst.sampled_from(["block", "cyclic"]),
           distribution=hst.sampled_from(["row", "nonzero"]),
           kid=hst.integers(min_value=0, max_value=len(_KERNEL_CONFIGS) - 1),
           seed=hst.integers(min_value=0, max_value=2**31 - 1),
           exchanges=hst.lists(hst.sampled_from(PLAN_EXCHANGES),
                               min_size=4, max_size=4))
    def test_property_exchange_kernel_grid(M, density, num_shards, layout,
                                           distribution, kid, seed,
                                           exchanges):
        _property(M, density, num_shards, layout, distribution, kid, seed,
                  tuple(exchanges))

else:

    @pytest.mark.parametrize("seed", range(20))
    def test_property_exchange_kernel_grid_fallback(seed):
        """Deterministic stand-in for the hypothesis sweep (hypothesis is
        absent in the pinned local environment): the same property over a
        seeded random draw of every axis."""
        rng = np.random.default_rng(1000 + seed)
        M = int(rng.integers(8, 161))
        density = float(rng.uniform(0.002, 0.2))
        num_shards = int(rng.choice([1, 2, 4]))
        layout = str(rng.choice(["block", "cyclic"]))
        distribution = str(rng.choice(["row", "nonzero"]))
        kid = int(rng.integers(0, len(_KERNEL_CONFIGS)))
        exchanges = tuple(rng.choice(PLAN_EXCHANGES, size=4))
        _property(M, density, num_shards, layout, distribution, kid,
                  int(rng.integers(0, 2**31)), exchanges)
