"""Batched serving engine: prefill + decode over the distributed runtime.

The sparse-matrix serving path lives in :mod:`repro.serve.router` since
the multi-tenant refactor — :class:`SparseMatrixEngine` (autotuned
ingest, warm-start artifacts, per-tenant rebalancing, cross-request
micro-batching) is re-exported here so every historical import path
(``from repro.serve.engine import SparseMatrixEngine``) keeps working.

The LM :class:`Engine` below is small-scale runnable on CPU
(examples/serve_lm.py); the same step functions lower on the production
mesh for the dry-run's decode cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as mm
from repro.models.config import ModelConfig
from repro.serve.router import IngestedMatrix, MicroBatchConfig, \
    SparseMatrixEngine

__all__ = ["Engine", "ServeConfig", "SparseMatrixEngine",
           "IngestedMatrix", "MicroBatchConfig"]


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy


class Engine:
    """Single-host batched generation (KV/recurrent caches threaded)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self._decode = jax.jit(
            lambda p, t, c, pos: mm.decode_step(p, cfg, t, c, pos))

    def generate(self, prompts: np.ndarray, steps: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + steps) tokens.

        Edge semantics (regression-tested in tests/test_serve_engine.py):

        * ``steps == 0`` returns the prompts unchanged (no decode work);
        * ``S0 == 0`` with ``steps > 0`` raises ``ValueError`` — decoding
          needs at least one prefilled token to produce logits, so callers
          must seed the prompt (e.g. with BOS) explicitly rather than
          having the engine invent one (the old code crashed with a
          ``NameError`` here);
        * sampling (``temperature > 0``) requires an explicit PRNG key —
          silently falling back to greedy decoding was a correctness trap
          for anyone measuring sampled generations.
        """
        B, S0 = prompts.shape
        if steps == 0:
            return np.asarray(prompts, np.int32).copy()
        if self.serve_cfg.temperature > 0 and self.cfg.num_codebooks <= 1 \
                and key is None:
            raise ValueError(
                f"temperature={self.serve_cfg.temperature} requires a PRNG "
                f"key: pass key=jax.random.PRNGKey(seed) to generate(), or "
                f"set temperature=0 for greedy decoding")
        if S0 == 0:
            raise ValueError(
                "cannot decode from an empty prompt (S0 == 0): there are "
                "no logits to sample the first token from; seed each "
                "prompt with at least one token (e.g. BOS)")
        caches = mm.init_cache(self.cfg, B, self.serve_cfg.max_len)
        # Prefill by stepping tokens through the decode path (keeps one
        # compiled program; bulk-prefill lowering is exercised by dryrun).
        for t in range(S0):
            tok = prompts[:, t: t + 1]
            logits, caches = self._decode(self.params, jnp.asarray(tok),
                                          caches, jnp.int32(t))
        out = [prompts]
        pos = S0
        for _ in range(steps):
            if self.cfg.num_codebooks > 1:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, :1]   # head 0
            elif self.serve_cfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, 0] / self.serve_cfg.temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            out.append(np.asarray(nxt, np.int32))
            logits, caches = self._decode(self.params, nxt, caches,
                                          jnp.int32(pos))
            pos += 1
        return np.concatenate(out, axis=1)
