"""Pallas SpMV kernels + pure-jnp oracles (``ref.py``) + jit'd wrappers
(``ops.py``).

Three kernel families, one per sparse format/work-distribution choice:

* **ELL** (``spmv_ell.py``) — row-tiled padded-ELL SpMV (+ COO overflow
  tail = HYB via ``ops.hyb_spmv``).  Grid is shape-aware: (rows, width)
  tiles, so one power-law row widens every tile's reduction.
* **BELL** (``spmv_bell.py``) — Block-ELL SpMV/SpMM over MXU-aligned dense
  blocks; how structured sparsity pays on a systolic array.
* **Segmented** (``spmv_seg.py``) — nonzero-balanced merge-path-style
  SpMV: the nnz stream is cut into equal-size chunks, the kernel emits
  within-chunk prefix sums, and a jit'd cross-chunk carry fix-up
  assembles rows.  Grid is load-balance-aware: every step owns the same
  number of non-zeros regardless of row skew (the TPU analogue of the
  paper's nonzero work distribution, §III-C).

Every kernel has the same contract: pure-jnp oracle as the default
execution path, ``use_kernel=True`` for the Pallas path (TPU), and
``interpret=True`` to run the Pallas path on CPU.
"""
