"""musicgen-medium [audio] — arXiv:2306.05284 (hf).  Decoder-only over
EnCodec tokens; 4 codebooks, vocab 2048/codebook; frontend stubbed to
precomputed frame embeddings per the assignment brief."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
    num_heads=24, num_kv_heads=24, head_dim=64, d_ff=6144,
    vocab_size=2048, activation="swiglu", frontend="encodec_stub",
    num_codebooks=4)

def smoke_config():
    return ModelConfig(
        name="musicgen-smoke", family="audio", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
        activation="swiglu", frontend="encodec_stub", num_codebooks=4)
