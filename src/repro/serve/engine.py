"""Batched serving engine: prefill + decode over the distributed runtime.

Small-scale runnable on CPU (examples/serve_lm.py); the same step functions
lower on the production mesh for the dry-run's decode cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as mm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy


class Engine:
    """Single-host batched generation (KV/recurrent caches threaded)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self._decode = jax.jit(
            lambda p, t, c, pos: mm.decode_step(p, cfg, t, c, pos))

    def generate(self, prompts: np.ndarray, steps: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + steps) tokens."""
        B, S0 = prompts.shape
        caches = mm.init_cache(self.cfg, B, self.serve_cfg.max_len)
        # Prefill by stepping tokens through the decode path (keeps one
        # compiled program; bulk-prefill lowering is exercised by dryrun).
        tok = None
        for t in range(S0):
            tok = prompts[:, t: t + 1]
            logits, caches = self._decode(self.params, jnp.asarray(tok),
                                          caches, jnp.int32(t))
        out = [prompts]
        pos = S0
        for _ in range(steps):
            if self.cfg.num_codebooks > 1:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, :1]   # head 0
            elif self.serve_cfg.temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, 0] / self.serve_cfg.temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            out.append(np.asarray(nxt, np.int32))
            logits, caches = self._decode(self.params, nxt, caches,
                                          jnp.int32(pos))
            pos += 1
        return np.concatenate(out, axis=1)
