"""Cost-model-driven SpMV plan autotuner (the paper's study as policy).

The paper's core result is a *cost-benefit study*: no single layout /
work-distribution / reordering wins on a migratory-thread machine — the
right choice depends on sparsity structure (reordering buys up to 70% on
Emu vs <= 16% on a cache machine, §IV-E; the nonzero split only pays on
skewed matrices, §IV-C).  This module turns that study into an executable
policy, in the spirit of feature-based SpMV optimization selection
(Elafrou et al., 2017):

1. :func:`extract_features` — structural features of a
   :class:`~repro.core.sparse_matrix.CSRMatrix` (row-nnz CV, bandwidth,
   power-law tail share, hot-column share via
   :func:`~repro.core.migration.remote_access_matrix`).
2. :func:`estimate_cost` — an analytic cost model for one
   :class:`~repro.core.spmv.SpmvPlan`, grounded in the Emu machine
   constants (:class:`~repro.core.emu.EmuConfig`) and the exact migration
   counts of :mod:`repro.core.migration`; TPU-side terms (ELL padding,
   collective volume) follow :mod:`repro.core.cache_model`'s style of
   analytic accounting.
3. :func:`autotune` — score the full candidate grid, refine the top
   candidates with a short empirical probe (the vectorized Emu timeline
   simulator, :func:`~repro.core.emu.run_spmv`; on by default, see
   :data:`DEFAULT_PROBE`), and return a ranked, JSON-serializable
   :class:`PlanChoice`.

``SpmvPlan.auto(csr)`` (in :mod:`repro.core.spmv`) is the one-call
entry point; ``serve.engine.SparseMatrixEngine`` applies it to every
ingested matrix at load time.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
from typing import Iterable, Sequence

import numpy as np

from .emu import EmuConfig, run_spmv
from .layout import make_layout
from .migration import count_migrations, migration_arrivals, \
    remote_access_matrix, shard_load_map
from .partition import Partition, make_partition
from .reorder import REORDERINGS, reordering_permutation
from .sparse_matrix import CSRMatrix, ELL_LANE, ELL_SUBLANE, csr_from_coo, \
    csr_row_nnz, hyb_cap_width
from .spmv import PLAN_EXCHANGES, PLAN_KERNELS, SpmvPlan
from repro.kernels.ops import SEG_CHUNK

__all__ = ["DEFAULT_PROBE", "KERNELS", "SPLIT_CORES", "SPLIT_MIN_SPAN",
           "MatrixFeatures", "ShardFeatures",
           "PlanCost", "RankedPlan", "PlanChoice", "extract_features",
           "extract_shard_features", "estimate_cost", "autotune",
           "feature_key", "PlanCache", "kernel_shard_costs", "select_shard_kernels",
           "exchange_shard_costs", "select_shard_exchanges",
           "remote_row_share", "device_path_model", "split_meta"]

#: Bases the autotuner re-ranks with the Emu timeline simulator when the
#: caller does not pass ``probe``.  Probing is on by default since the
#: vectorized tick engine made a probe cost milliseconds instead of
#: minutes; pass ``probe=0`` for the analytic-only ranking.
DEFAULT_PROBE = 4

#: Adaptive-probe (``probe="auto"``) stopping rule: keep probing distinct
#: bases in analytic-rank order, tracking the pairwise *inversion rate*
#: between the analytic ordering and the measured seconds among probed
#: bases.  Once at least ``AUTO_PROBE_MIN`` bases are probed and the rate
#: has moved by at most ``AUTO_PROBE_TOL`` for ``AUTO_PROBE_STREAK``
#: consecutive probes, the analytic ranking is trusted for the remaining
#: tail — the estimate of how often the model mis-orders bases has
#: stopped changing, so more probes no longer buy information.
AUTO_PROBE_MIN = 4
AUTO_PROBE_STREAK = 2
AUTO_PROBE_TOL = 0.05


def _inversion_rate(seconds: Sequence[float]) -> float:
    """Pairwise inversion rate of measured seconds vs analytic order.

    ``seconds`` is listed in analytic-rank order (best model total
    first); an inversion is a pair the probe measured in the opposite
    order.  0.0 = the model's ordering is fully trustworthy so far.
    """
    n = len(seconds)
    if n < 2:
        return 0.0
    inv = sum(1 for i in range(n) for j in range(i + 1, n)
              if seconds[i] > seconds[j])
    return inv / (n * (n - 1) / 2)

#: Weight of the TPU-side kernel-execution term relative to Emu issue
#: cycles.  Small enough that Emu-visible terms dominate across (layout,
#: distribution, reordering) bases; decisive between the per-shard
#: ``ell``/``seg``/``hyb`` kernels, which the Emu terms cannot distinguish.
_W_PAD = 0.02
#: Cycles charged per x element moved by the collective exchange (halo
#: all-to-all vs all-gather) — again sub-dominant, decisive within a base.
_W_COMM = 0.25
#: Relative per-element cost of the two exchange mechanisms.  A halo
#: element is gathered through the send tables (indexed read on the
#: sender, positioned write on the reader) — ``_W_EXCH_GATHER`` each; an
#: all-gather element streams contiguously with no index math —
#: ``_W_EXCH_STREAM`` each.  A shard whose halo would cover more than
#: ``_W_EXCH_STREAM/_W_EXCH_GATHER`` of the padded vector is cheaper on
#: full replication — exactly the skewed shards of §IV; banded shards
#: keep the exact-entries halo.  ``select_shard_exchanges`` is the
#: per-shard argmin of these two columns.
_W_EXCH_GATHER = 2.0
_W_EXCH_STREAM = 1.0

#: Kernel formats a shard stage may select, in tie-break preference order
#: — alias of the single definition in ``spmv.PLAN_KERNELS`` (also aliased
#: as ``program.PROGRAM_KERNELS`` for the switch branch ids).
KERNELS = PLAN_KERNELS
#: Relative slot-cost weights behind :func:`kernel_shard_costs`.  An ELL
#: slab cell costs 1 (one regular FMA lane-slot, padding included); a seg
#: chunk cell costs ``_W_SEG_SCAN`` (the prefix-scan reads and writes each
#: slot) plus ``_W_SEG_PIECE`` per piece (the serialized carry fix-up
#: scatter-add); a HYB overflow entry costs ``_W_OVF`` (pure scatter-add,
#: no scan).  The absolute scale cancels inside a base — only the ratios
#: decide which format a shard gets.
_W_SEG_SCAN = 2.0
_W_SEG_PIECE = 16.0
_W_OVF = 8.0
#: Per-slot cost of the serialized cross-chunk carry chain: a row spanning
#: ``span`` chunks accumulates ``span`` piece carries into one output row
#: sequentially, so the seg fix-up's critical path grows with the longest
#: row — the §IV-D monster-row hot-spot surviving inside the seg format.
_W_SEG_CARRY = 4.0
#: Per-partial-slot cost of the split stage-2 combine ((NS, R) reads).
_W_SPLIT_COMBINE = 1.0
#: Per-cell cost of a dense (8, 128) tile in the bitmask-tiled walk.
#: Cheaper than an ELL slot: the tile stream has **no per-element column
#: index** (one block-column id per 1024 cells) and x moves in
#: lane-aligned tiles instead of gathered scalars — the cell is one FMA
#: against streamed operands, about half an ELL slot's data+index+gather.
_W_TILE = 0.5
#: Per-occupied-tile overhead of the coarse pointer walk (tid/bc table
#: entry, block-row scatter).
_W_TILE_PTR = 2.0

#: Core count the split policy tries to keep busy — one Emu nodelet's
#: hardware thread contexts (the ``get_cu_num`` analogue in aiter's
#: ``get_meta_param``).
SPLIT_CORES = EmuConfig().threads_per_nodelet
#: Minimum longest-row chunk span before splitting pays: below this the
#: carry chain is already short and stage 2 is pure overhead.
SPLIT_MIN_SPAN = 4


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.lru_cache(maxsize=4096)
def split_meta(nnz: int, max_row_nnz: int, num_cores: int = SPLIT_CORES,
               chunk: int = SEG_CHUNK) -> int:
    """Split count NS for one shard (the ``get_meta_param`` analogue).

    Driven by the shard's nnz stream and its longest row, exactly like
    aiter's occupancy heuristic is driven by batch/head geometry and the
    CU count: ``span = ceil(max_row_nnz / chunk)`` is the length of the
    serialized carry chain the seg fix-up would pay.  Shards whose rows
    all fit a few chunks (``span < SPLIT_MIN_SPAN``) keep NS=1 — stage 2
    would be pure overhead.  Otherwise NS is chosen so that (a) every
    core sees work even when the shard is one monster row (``NS >=
    span``), (b) a shard holding *several* monster rows still cuts each
    chain (``NS >= 2 * chunks / span`` keeps chunks-per-split at or
    under span/2), capped by the chunk count and the core budget, and
    floored to a power of two for even stage-2 tree reduction.  Cached:
    the planner calls this per shard per candidate base.

    >>> split_meta(100, 10)                    # short rows: no split
    1
    >>> split_meta(8192, 8192)                 # one monster row
    16
    >>> split_meta(3 * 8192, 8192) >= 16       # three monster rows
    True
    """
    chunks = max((nnz + chunk - 1) // chunk, 1)
    span = max((max_row_nnz + chunk - 1) // chunk, 1)
    if span < SPLIT_MIN_SPAN or chunks < 2:
        return 1
    want = max(span, -(-2 * chunks // span))
    ns = max(min(chunks, max(num_cores, 1), want), 1)
    p = 1
    while p * 2 <= ns:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class MatrixFeatures:
    """Structural features that drive plan selection.

    All fields are plain Python scalars so the dataclass JSON round-trips
    exactly.  Extraction is deterministic: every statistic is an exact
    vectorized reduction over the matrix (no sampling, no RNG).

    Attributes
    ----------
    nrows, ncols, nnz : int
        Matrix dimensions and stored non-zeros.
    density : float
        ``nnz / (nrows * ncols)``.
    row_nnz_mean, row_nnz_cv, row_nnz_max : float
        Mean / coefficient of variation / max of per-row non-zero counts.
        High CV is the paper's §IV-C trigger for the nonzero distribution.
    tail_share : float
        Fraction of all non-zeros held by the heaviest 1% of rows — the
        power-law-tail indicator (webbase/rmat style matrices).
    bandwidth_mean, bandwidth_p95 : float
        Mean and 95th-percentile of ``|i - j| / ncols`` over stored
        entries.  Small values mean a banded matrix whose block layout is
        already migration-cheap (ford1/audikw_1).
    hot_col_share : float
        Largest per-shard share of all x loads under a row partition +
        block layout, computed from
        :func:`~repro.core.migration.remote_access_matrix` — the §IV-D
        hot-spot indicator (cop20k_A's nodelet 0 serves ~25%).
    remote_frac : float
        Off-diagonal mass of the same access matrix: the fraction of x
        loads that are remote at all.
    """

    nrows: int
    ncols: int
    nnz: int
    density: float
    row_nnz_mean: float
    row_nnz_cv: float
    row_nnz_max: float
    tail_share: float
    bandwidth_mean: float
    bandwidth_p95: float
    hot_col_share: float
    remote_frac: float

    def to_dict(self) -> dict:
        """Return the features as a plain ``dict`` (JSON-ready)."""
        return dataclasses.asdict(self)


def extract_features(csr: CSRMatrix, *, num_shards: int = 8) -> MatrixFeatures:
    """Extract plan-selection features from a CSR matrix.

    Parameters
    ----------
    csr : CSRMatrix
        Host matrix (any shape; hot-column share uses a row partition over
        ``num_shards`` shards).
    num_shards : int, optional
        Shard count the hot-column / remote-fraction statistics are
        measured against (default 8, the Emu Chick nodelet count).

    Returns
    -------
    MatrixFeatures
        Deterministic scalar features (see the class docstring).

    Examples
    --------
    >>> import numpy as np
    >>> from repro.core.sparse_matrix import csr_from_coo
    >>> from repro.core.plan import extract_features
    >>> eye = csr_from_coo(np.arange(8), np.arange(8), np.ones(8), (8, 8))
    >>> f = extract_features(eye, num_shards=4)
    >>> (f.nnz, round(f.row_nnz_cv, 3), round(f.bandwidth_mean, 3))
    (8, 0.0, 0.0)
    >>> f.remote_frac        # diagonal: every x load is shard-local
    0.0
    """
    per_row = csr_row_nnz(csr).astype(np.float64)
    mean = float(per_row.mean()) if csr.nrows else 0.0
    cv = float(per_row.std() / mean) if mean else 0.0
    top = max(int(np.ceil(csr.nrows * 0.01)), 1)
    tail = float(np.sort(per_row)[-top:].sum() / max(csr.nnz, 1))

    rows_of_nnz = np.repeat(np.arange(csr.nrows), csr_row_nnz(csr))
    if csr.nnz:
        dist = np.abs(rows_of_nnz - csr.col_index.astype(np.int64))
        bw_mean = float(dist.mean() / max(csr.ncols, 1))
        bw_p95 = float(np.percentile(dist, 95) / max(csr.ncols, 1))
    else:
        bw_mean = bw_p95 = 0.0

    part = make_partition(csr, num_shards, "row")
    T = remote_access_matrix(csr, part, make_layout("block", csr.ncols,
                                                    num_shards))
    tot = float(T.sum())
    hot = float(T.sum(axis=0).max() / tot) if tot else 0.0
    remote = float((tot - np.trace(T)) / tot) if tot else 0.0

    return MatrixFeatures(
        nrows=csr.nrows, ncols=csr.ncols, nnz=csr.nnz,
        density=float(csr.nnz / max(csr.nrows * csr.ncols, 1)),
        row_nnz_mean=mean, row_nnz_cv=cv, row_nnz_max=float(per_row.max())
        if csr.nrows else 0.0,
        tail_share=tail, bandwidth_mean=bw_mean, bandwidth_p95=bw_p95,
        hot_col_share=hot, remote_frac=remote)


@dataclasses.dataclass(frozen=True)
class ShardFeatures:
    """Structural features of one shard's row slice (plain scalars).

    The per-shard analogue of :class:`MatrixFeatures`: what the per-shard
    kernel selector reacts to.  A shard with low ``row_nnz_cv`` and a
    moderate ``row_nnz_max`` keeps the regular ELL slab; a skewed shard
    (``row_nnz_cv`` high, ``tail_share`` high) pushes toward ``seg`` or
    ``hyb``; a block-structured shard (``tile_fill`` high — its nonzeros
    concentrate into few dense (8, 128) tiles) pushes toward ``tile``.
    Serialized with the :class:`PlanChoice` so an operator can audit
    *why* each shard got its kernel.  ``tile_fill`` defaults to 0.0 so
    pre-tile JSON still loads.
    """

    shard: int
    rows: int
    nnz: int
    row_nnz_mean: float
    row_nnz_cv: float
    row_nnz_max: float
    tail_share: float
    tile_fill: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def extract_shard_features(csr: CSRMatrix,
                           part: Partition) -> tuple:
    """Per-shard structural features for every row slice of ``part``.

    Examples
    --------
    >>> from repro.core.partition import make_partition
    >>> from repro.core.plan import extract_shard_features
    >>> from repro.data.matrices import powerlaw
    >>> A = powerlaw(512, 8000, seed=0)
    >>> fs = extract_shard_features(A, make_partition(A, 4, "nonzero"))
    >>> len(fs), fs[0].shard, sum(f.nnz for f in fs) == A.nnz
    (4, 0, True)
    """
    per_row = csr_row_nnz(csr).astype(np.float64)
    rows_of_nnz = np.repeat(np.arange(csr.nrows), csr_row_nnz(csr))
    out = []
    for p in range(part.num_shards):
        r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
        rows = per_row[r0:r1]
        nnz_p = int(csr.row_ptr[r1] - csr.row_ptr[r0])
        mean = float(rows.mean()) if r1 > r0 else 0.0
        cv = float(rows.std() / mean) if mean else 0.0
        top = max(int(np.ceil((r1 - r0) * 0.01)), 1)
        tail = float(np.sort(rows)[-top:].sum() / max(nnz_p, 1)) \
            if r1 > r0 else 0.0
        tiles = _shard_tile_count(csr, rows_of_nnz, r0, r1)
        fill = nnz_p / (tiles * ELL_SUBLANE * ELL_LANE) if tiles else 0.0
        out.append(ShardFeatures(
            shard=p, rows=r1 - r0, nnz=nnz_p, row_nnz_mean=mean,
            row_nnz_cv=cv,
            row_nnz_max=float(rows.max()) if r1 > r0 else 0.0,
            tail_share=tail, tile_fill=float(fill)))
    return tuple(out)


def _shard_tile_count(A: CSRMatrix, rows_of_nnz: np.ndarray, r0: int,
                      r1: int) -> int:
    """Occupied (8, 128) tiles of a shard's row slice — the block grid of
    :func:`~repro.core.sparse_matrix.csr_to_tile` on the shard CSR, so
    the cost model charges exactly what the lowered tile stage stores."""
    n0, n1 = int(A.row_ptr[r0]), int(A.row_ptr[r1])
    if n1 == n0:
        return 0
    brow = (rows_of_nnz[n0:n1] - r0) // ELL_SUBLANE
    bcol = A.col_index[n0:n1] // ELL_LANE
    Nb = max(-(-A.ncols // ELL_LANE), 1)
    return int(np.unique(brow.astype(np.int64) * Nb + bcol).size)


def feature_key(features: MatrixFeatures) -> tuple:
    """Coarse structural signature for feature-keyed plan caching.

    Sizes are binned to half-octaves (2x in nnz never collides, ~1.4x
    may) and the shape statistics are rounded to the resolution at which
    the cost model actually changes its mind; two matrices with equal
    keys are structurally similar enough that the autotuned plan for one
    is a sound choice for the other.  ``SparseMatrixEngine`` uses this to
    skip the full autotune grid when re-ingesting look-alike matrices;
    the leading version tag lets the binning evolve without silently
    reusing stale persisted keys.

    Examples
    --------
    >>> from repro.core.plan import extract_features, feature_key
    >>> from repro.data.matrices import make_matrix
    >>> a = extract_features(make_matrix("rmat", scale=0.002, seed=0))
    >>> b = extract_features(make_matrix("rmat", scale=0.002, seed=7))
    >>> feature_key(a) == feature_key(b)        # same structure, new seed
    True
    >>> c = extract_features(make_matrix("ford1", scale=0.05))
    >>> feature_key(a) == feature_key(c)        # different archetype
    False
    """
    def half_octave(v: int) -> int:
        return int(round(2.0 * np.log2(max(v, 1))))

    return ("fk1", half_octave(features.nrows), half_octave(features.ncols),
            half_octave(features.nnz),
            round(features.row_nnz_cv, 1), round(features.tail_share, 2),
            round(features.bandwidth_mean, 1),
            round(features.bandwidth_p95, 1),
            round(features.hot_col_share, 1),
            round(features.remote_frac, 1))


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Analytic cost breakdown for one plan, in Gossamer-Core cycles.

    ``issue_cycles`` is the critical-path memory-instruction term (max over
    nodelets, :class:`~repro.core.emu.EmuConfig` ``access_cycles`` each);
    ``ingress_cycles`` the migration-arrival service time at the hottest
    nodelet (the §IV-D collapse mechanism); ``migration_cycles`` the
    per-thread migration overhead; ``padding_cycles`` the (down-weighted)
    TPU-side kernel-execution-slot term — :func:`kernel_shard_costs`
    summed over shards, the term that separates the per-shard
    ``ell``/``seg``/``hyb`` kernels (the field name predates the per-shard
    refactor and is kept for JSON back-compatibility);
    ``comm_cycles`` the (down-weighted) collective-volume term that
    separates ``halo``/``allgather`` — since the per-shard exchange axis
    it is the hottest reader's weighted ingest under the plan's
    (possibly per-shard) policies.  ``overlap_cycles`` is the part of
    the schedule the pipelined executor hides: the smaller of the comm
    term and the local-slice share of the kernel term (rows with no
    remote reads execute while the collective is in flight), and
    ``total = max(issue, ingress) + migration + padding + comm -
    overlap`` is the ranking key.  ``overlap_cycles`` defaults to 0.0 so
    JSON written before the pipelined executor still loads.
    """

    issue_cycles: float
    ingress_cycles: float
    migration_cycles: float
    padding_cycles: float
    comm_cycles: float
    total: float
    overlap_cycles: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class RankedPlan:
    """One scored candidate: the plan, its model cost, optional probe time."""

    plan: SpmvPlan
    cost: PlanCost
    probe_seconds: float | None = None
    probe_mbs: float | None = None

    def to_dict(self) -> dict:
        return {"plan": dataclasses.asdict(self.plan),
                "cost": self.cost.to_dict(),
                "probe_seconds": self.probe_seconds,
                "probe_mbs": self.probe_mbs}


@dataclasses.dataclass(frozen=True)
class PlanChoice:
    """Ranked autotuning result (best candidate first).

    ``ranking[0].plan`` is the chosen plan; :meth:`to_json` /
    :meth:`from_json` round-trip the whole object, so a serving layer can
    persist the decision next to the ingested matrix.

    JSON written before the per-shard refactor (no ``shard_kernels`` plan
    field, no ``shard_features`` entry) still loads: the missing fields
    default to ``None``, which lowers as the uniform program
    (``tests/test_plan.py`` pins a legacy fixture).
    """

    features: MatrixFeatures
    ranking: tuple[RankedPlan, ...]
    probed: int
    #: Per-shard features of the winning plan's (reordered) partition —
    #: the audit trail for its shard_kernels.  None on legacy JSON and on
    #: externally-supplied plans.
    shard_features: tuple | None = None
    #: Bottleneck class of the whole matrix / of each winning-partition
    #: shard (:meth:`repro.core.oracle.CostOracle.classify` — the Elafrou
    #: bandwidth/latency/imbalance taxonomy).  Deterministic functions of
    #: the features above, persisted so a serving layer can audit *why*
    #: a plan was picked.  None on legacy JSON.
    bottleneck: str | None = None
    shard_bottlenecks: tuple | None = None

    @property
    def plan(self) -> SpmvPlan:
        """The winning :class:`~repro.core.spmv.SpmvPlan`."""
        return self.ranking[0].plan

    def to_json(self, indent: int | None = None) -> str:
        """Serialize to a JSON string (stable field order)."""
        return json.dumps({
            "features": self.features.to_dict(),
            "ranking": [r.to_dict() for r in self.ranking],
            "probed": self.probed,
            "shard_features": None if self.shard_features is None else
            [f.to_dict() for f in self.shard_features],
            "bottleneck": self.bottleneck,
            "shard_bottlenecks": None if self.shard_bottlenecks is None
            else list(self.shard_bottlenecks),
        }, indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "PlanChoice":
        """Inverse of :meth:`to_json` (exact dataclass equality).

        Tolerates pre-per-shard JSON: absent ``shard_features`` /
        ``plan.shard_kernels`` load as ``None`` (uniform program); absent
        ``bottleneck`` / ``shard_bottlenecks`` (pre-oracle JSON) load as
        ``None`` too."""
        d = json.loads(s)
        ranking = tuple(
            RankedPlan(plan=SpmvPlan(**r["plan"]),
                       cost=PlanCost(**r["cost"]),
                       probe_seconds=r["probe_seconds"],
                       probe_mbs=r["probe_mbs"])
            for r in d["ranking"])
        sf = d.get("shard_features")
        sb = d.get("shard_bottlenecks")
        return cls(features=MatrixFeatures(**d["features"]),
                   ranking=ranking, probed=int(d["probed"]),
                   shard_features=None if sf is None else
                   tuple(ShardFeatures(**f) for f in sf),
                   bottleneck=d.get("bottleneck"),
                   shard_bottlenecks=None if sb is None else tuple(sb))


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------

def _base_metrics(A: CSRMatrix, part: Partition, layout: str,
                  emu: EmuConfig,
                  col_weight: np.ndarray | None = None) -> dict:
    """Emu-visible cost terms shared by every (kernel, exchange) variant.

    ``col_weight`` (per-column activity, in ``A``'s index order) switches
    the issue and ingress terms to their traffic-weighted versions, so the
    ranking optimizes for the workload actually observed instead of the
    dense all-columns-hot one; the migration-overhead and exchange-volume
    terms stay structural (they are properties of the built program, not
    of one request).
    """
    S = part.num_shards
    xl = make_layout(layout, A.ncols, S)
    bl = make_layout(layout, A.nrows, S)
    tr = count_migrations(A, part, xl, bl)
    arrivals = migration_arrivals(A, part, xl, col_weight=col_weight)
    if col_weight is None:
        issue = float(tr.mem_instr_per_nodelet.max()) * emu.access_cycles
    else:
        lm, base = shard_load_map(A, part, xl, bl)
        issue = float((lm @ col_weight + base).max()) * emu.access_cycles
    ingress = float(arrivals.max()) * emu.tick_cycles / emu.ingress_rate
    migration = tr.migrations / S * emu.migration_overhead_cycles

    # Exchange volumes (x elements per shard): all-gather replicates the
    # padded vector; halo moves S * H where H is the max unique remote-x
    # set any (reader, owner) pair exchanges (build_halo pads to the max).
    rows_of_nnz = np.repeat(np.arange(A.nrows), csr_row_nnz(A))
    home_of_nnz = part.owner_of_rows(A.nrows)[rows_of_nnz]
    owners = xl.owner_of(A.col_index)
    remote = owners != home_of_nnz
    if remote.any():
        key = home_of_nnz[remote].astype(np.int64) * A.ncols + \
            A.col_index[remote].astype(np.int64)
        uniq = np.unique(key)
        up, ucol = uniq // A.ncols, uniq % A.ncols
        pair_counts = np.zeros((S, S), dtype=np.int64)
        np.add.at(pair_counts, (up, xl.owner_of(ucol)), 1)
        H = int(pair_counts.max())
        halo_per_shard = pair_counts.sum(axis=1).astype(np.float64)
    else:
        H = 0
        halo_per_shard = np.zeros(S, dtype=np.float64)
    return {"issue": issue, "ingress": ingress, "migration": migration,
            "halo_elems": S * max(H, 1), "allgather_elems": xl.padded_length(),
            "halo_per_shard": halo_per_shard, "part": part}


def kernel_shard_costs(A: CSRMatrix, part: Partition) -> dict:
    """Per-shard analytic execution-slot cost of every kernel format.

    Returns ``{kernel: (S,) float64}``.  The model charges what each
    format actually executes on a shard's row slice:

    * ``ell``   — every padded slab cell: ``round_up(rows, 8) *
      round_up(max_row_nnz, 128)``.  Regular stream, but a single heavy
      row inflates every row's width.
    * ``seg``   — ``_W_SEG_SCAN`` per chunk cell (the prefix scan touches
      each slot twice) plus ``_W_SEG_PIECE`` per piece (the serialized
      carry fix-up scatter) plus ``_W_SEG_CARRY`` per slot of every
      row-spanning carry: a row covering ``span_r`` chunks serializes
      ``span_r - 1`` carries into one output row, and the charges *sum
      over rows* — a shard holding eight monster rows pays eight chains,
      not the single longest one (charging only ``max_r span_r`` was the
      monster-row under-count this model used to make).  Immune to
      row-width padding, but pays per-row bookkeeping — dense regular
      rows are cheaper in ELL.
    * ``hyb``   — the p95-capped slab (:func:`~repro.core.sparse_matrix.
      hyb_cap_width`) plus ``_W_OVF`` per spilled entry.  Wins when a thin
      tail of hub rows would otherwise blow up the ELL width.
    * ``split`` — the seg scan/piece terms with every carry chain cut by
      the policy split count NS (:func:`split_meta`): each row's chain
      shrinks to ``ceil(span_r / NS)`` because each split scatters into
      its own partial accumulator, at the price of ``_W_SPLIT_COMBINE``
      per stage-2 partial slot (NS x padded rows).  Strictly worse than seg
      on short-row shards (NS=1 still pays the combine), strictly better
      once one row spans many chunks — exactly the §IV-D trade.
    * ``tile``  — ``_W_TILE`` per cell of every *occupied* (8, 128) tile
      plus ``_W_TILE_PTR`` per tile for the pointer walk.  The cell is
      cheaper than an ELL slot (no per-element column-index stream, x
      moves in lane-aligned tiles), but a scattered nonzero drags a
      whole 1024-cell tile in — tile wins on banded / block-structured
      shards (high fill, padding-free of ELL's max-width tax) and loses
      catastrophically on scattered ones, which is exactly the
      cache-blocking criterion of Elafrou et al. the selector needs.

    ``select_shard_kernels`` takes the per-shard argmin of this table and
    the plan cost model sums the selected column over shards
    (:func:`_plan_kernel_slots`): kernel slots are *aggregate* execution
    work — the single-host serving executor runs the stages sequentially,
    and on the device path wasted slots are wasted FLOPs/HBM traffic
    whichever shard issues them — so the per-shard argmin minimizes the
    term exactly, and a heterogeneous program strictly beats every uniform
    kernel whenever the selection is genuinely mixed.  (The parallel
    critical-path terms — issue, ingress — remain max-aggregated; the
    kernel term is the down-weighted tax on top.)
    """
    S = part.num_shards
    per_row = csr_row_nnz(A)
    rows_of_nnz = np.repeat(np.arange(A.nrows), per_row)
    out = {k: np.zeros(S, dtype=np.float64) for k in KERNELS}
    for p in range(S):
        r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
        rows = per_row[r0:r1]
        nnz_p = int(A.row_ptr[r1] - A.row_ptr[r0])
        rows_pad = _round_up(max(r1 - r0, 1), ELL_SUBLANE)
        max_row = int(rows.max()) if r1 > r0 else 0
        W = _round_up(max(max_row, 1), ELL_LANE)
        out["ell"][p] = rows_pad * W
        chunks = max((nnz_p + SEG_CHUNK - 1) // SEG_CHUNK, 1)
        pieces = int((rows > 0).sum()) + chunks
        spans = -(-rows // SEG_CHUNK)          # chunks each row spans
        carries = int(np.maximum(spans - 1, 0).sum())
        scan = _W_SEG_SCAN * chunks * SEG_CHUNK + _W_SEG_PIECE * pieces
        out["seg"][p] = scan + _W_SEG_CARRY * carries * SEG_CHUNK
        Wc = hyb_cap_width(rows) if r1 > r0 else ELL_LANE
        ovf = int(np.maximum(rows - Wc, 0).sum())
        out["hyb"][p] = rows_pad * Wc + _W_OVF * ovf
        ns = split_meta(nnz_p, max_row)
        carries_s = int(np.maximum(-(-spans // ns) - 1, 0).sum())
        out["split"][p] = scan + \
            _W_SEG_CARRY * carries_s * SEG_CHUNK + \
            _W_SPLIT_COMBINE * ns * rows_pad
        tiles = max(_shard_tile_count(A, rows_of_nnz, r0, r1), 1)
        out["tile"][p] = tiles * (_W_TILE * ELL_SUBLANE * ELL_LANE
                                  + _W_TILE_PTR)
    return out


def select_shard_kernels(A: CSRMatrix, part: Partition,
                         kernels: Sequence[str] = KERNELS,
                         costs: dict | None = None) -> tuple:
    """Per-shard argmin of :func:`kernel_shard_costs` (ties prefer the
    earlier entry of ``kernels`` — the regular ELL stream by default).

    Examples
    --------
    A skewed power-law matrix never keeps the uncapped ELL slab on a
    hub-heavy shard:

    >>> from repro.core.partition import make_partition
    >>> from repro.core.plan import select_shard_kernels
    >>> from repro.data.matrices import powerlaw
    >>> A = powerlaw(1024, 40000, seed=0)
    >>> sel = select_shard_kernels(A, make_partition(A, 4, "row"))
    >>> from repro.core.plan import KERNELS
    >>> len(sel), set(sel) <= set(KERNELS)
    (4, True)
    """
    costs = kernel_shard_costs(A, part) if costs is None else costs
    kernels = tuple(kernels)
    return tuple(
        min(kernels, key=lambda k: (costs[k][p], kernels.index(k)))
        for p in range(part.num_shards))


def _plan_kernel_slots(costs: dict, plan: SpmvPlan) -> float:
    """Total kernel slot cost of a plan over all shards (per-shard aware)."""
    sk = plan.resolved_shard_kernels()
    return float(sum(costs[k][p] for p, k in enumerate(sk)))


def _majority_kernel(sel: tuple) -> str:
    counts = {k: 0 for k in KERNELS}
    for k in sel:
        counts[k] += 1
    return max(KERNELS, key=lambda k: (counts[k], -KERNELS.index(k)))


def exchange_shard_costs(A: CSRMatrix, part: Partition,
                         layout="block") -> dict:
    """Per-shard weighted exchange cost of both policies.

    Returns ``{policy: (S,) float64}`` — the elements reader shard p
    ingests under each policy, weighted by the mechanism's per-element
    cost: ``halo`` counts p's unique active remote columns (zero-valued
    stored entries excluded, matching the executor's tables) at
    ``_W_EXCH_GATHER`` each; ``allgather`` counts the full padded vector
    at ``_W_EXCH_STREAM`` each.  The per-shard argmin is
    :func:`select_shard_exchanges`; the plan cost's comm term takes the
    hottest reader (:func:`_assemble_cost`).  ``layout`` may be a layout
    name or a built :class:`~repro.core.layout.VectorLayout`.
    """
    S = part.num_shards
    xl = layout if hasattr(layout, "owner_of") else \
        make_layout(layout, A.ncols, S)
    rows_of_nnz = np.repeat(np.arange(A.nrows), csr_row_nnz(A))
    home = part.owner_of_rows(A.nrows)[rows_of_nnz]
    owners = xl.owner_of(A.col_index)
    rem = (A.values != 0) & (owners != home)
    halo_per = np.zeros(S, dtype=np.float64)
    if rem.any():
        key = home[rem].astype(np.int64) * A.ncols + \
            A.col_index[rem].astype(np.int64)
        uniq = np.unique(key)
        np.add.at(halo_per, uniq // A.ncols, 1.0)
    return {"halo": _W_EXCH_GATHER * halo_per,
            "allgather": np.full(S, _W_EXCH_STREAM * float(xl.padded_length()),
                                 dtype=np.float64)}


def select_shard_exchanges(A: CSRMatrix, part: Partition, layout="block",
                           costs: dict | None = None) -> tuple:
    """Per-shard argmin of :func:`exchange_shard_costs` (ties prefer the
    earlier entry of :data:`~repro.core.spmv.PLAN_EXCHANGES` — the
    exact-entries halo)."""
    costs = exchange_shard_costs(A, part, layout) if costs is None else costs
    return tuple(
        min(PLAN_EXCHANGES,
            key=lambda e: (costs[e][p], PLAN_EXCHANGES.index(e)))
        for p in range(part.num_shards))


def _majority_exchange(sel: tuple) -> str:
    counts = {e: 0 for e in PLAN_EXCHANGES}
    for e in sel:
        counts[e] += 1
    return max(PLAN_EXCHANGES,
               key=lambda e: (counts[e], -PLAN_EXCHANGES.index(e)))


def remote_row_share(A: CSRMatrix, part: Partition,
                     layout="block") -> np.ndarray:
    """(S,) fraction of each shard's stored entries living in rows that
    read at least one active remote x entry.

    This is the pipelined executor's slice split exactly
    (``program._row_remote_flags``): entries in all-local rows run in
    the local pass — issuable while the exchange is in flight — so
    ``1 - share`` of a shard's kernel slots can hide behind the
    collective.  ``layout`` may be a name or a built layout.
    """
    S = part.num_shards
    xl = layout if hasattr(layout, "owner_of") else \
        make_layout(layout, A.ncols, S)
    per_row = csr_row_nnz(A)
    rows_of_nnz = np.repeat(np.arange(A.nrows), per_row)
    home = part.owner_of_rows(A.nrows)[rows_of_nnz]
    owners = xl.owner_of(A.col_index)
    rem = (A.values != 0) & (owners != home)
    row_remote = np.zeros(A.nrows, dtype=bool)
    row_remote[rows_of_nnz[rem]] = True
    share = np.zeros(S, dtype=np.float64)
    for p in range(S):
        r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
        nnz_p = int(A.row_ptr[r1] - A.row_ptr[r0])
        if nnz_p:
            share[p] = float(per_row[r0:r1][row_remote[r0:r1]].sum()) / nnz_p
    return share


def device_path_model(A: CSRMatrix, part: Partition, plan: SpmvPlan,
                      emu: EmuConfig | None = None) -> dict:
    """Modeled device-path (SPMD) latency of one SpMV, serial vs pipelined.

    The :class:`PlanCost` totals model the Emu machine, where the kernel
    term is *total work* (summed over nodelets).  The shard_map device
    path is SPMD: one step's latency is the **slowest shard's** kernel
    time plus the collective.  This helper prices exactly that from the
    same per-shard tables:

    * ``serial`` — the pre-pipeline schedule: the exchange completes
      before any kernel work, so latency is
      ``max_p(slots_p) + comm``.
    * ``pipelined`` — the local slice (all-local rows,
      :func:`remote_row_share`) runs during the collective:
      ``max(max_p(local_p), comm) + max_p(remote_p)``.

    ``A``/``part`` must already be in the plan's reordered index space.
    Returns the two latencies (cycles) plus every term.  The per-shard
    tables come from the :class:`~repro.core.oracle.CostOracle` facade —
    the same single set of weights every other consumer queries.
    """
    from .oracle import DEFAULT_ORACLE as oracle
    emu = emu or EmuConfig(nodelets=plan.num_shards)
    costs = oracle.kernel_costs(A, part)
    slots = np.array([costs[k][p] for p, k in
                      enumerate(plan.resolved_shard_kernels())],
                     dtype=np.float64)
    share = remote_row_share(A, part, plan.layout)
    ex = oracle.exchange_costs(A, part, layout=plan.layout)
    per = np.array([ex[e][p] for p, e in
                    enumerate(plan.resolved_shard_exchanges())],
                   dtype=np.float64)
    comm = _W_COMM * max(float(per.max()), 1.0)
    t_all = _W_PAD * float(slots.max()) * emu.access_cycles
    t_loc = _W_PAD * float((slots * (1.0 - share)).max()) * emu.access_cycles
    t_rem = _W_PAD * float((slots * share).max()) * emu.access_cycles
    serial = t_all + comm
    pipelined = max(t_loc, comm) + t_rem
    return {"serial_cycles": serial, "pipelined_cycles": pipelined,
            "kernel_cycles": t_all, "local_slice_cycles": t_loc,
            "remote_slice_cycles": t_rem, "comm_cycles": comm,
            "speedup": serial / max(pipelined, 1e-12)}


def _permute_weights(w: np.ndarray, perm: np.ndarray | None) -> np.ndarray:
    """Carry per-column weights through a symmetric reordering.

    ``perm[old] = new`` (the :func:`~repro.core.reorder.reordering_permutation`
    convention), so the weight of old column j must land at new index
    ``perm[j]``.
    """
    if perm is None:
        return w
    out = np.empty_like(w)
    out[perm] = w
    return out


def _active_submatrix(A: CSRMatrix, col_weight: np.ndarray,
                      seed: int = 0) -> CSRMatrix:
    """Traffic-importance-thinned structure (same shape) for probing.

    Each stored entry survives with probability ``min(w[col]/mean(w), 1)``
    — columns at or above mean activity keep every entry, colder columns
    are thinned in proportion to how rarely the request stream touches
    them.  The result is the structure *one expected request* exercises:
    probing it with the Emu engine measures how a plan handles the
    observed traffic, not the dense all-columns-hot workload.  Uniform
    weights return ``A`` unchanged (the probe degrades to the structural
    one), and thinning is deterministic for a given ``seed``.

    Callers comparing plans must thin **once in a common index order** and
    permute the thinned matrix per plan — thinning after reordering would
    hand each plan a different entry set.
    """
    w = np.asarray(col_weight, dtype=np.float64)
    mean = w.mean() if w.size else 0.0
    if mean <= 0:
        return A
    p = np.minimum(w / mean, 1.0)
    if (p >= 1.0).all():
        return A
    rng = np.random.default_rng(seed)
    keep = rng.random(A.nnz) < p[A.col_index]
    if keep.all() or not keep.any():
        return A
    rows = np.repeat(np.arange(A.nrows), csr_row_nnz(A))
    return csr_from_coo(rows[keep], A.col_index[keep], A.values[keep],
                        A.shape, sum_duplicates=False)


def estimate_cost(csr: CSRMatrix, plan: SpmvPlan, *,
                  emu: EmuConfig | None = None,
                  col_weight: np.ndarray | None = None) -> PlanCost:
    """Analytic cost of executing SpMV under ``plan`` on the Emu model.

    The matrix is reordered per ``plan.reordering`` before accounting, so
    the returned cost is for the plan exactly as ``build_distributed``
    would realize it.

    Parameters
    ----------
    csr : CSRMatrix
        Square host matrix (reorderings are symmetric permutations).
    plan : SpmvPlan
        Candidate configuration to score.
    emu : EmuConfig, optional
        Machine constants; defaults to ``EmuConfig(nodelets=plan.num_shards)``.
    col_weight : np.ndarray, optional
        (ncols,) per-column activity in the *caller's* index order (it is
        permuted alongside the matrix for reordered plans); weights the
        issue/ingress terms by observed traffic.

    Returns
    -------
    PlanCost
        Cycle-denominated breakdown; ``total`` is the ranking key.

    Examples
    --------
    A banded matrix is cheaper under a block layout than a cyclic one
    (paper Fig. 3):

    >>> import numpy as np
    >>> from repro.core.plan import estimate_cost
    >>> from repro.core.spmv import SpmvPlan
    >>> from repro.data.matrices import banded
    >>> A = banded(512, 4096, 8, seed=0)
    >>> blk = estimate_cost(A, SpmvPlan(layout="block"))
    >>> cyc = estimate_cost(A, SpmvPlan(layout="cyclic"))
    >>> blk.total < cyc.total
    True
    """
    emu = emu or EmuConfig(nodelets=plan.num_shards)
    perm = reordering_permutation(csr, plan.reordering, seed=plan.seed,
                                  parts=plan.num_shards)
    if plan.reordering == "none":
        A, w = csr, col_weight
    else:
        A = csr.permuted(perm, perm)
        w = None if col_weight is None else _permute_weights(
            np.asarray(col_weight, dtype=np.float64), perm)
    from .oracle import DEFAULT_ORACLE as oracle
    part = make_partition(A, plan.num_shards, plan.distribution)
    base = _base_metrics(A, part, plan.layout, emu, col_weight=w)
    costs = oracle.kernel_costs(A, part)
    sk = plan.resolved_shard_kernels()
    slots_p = np.array([costs[k][p] for p, k in enumerate(sk)],
                       dtype=np.float64)
    share = remote_row_share(A, part, plan.layout)
    local_slots = float((slots_p * (1.0 - share)).sum())
    return _assemble_cost(base, float(slots_p.sum()),
                          plan.resolved_shard_exchanges(), emu,
                          local_slots=local_slots)


def _assemble_cost(base: dict, pad_slots: float, policies, emu: EmuConfig,
                   local_slots: float = 0.0) -> PlanCost:
    """Assemble a :class:`PlanCost` under the pipelined schedule.

    ``policies`` is a per-shard exchange tuple (or one uniform policy
    string); the comm term is the hottest reader's weighted ingest —
    ``_W_EXCH_GATHER`` per exact halo element vs ``_W_EXCH_STREAM`` per
    streamed full-replication element.  ``local_slots`` is the kernel
    slot share living in all-local rows: the pipelined executor runs
    those while the collective is in flight, so the smaller of that
    slice and the comm term comes off the serial total.
    """
    pad = _W_PAD * pad_slots * emu.access_cycles
    halo_per = base["halo_per_shard"]
    ag = float(base["allgather_elems"])
    if isinstance(policies, str):
        policies = (policies,) * len(halo_per)
    per_cost = [_W_EXCH_GATHER * float(halo_per[p]) if e == "halo"
                else _W_EXCH_STREAM * ag
                for p, e in enumerate(policies)]
    comm = _W_COMM * max(max(per_cost), 1.0)
    pad_local = min(_W_PAD * local_slots * emu.access_cycles, pad)
    overlap = min(comm, pad_local)
    total = max(base["issue"], base["ingress"]) + base["migration"] + \
        pad + comm - overlap
    return PlanCost(issue_cycles=float(base["issue"]),
                    ingress_cycles=float(base["ingress"]),
                    migration_cycles=float(base["migration"]),
                    padding_cycles=float(pad), comm_cycles=float(comm),
                    total=float(total), overlap_cycles=float(overlap))


# --------------------------------------------------------------------------
# autotuner
# --------------------------------------------------------------------------

def autotune(csr: CSRMatrix, *, num_shards: int = 8, seed: int = 0,
             layouts: Sequence[str] = ("block", "cyclic"),
             distributions: Sequence[str] = ("row", "nonzero"),
             reorderings: Iterable[str] = REORDERINGS,
             kernels: Sequence[str] = KERNELS,
             exchanges: Sequence[str] = ("halo", "allgather"),
             probe: int | str | None = None,
             emu: EmuConfig | None = None,
             col_weight: np.ndarray | None = None,
             per_shard: bool = True) -> PlanChoice:
    """Rank the candidate plan grid for one matrix.

    Scores every plan in ``layouts x distributions x reorderings x kernels
    x exchanges`` with :func:`estimate_cost` (reordered matrices and
    per-base migration accounting are computed once and shared).  With
    ``per_shard`` (the default), every (reordering, distribution) base
    additionally contributes a **heterogeneous candidate** whose kernel is
    selected shard-by-shard (:func:`select_shard_kernels` — the per-shard
    argmin of :func:`kernel_shard_costs`); the kernel term sums over
    shards, so the heterogeneous candidate's kernel term is never worse
    than any uniform kernel's on the same base, and strictly better
    exactly on the mixed-structure matrices the global plan loses on
    (``benchmarks/hetero_bench.py``).  When both exchange policies are in
    play, each base likewise contributes mixed-exchange candidates
    (:func:`select_shard_exchanges`, ``plan.shard_exchanges``) whenever
    the per-shard argmin over :func:`exchange_shard_costs` disagrees
    across shards.  The model's top candidates are then
    optionally re-ranked with a short empirical probe: the Emu timeline
    simulator (:func:`~repro.core.emu.run_spmv`) run on the ``probe`` best
    distinct (reordering, layout, distribution) bases.  Probed candidates
    rank by measured seconds (model total as the tiebreak) ahead of
    unprobed ones; the probe cannot see kernels, so within a probed base
    the analytic kernel term still decides.

    Parameters
    ----------
    csr : CSRMatrix
        Square host matrix.
    num_shards : int, optional
        Shards/nodelets the plan targets (default 8).
    seed : int, optional
        Seed threaded into the stochastic reorderings (default 0).
    layouts, distributions, reorderings, kernels, exchanges : sequence of str
        Candidate axes; defaults are the full paper grid (kernels now
        include the HYB capped-ELL + overflow format, the split-nnz
        two-stage ``split`` family, and the bitmask-tiled ``tile``
        family).
    probe : int or "auto", optional
        Number of distinct bases to simulate; defaults to
        :data:`DEFAULT_PROBE` (0 = analytic only).  The probe runs the
        vectorized Emu engine, so re-ranking is cheap enough to stay on
        for serving-time ingestion (``serve.engine.SparseMatrixEngine``);
        ``benchmarks/autotune_bench.py`` checks the resulting regret.
        ``probe="auto"`` spends probes adaptively: bases are measured in
        analytic-rank order until the measured-vs-analytic pairwise
        inversion rate stabilizes (:data:`AUTO_PROBE_MIN` /
        :data:`AUTO_PROBE_TOL` / :data:`AUTO_PROBE_STREAK`), so easy
        matrices stop after a handful of probes while model-hostile ones
        keep probing up to the full base grid — this is what lets
        ``benchmarks/hetero_bench.py`` drop its fixed ``probe=20``.
    emu : EmuConfig, optional
        Machine constants for both the model and the probe.
    col_weight : np.ndarray, optional
        (ncols,) per-column activity in the caller's index order.  When
        given, the analytic issue/ingress terms are traffic-weighted and
        the simulator probe runs on the traffic-active submatrix
        (:func:`_active_submatrix`) — the re-plan path of the serving
        rebalancer (``serve/rebalance.py``).  Uniform weights reproduce
        the unweighted ranking.
    per_shard : bool, optional
        Add the per-shard heterogeneous candidates (default True); pass
        False for the pre-refactor uniform-kernel grid (what
        ``benchmarks/hetero_bench.py`` calls the *best global* baseline).

    Returns
    -------
    PlanChoice
        Features + full ranking, best candidate first, plus the winning
        partition's per-shard features (:class:`ShardFeatures`).

    Examples
    --------
    >>> import numpy as np
    >>> from repro.core.plan import autotune
    >>> from repro.data.matrices import powerlaw
    >>> A = powerlaw(256, 2048, seed=0)
    >>> choice = autotune(A, num_shards=4)
    >>> choice.probed                 # simulator re-ranking, on by default
    4
    >>> choice.plan.distribution      # skewed rows -> nonzero split wins
    'nonzero'
    >>> len(choice.ranking) >= 2 * 2 * 5 * 5 * 2   # + per-shard candidates
    True
    >>> len(choice.shard_features)    # winner's per-shard audit trail
    4
    """
    from .oracle import DEFAULT_ORACLE as oracle
    emu = emu or EmuConfig(nodelets=num_shards)
    probe = DEFAULT_PROBE if probe is None else probe
    adaptive = isinstance(probe, str)
    if adaptive and probe != "auto":
        raise ValueError(f"probe must be an int or 'auto', got {probe!r}")
    if col_weight is not None:
        col_weight = np.asarray(col_weight, dtype=np.float64)

    reordered: dict[str, CSRMatrix] = {}
    weights: dict[str, np.ndarray | None] = {}
    perms: dict[str, np.ndarray] = {}
    for method in reorderings:
        perm = reordering_permutation(csr, method, seed=seed,
                                      parts=num_shards)
        perms[method] = perm
        if method == "none":
            reordered[method], weights[method] = csr, col_weight
        else:
            reordered[method] = csr.permuted(perm, perm)
            weights[method] = None if col_weight is None else \
                _permute_weights(col_weight, perm)

    bases: dict[tuple, dict] = {}
    parts: dict[tuple, Partition] = {}
    candidates: list[RankedPlan] = []
    for method, A in reordered.items():
        for dist in distributions:
            part = make_partition(A, num_shards, dist)
            parts[(method, dist)] = part
            costs = oracle.kernel_costs(A, part)
            shard_sel = None
            if per_shard and len(kernels) > 1:
                sel = oracle.select_kernels(A, part, kernels=kernels,
                                            costs=costs)
                if len(set(sel)) > 1:     # uniform pick == existing plan
                    shard_sel = sel
            for layout in layouts:
                key = (method, layout, dist)
                bases[key] = _base_metrics(A, part, layout, emu,
                                           col_weight=weights[method])
                share = remote_row_share(A, part, layout)
                ex_sel = None
                if per_shard and "halo" in exchanges \
                        and "allgather" in exchanges:
                    sel = oracle.select_exchanges(A, part, layout)
                    if len(set(sel)) > 1:  # uniform pick == existing plan
                        ex_sel = sel
                loc = {k: float((costs[k] * (1.0 - share)).sum())
                       for k in kernels}
                for kernel in kernels:
                    for exchange in exchanges:
                        plan = SpmvPlan(layout=layout, distribution=dist,
                                        reordering=method, exchange=exchange,
                                        kernel=kernel, num_shards=num_shards,
                                        seed=seed)
                        cost = _assemble_cost(bases[key],
                                              float(costs[kernel].sum()),
                                              exchange, emu,
                                              local_slots=loc[kernel])
                        candidates.append(RankedPlan(plan=plan, cost=cost))
                    if ex_sel is not None:
                        plan = SpmvPlan(layout=layout, distribution=dist,
                                        reordering=method,
                                        exchange=_majority_exchange(ex_sel),
                                        kernel=kernel, num_shards=num_shards,
                                        seed=seed, shard_exchanges=ex_sel)
                        cost = _assemble_cost(bases[key],
                                              float(costs[kernel].sum()),
                                              ex_sel, emu,
                                              local_slots=loc[kernel])
                        candidates.append(RankedPlan(plan=plan, cost=cost))
                if shard_sel is not None:
                    slots = float(sum(costs[k][p]
                                      for p, k in enumerate(shard_sel)))
                    slots_loc = float(sum(costs[k][p] * (1.0 - share[p])
                                          for p, k in enumerate(shard_sel)))
                    hetero_ex = list(exchanges)
                    if ex_sel is not None:
                        hetero_ex.append(ex_sel)
                    for exchange in hetero_ex:
                        uniform = isinstance(exchange, str)
                        plan = SpmvPlan(
                            layout=layout, distribution=dist,
                            reordering=method,
                            exchange=exchange if uniform
                            else _majority_exchange(exchange),
                            kernel=_majority_kernel(shard_sel),
                            num_shards=num_shards, seed=seed,
                            shard_kernels=shard_sel,
                            shard_exchanges=None if uniform else exchange)
                        cost = _assemble_cost(bases[key], slots, exchange,
                                              emu, local_slots=slots_loc)
                        candidates.append(RankedPlan(plan=plan, cost=cost))

    candidates.sort(key=lambda r: r.cost.total)

    n_probed = 0
    if adaptive or probe > 0:
        # Traffic-thinned probe source, cut once in the caller's order so
        # every probed base sees the same entry set (then permuted per
        # reordering alongside the plan itself).
        probe_src = csr if col_weight is None else \
            _active_submatrix(csr, col_weight, seed=seed)
        probe_times: dict[tuple, tuple[float, float]] = {}
        auto_secs: list[float] = []   # analytic-rank order, adaptive mode
        auto_rate = 0.0
        auto_streak = 0
        auto_done = False
        for cand in candidates:
            key = (cand.plan.reordering, cand.plan.layout,
                   cand.plan.distribution)
            if key in probe_times:
                continue
            if auto_done if adaptive else len(probe_times) >= probe:
                continue
            A = reordered[cand.plan.reordering]
            part = make_partition(A, num_shards, cand.plan.distribution)
            if probe_src is csr:
                probe_A = A
            else:
                perm = perms[cand.plan.reordering]
                probe_A = probe_src if cand.plan.reordering == "none" \
                    else probe_src.permuted(perm, perm)
            res = run_spmv(probe_A, part,
                           make_layout(cand.plan.layout, A.ncols, num_shards),
                           emu)
            probe_times[key] = (float(res.seconds), float(res.bandwidth_mbs))
            if adaptive:
                auto_secs.append(float(res.seconds))
                rate = _inversion_rate(auto_secs)
                if len(auto_secs) >= AUTO_PROBE_MIN:
                    if abs(rate - auto_rate) <= AUTO_PROBE_TOL:
                        auto_streak += 1
                        if auto_streak >= AUTO_PROBE_STREAK:
                            auto_done = True
                    else:
                        auto_streak = 0
                auto_rate = rate
        probed = []
        for cand in candidates:
            key = (cand.plan.reordering, cand.plan.layout,
                   cand.plan.distribution)
            if key in probe_times:
                sec, mbs = probe_times[key]
                cand = dataclasses.replace(cand, probe_seconds=sec,
                                           probe_mbs=mbs)
            probed.append(cand)
        probed.sort(key=lambda r: (r.probe_seconds is None,
                                   r.probe_seconds or 0.0, r.cost.total))
        candidates = probed
        n_probed = len(probe_times)

    winner = candidates[0].plan
    shard_features = extract_shard_features(
        reordered[winner.reordering],
        parts[(winner.reordering, winner.distribution)])
    features = extract_features(csr, num_shards=num_shards)
    return PlanChoice(features=features,
                      ranking=tuple(candidates), probed=n_probed,
                      shard_features=shard_features,
                      bottleneck=oracle.classify(features),
                      shard_bottlenecks=oracle.classify_shards(
                          shard_features,
                          remote_frac=features.remote_frac))


# --------------------------------------------------------------------------
# plan cache (feature-keyed, disk-backed)
# --------------------------------------------------------------------------

class PlanCache:
    """Feature-keyed plan cache: in-memory L1 dict + optional disk L2.

    Keys are whatever the caller derives from :func:`feature_key` (the
    serving layer uses ``(feature_key(features), num_shards)``); values
    are :class:`~repro.core.spmv.SpmvPlan`.  With ``cache_dir`` set, every
    ``put`` also writes a small JSON file named by the key's hash, so a
    *different engine instance* — or a restarted process — skips the
    autotune grid for any structurally similar matrix the fleet has seen.
    The stored key is verified verbatim on read (hash collisions and
    ``feature_key`` version bumps degrade to a miss, never a wrong plan),
    and corrupt or concurrently rewritten files read as misses too.

    >>> cache = PlanCache()
    >>> cache.put(("fk1", 8), SpmvPlan(kernel="seg"))
    >>> cache.get(("fk1", 8)).kernel
    'seg'
    >>> cache.get(("fk1", 9)) is None
    True
    """

    def __init__(self, cache_dir: str | None = None):
        self._mem: dict = {}
        self.cache_dir = cache_dir
        if cache_dir:
            os.makedirs(cache_dir, exist_ok=True)

    def _path(self, key) -> str:
        h = hashlib.sha256(repr(key).encode()).hexdigest()[:32]
        return os.path.join(self.cache_dir, f"plan_{h}.json")

    def get(self, key) -> SpmvPlan | None:
        """The cached plan for ``key``, promoting disk hits into the L1."""
        if key in self._mem:
            return self._mem[key]
        if not self.cache_dir:
            return None
        try:
            with open(self._path(key)) as f:
                d = json.load(f)
            if d.get("key") != repr(key):
                return None
            plan = SpmvPlan(**d["plan"])
        except (FileNotFoundError, json.JSONDecodeError, TypeError,
                ValueError, KeyError):
            return None
        self._mem[key] = plan
        return plan

    def put(self, key, plan: SpmvPlan) -> None:
        """Record ``key -> plan`` in the L1 and (atomically) on disk."""
        self._mem[key] = plan
        if not self.cache_dir:
            return
        d = dataclasses.asdict(plan)
        for f_ in ("shard_kernels", "split_counts", "shard_exchanges"):
            if d[f_] is not None:
                d[f_] = list(d[f_])
        path = self._path(key)
        tmp = f"{path}.tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"key": repr(key), "plan": d}, f, indent=1)
        os.replace(tmp, path)

    def __len__(self) -> int:
        return len(self._mem)
