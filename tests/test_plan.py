"""Planner tests: deterministic features, JSON round-trip, auto-plan
correctness on the synthetic suite, and the regret bound vs the Emu model.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.plan import (MatrixFeatures, PlanChoice, autotune,
                             estimate_cost, extract_features)
from repro.core.reorder import REORDERINGS, reorder
from repro.core.sparse_matrix import csr_to_dense
from repro.core.spmv import SpmvPlan, build_distributed, local_spmv
from repro.data.matrices import make_matrix

# The ISSUE's synthetic suite: rmat, banded, power-law, dense-block
SUITE = {
    "rmat": 0.002,
    "ford1": 0.05,          # banded
    "webbase-1M": 0.001,    # power-law
    "nd24k": 0.0005,        # dense blocks
}


def test_features_deterministic_for_fixed_seed():
    A = make_matrix("rmat", scale=0.002, seed=3)
    B = make_matrix("rmat", scale=0.002, seed=3)
    f1 = extract_features(A, num_shards=8)
    f2 = extract_features(B, num_shards=8)
    assert f1 == f2
    # features are plain scalars (JSON-able, no numpy leakage)
    for k, v in f1.to_dict().items():
        assert isinstance(v, (int, float)), (k, type(v))


def test_features_read_structure():
    """The features separate the suite archetypes the way the model needs."""
    banded = extract_features(make_matrix("ford1", scale=0.05))
    plaw = extract_features(make_matrix("webbase-1M", scale=0.001))
    hot = extract_features(make_matrix("cop20k_A", scale=0.02))
    assert banded.bandwidth_mean < plaw.bandwidth_mean
    assert banded.row_nnz_cv < plaw.row_nnz_cv
    # the banded mesh keeps most x loads shard-local; the scattered
    # power-law matrix does not
    assert banded.remote_frac < 0.5 * plaw.remote_frac
    # the arrowhead matrix concentrates x loads on shard 0 (paper §IV-D):
    # clearly above the uniform 1/8 share and above the banded baseline
    assert hot.hot_col_share > 1.4 / 8
    assert hot.hot_col_share > banded.hot_col_share
    assert MatrixFeatures(**banded.to_dict()) == banded


def test_plan_choice_json_roundtrip():
    A = make_matrix("rmat", scale=0.002)
    choice = autotune(A, num_shards=4)
    s = choice.to_json()
    json.loads(s)                          # really is JSON
    back = PlanChoice.from_json(s)
    assert back == choice
    assert back.plan == choice.plan
    # probe fields survive too
    probed = autotune(A, num_shards=4, probe=2)
    assert probed.probed == 2
    assert probed.ranking[0].probe_seconds is not None
    assert PlanChoice.from_json(probed.to_json()) == probed
    # probed reports bases actually simulated, not the requested budget
    small = autotune(A, num_shards=4, reorderings=("none",), probe=8)
    assert small.probed == 2 * 2            # layouts x distributions


def test_tile_plans_roundtrip_and_legacy_shard_features():
    """Tile-kernel plans and the ``tile_fill`` shard feature survive the
    PlanChoice JSON round-trip, the autotune grid reaches ``tile`` on a
    block-structured matrix, and pre-tile ShardFeatures dicts (no
    ``tile_fill`` key) still load with the 0.0 default."""
    from repro.core.plan import ShardFeatures
    from repro.data.matrices import blocked_band
    A = blocked_band(1024, 215 * 1024, seed=0)
    choice = autotune(A, num_shards=4, probe=0)
    kernels = set()
    for r in choice.ranking:
        kernels.update(r.plan.resolved_shard_kernels())
    assert "tile" in kernels
    assert choice.shard_features is not None
    # The nnz-balanced base partition smears the band across shards, so the
    # fill is well below the per-tile 1.0 — but still clearly nonzero on the
    # banded shards and exactly preserved through JSON.
    assert max(sf.tile_fill for sf in choice.shard_features) > 0.1
    back = PlanChoice.from_json(choice.to_json())
    assert back == choice
    assert [sf.tile_fill for sf in back.shard_features] == \
        [sf.tile_fill for sf in choice.shard_features]
    d = dict(choice.shard_features[0].to_dict())
    del d["tile_fill"]
    legacy = ShardFeatures(**d)
    assert legacy.tile_fill == 0.0


def test_autotune_probes_by_default():
    """Simulator re-ranking is on unless the caller opts out (probe=0)."""
    from repro.core.plan import DEFAULT_PROBE

    A = make_matrix("rmat", scale=0.002)
    choice = autotune(A, num_shards=4)
    assert choice.probed == DEFAULT_PROBE > 0
    assert choice.ranking[0].probe_seconds is not None
    # the winner is a measured candidate, ranked by simulated seconds
    probed = [r for r in choice.ranking if r.probe_seconds is not None]
    secs = [r.probe_seconds for r in probed]
    assert secs == sorted(secs)


def test_ranking_sorted_and_full_grid():
    A = make_matrix("ford1", scale=0.05)
    choice = autotune(A, num_shards=4, probe=0)
    totals = [r.cost.total for r in choice.ranking]
    assert totals == sorted(totals)
    # uniform grid (kernels now include hyb) + optional per-shard
    # heterogeneous candidates (per-shard kernels and/or per-shard
    # exchange policies, only when the selection is genuinely mixed)
    from repro.core.plan import KERNELS
    uniform = [r for r in choice.ranking
               if r.plan.shard_kernels is None
               and r.plan.shard_exchanges is None]
    hetero = [r for r in choice.ranking
              if r.plan.shard_kernels is not None]
    mixed_ex = [r for r in choice.ranking
                if r.plan.shard_exchanges is not None]
    assert len(uniform) == 2 * 2 * len(REORDERINGS) * len(KERNELS) * 2
    for r in hetero:
        assert len(set(r.plan.shard_kernels)) > 1
        assert len(r.plan.shard_kernels) == 4
    for r in mixed_ex:
        assert len(set(r.plan.shard_exchanges)) > 1
        assert len(r.plan.shard_exchanges) == 4
    assert choice.probed == 0
    # disabling per_shard reproduces the pre-refactor uniform-only grid
    uni_only = autotune(A, num_shards=4, probe=0, per_shard=False)
    assert all(r.plan.shard_kernels is None
               and r.plan.shard_exchanges is None
               for r in uni_only.ranking)


def test_per_shard_candidate_never_loses_to_uniform_on_same_base():
    """Within one base, the heterogeneous candidate's kernel-slot term is
    the per-shard argmin — its total can never exceed the best uniform
    kernel's on that base (max over shards of min <= min over kernels of
    max)."""
    from repro.data.matrices import mixed_structure
    A = mixed_structure(1024, 120_000, seed=0)
    choice = autotune(A, num_shards=4, probe=0)
    hetero = [r for r in choice.ranking if r.plan.shard_kernels is not None]
    assert hetero, "mixed-structure matrix produced no per-shard candidate"
    for h in hetero:
        base = (h.plan.reordering, h.plan.layout, h.plan.distribution,
                h.plan.exchange)
        uni = [r for r in choice.ranking
               if r.plan.shard_kernels is None and
               (r.plan.reordering, r.plan.layout, r.plan.distribution,
                r.plan.exchange) == base]
        assert h.cost.total <= min(u.cost.total for u in uni) + 1e-9


def test_shard_kernel_selection_reads_structure():
    """Dense-regular rows keep the ELL slab; short/skewed rows move off it."""
    from repro.core.partition import make_partition
    from repro.core.plan import kernel_shard_costs, select_shard_kernels
    from repro.data.matrices import mixed_structure
    A = mixed_structure(1024, 33 * 1024, seed=0)
    # the nonzero split puts the dense band on the leading shards and the
    # short-row sparse block on the trailing ones
    part = make_partition(A, 4, "nonzero")
    sel = select_shard_kernels(A, part)
    assert len(set(sel)) > 1, sel
    # band shards: regular lane-width rows -> ell; the short-row sparse
    # shards never keep the 128-lane slab floor
    assert sel[0] == "ell" and sel[1] == "ell", sel
    assert sel[3] == "seg", sel
    costs = kernel_shard_costs(A, part)
    assert set(costs) == {"ell", "seg", "hyb", "split", "tile"}
    for v in costs.values():
        assert v.shape == (4,) and (v > 0).all()
    # short-row shards never prefer split over seg: the stage-2 combine
    # is pure overhead when no row spans a chunk
    assert (costs["split"] >= costs["seg"]).sum() >= 1


def test_split_meta_policy():
    """The split-count policy: 1 below the span floor, capped by chunks
    and core count, power-of-two, and monotone-ish in work."""
    from repro.core.plan import SPLIT_CORES, SPLIT_MIN_SPAN, split_meta
    assert split_meta(100, 10) == 1                   # nothing spans
    assert split_meta(8 * 512, 2 * 512) == 1          # span < min floor
    ns = split_meta(16 * 512, 16 * 512)               # one monster row
    assert ns >= SPLIT_MIN_SPAN and ns & (ns - 1) == 0
    assert split_meta(10**9, 10**8) <= SPLIT_CORES
    for nnz, mx in ((10**5, 10**4), (10**6, 10**5), (10**7, 10**6)):
        n = split_meta(nnz, mx)
        chunks = -(-nnz // 512)
        assert 1 <= n <= min(chunks, SPLIT_CORES)


def test_split_reachable_from_auto_on_powerlaw_tail():
    """`SpmvPlan.auto` on the monster-row workload reaches the split
    family on its own, and the plan serves exactly."""
    from repro.data.matrices import powerlaw_tail
    A = powerlaw_tail(2048, 2 * 4 * 2048, n_monster=4, seed=0)
    choice = autotune(A, num_shards=4, seed=0)
    kernels = choice.plan.shard_kernels or (choice.plan.kernel,) * 4
    assert "split" in kernels, choice.plan
    from repro.core.program import execute, lower
    prog = lower(A, choice.plan)
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(prog, x),
                               csr_to_dense(A) @ x, atol=1e-4, rtol=1e-5)


def test_plan_json_roundtrip_with_split_counts():
    """Plans carrying explicit per-shard split counts survive the
    PlanChoice JSON round-trip and validate their shapes."""
    import dataclasses
    p = SpmvPlan(num_shards=4, shard_kernels=("split", "seg", "seg", "seg"),
                 split_counts=(8, 1, 1, 1))
    assert p.resolved_split_counts() == (8, 1, 1, 1)
    d = json.loads(json.dumps(dataclasses.asdict(p)))
    back = SpmvPlan(**d)
    assert back == p and back.split_counts == (8, 1, 1, 1)
    # None -> policy decides (0 sentinel per shard)
    q = SpmvPlan(num_shards=4, kernel="split")
    assert q.resolved_split_counts() == (0, 0, 0, 0)
    with pytest.raises(ValueError, match="split_counts"):
        SpmvPlan(num_shards=4, split_counts=(2, 2)).resolved_split_counts()
    with pytest.raises(ValueError, match="split_counts"):
        SpmvPlan(num_shards=2, split_counts=(0, 1))


FIXTURES = pathlib.Path(__file__).parent / "fixtures"
# Kept as a module constant for external reference; the frozen bytes now
# live in tests/fixtures/ alongside the pre-per-shard-exchange one.
LEGACY_CHOICE_JSON = (FIXTURES /
                      "plan_choice_pre_shard_kernels.json").read_text()


def test_legacy_plan_choice_json_loads_as_uniform_program():
    """Pre-per-shard JSON (no shard_kernels, no shard_features) must keep
    loading — and lower as the uniform program it always meant."""
    from repro.core.program import lower
    choice = PlanChoice.from_json(LEGACY_CHOICE_JSON)
    assert choice.plan.shard_kernels is None
    assert choice.shard_features is None
    assert choice.plan.resolved_shard_kernels() == ("seg",) * 4
    # it lowers and serves as the uniform-seg program
    A = make_matrix("ford1", scale=0.05)
    prog = lower(A, choice.plan)
    assert prog.shard_kernels() == ("seg",) * 4
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(local_spmv(prog, x), csr_to_dense(A) @ x,
                               atol=1e-6)
    # and the new-style JSON of the same choice still round-trips
    assert PlanChoice.from_json(choice.to_json()) == choice


def test_pre_shard_exchange_fixture_loads_and_executes():
    """PlanChoice JSON frozen before the per-shard exchange axis existed
    (plans carry shard_kernels/split_counts but no ``shard_exchanges``
    key) must load as the uniform exchange policy it always meant,
    round-trip through the new writer, and still execute."""
    from repro.core.program import execute, lower
    raw = (FIXTURES / "plan_choice_pre_shard_exchanges.json").read_text()
    assert "shard_exchanges" not in raw
    choice = PlanChoice.from_json(raw)
    assert choice.plan.shard_exchanges is None
    assert choice.plan.resolved_shard_exchanges() == ("halo",) * 4
    assert choice.plan.shard_kernels == ("ell", "seg", "hyb", "split")
    assert choice.plan.split_counts == (1, 1, 1, 2)
    # the audit trail survives, including the ranked runner-up
    assert choice.shard_features is not None
    assert len(choice.shard_features) == 4
    assert choice.ranking[1].plan.resolved_shard_exchanges() == \
        ("allgather",) * 4
    # new-style JSON of the same choice round-trips exactly
    assert PlanChoice.from_json(choice.to_json()) == choice
    # and the loaded plan lowers and matches the oracle end to end
    A = make_matrix("ford1", scale=0.05)
    prog = lower(A, choice.plan)
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(prog, x), csr_to_dense(A) @ x,
                               atol=1e-5)


def test_plan_retarget_drops_mismatched_shard_kernels():
    p = SpmvPlan(num_shards=4, shard_kernels=("ell", "seg", "hyb", "seg"))
    assert p.retarget(4).shard_kernels == ("ell", "seg", "hyb", "seg")
    assert p.retarget(8).shard_kernels is None
    assert p.retarget(8).num_shards == 8
    with pytest.raises(ValueError, match="num_shards"):
        SpmvPlan(num_shards=8,
                 shard_kernels=("ell", "seg")).resolved_shard_kernels()
    with pytest.raises(ValueError, match="shard kernel"):
        SpmvPlan(shard_kernels=("ell", "bogus"))


@pytest.mark.parametrize("name", list(SUITE))
def test_auto_plan_matches_ref_on_suite(name):
    A = make_matrix(name, scale=SUITE[name], seed=0)
    plan = SpmvPlan.auto(A, num_shards=4)
    dist = build_distributed(A, plan)
    x = np.random.default_rng(1).standard_normal(A.ncols)
    y = local_spmv(dist, x)
    ref = csr_to_dense(A) @ x
    np.testing.assert_allclose(y, ref, atol=1e-6, rtol=1e-6)


def test_estimate_cost_prefers_block_on_banded():
    A = make_matrix("ford1", scale=0.05)
    blk = estimate_cost(A, SpmvPlan(layout="block"))
    cyc = estimate_cost(A, SpmvPlan(layout="cyclic"))
    assert blk.total < cyc.total


def test_auto_regret_within_bound_vs_emu_model():
    """Chosen plan is never >1.25x slower than the best static plan."""
    name, scale = "cop20k_A", 0.005
    A = make_matrix(name, scale=scale)
    cfg = EmuConfig(nodelets=4)
    sim = {}
    for reo in REORDERINGS:
        B = reorder(A, reo, parts=4)
        for lay in ("block", "cyclic"):
            for strat in ("row", "nonzero"):
                part = make_partition(B, 4, strat)
                res = run_spmv(B, part, make_layout(lay, B.ncols, 4), cfg)
                sim[(reo, lay, strat)] = res.seconds
    best = min(sim.values())
    plan = SpmvPlan.auto(A, num_shards=4, probe=8)
    chosen = sim[(plan.reordering, plan.layout, plan.distribution)]
    assert chosen <= 1.25 * best, (plan, chosen / best)


def test_sparse_matrix_engine_serves_tuned_plans():
    from repro.serve.engine import SparseMatrixEngine
    eng = SparseMatrixEngine(num_shards=4)
    A = make_matrix("cop20k_A", scale=0.005)
    choice = eng.ingest("cop", A)
    assert eng.plan("cop") == choice.plan
    x = np.random.default_rng(2).standard_normal(A.ncols)
    np.testing.assert_allclose(eng.spmv("cop", x), csr_to_dense(A) @ x,
                               atol=1e-6)
    # decisions are persisted as JSON and stats are serializable
    assert json.loads(eng.plans()["cop"])["ranking"]
    assert json.dumps(eng.stats())
    # explicit plan bypasses the autotuner but still serves correctly,
    # re-targeted to the engine's shard count (plan default is 8)
    eng.ingest("manual", A, plan=SpmvPlan(layout="cyclic"))
    assert eng.plan("manual").layout == "cyclic"
    assert eng.plan("manual").num_shards == 4
    np.testing.assert_allclose(eng.spmv("manual", x), csr_to_dense(A) @ x,
                               atol=1e-6)
