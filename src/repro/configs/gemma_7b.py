"""gemma-7b [dense] — arXiv:2403.08295 (hf).  GeGLU, head_dim=256, MHA
(kv == q heads on 7b; MQA is the 2b variant)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", num_layers=28, d_model=3072,
    num_heads=16, num_kv_heads=16, head_dim=256, d_ff=24576,
    vocab_size=256_000, activation="geglu", rope_theta=10_000.0,
    tie_embeddings=True)

def smoke_config():
    return ModelConfig(
        name="gemma-7b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=512, activation="geglu")
