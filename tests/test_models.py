"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness.  Also decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as mm
from repro.models import params as pp
from repro.models.config import SHAPES, shape_applicable


def smoke_batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.frontend == "encodec_stub":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                             cfg.vocab_size)}
    if cfg.frontend == "siglip_stub":
        P = cfg.prefix_len
        return {"image_embeds": jax.random.normal(key, (B, P, cfg.d_model),
                                                  jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
                "labels": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = pp.init_params(cfg, jax.random.PRNGKey(0))
        batch = smoke_batch(cfg)
        logits, aux = mm.forward(params, cfg, batch)
        S_out = 16 if cfg.frontend != "siglip_stub" else 16
        if cfg.num_codebooks > 1:
            assert logits.shape == (2, 16, cfg.num_codebooks, cfg.vocab_size)
        else:
            assert logits.shape == (2, S_out, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_decreases_loss(self, arch):
        """Two SGD-ish steps on one batch must reduce the loss."""
        cfg = get_smoke_config(arch)
        params = pp.init_params(cfg, jax.random.PRNGKey(0))
        batch = smoke_batch(cfg)
        lg = jax.jit(jax.value_and_grad(
            lambda p: mm.loss_fn(p, cfg, batch)[0]))
        l0, g = lg(params)
        # step in f32 with a small lr — bf16 params round off tiny steps,
        # which can flip the sign of the improvement on recurrent archs
        params2 = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - 0.1 * gg.astype(jnp.float32)).astype(p.dtype),
            params, g)
        l1, _ = lg(params2)
        assert bool(jnp.isfinite(l0)) and bool(jnp.isfinite(l1))
        assert float(l1) < float(l0) + 1e-4


@pytest.mark.parametrize("arch", ["qwen3_4b", "recurrentgemma_2b",
                                  "xlstm_1_3b", "deepseek_moe_16b"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits == teacher-forced forward logits position-wise."""
    import dataclasses
    cfg = get_smoke_config(arch)
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    if cfg.moe is not None:
        # decode==prefill only holds dropless: prefill routes all B*S tokens
        # through the capacity buffer at once while decode sees B per step,
        # so any capacity drop breaks position-wise equality by design; and
        # bf16 activations can flip a near-tied top-k expert choice between
        # the two paths, which is a discontinuity no tolerance covers.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        params = jax.tree.map(
            lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
            params)
    B, S = 2, 8
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = mm.forward(params, cfg, {"tokens": toks})

    caches = mm.init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        logits, caches = mm.decode_step(params, cfg, toks[:, t: t + 1],
                                        caches, jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.15, atol=0.15)


def test_grid_cells_count():
    """Assignment grid: 10 archs x 4 shapes = 40 cells; 8 documented skips."""
    from repro.configs.registry import grid_cells
    cells = grid_cells()
    assert len(cells) == 40
    skips = [(a, s) for a, s, ok in cells if not ok]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)


def test_param_counts_match_nameplates():
    expect = {"gemma_7b": (7, 10), "qwen25_32b": (30, 35),
              "command_r_plus_104b": (100, 112), "deepseek_moe_16b": (15, 18),
              "grok_1_314b": (300, 330), "xlstm_1_3b": (1.0, 1.5)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"
