"""``benchmarks.common.append_bench_entry``: the atomic-append contract.

The trajectory file is append-only state shared by every recorded bench
run; the invariants under test are (1) the write is temp-file +
``os.replace`` atomic — a crash mid-write can never truncate the existing
file, (2) corrupt existing files degrade to empty instead of blocking new
records, and (3) recording nothing is loudly fatal.
"""
import json
import os

import pytest

from benchmarks.common import append_bench_entry


def _read(path):
    with open(path) as f:
        return json.load(f)


def test_creates_file_and_appends(tmp_path):
    path = str(tmp_path / "bench.json")
    assert append_bench_entry({"workload": "a", "n": 1}, path) == path
    append_bench_entry({"workload": "b", "n": 2}, path)
    doc = _read(path)
    assert [e["workload"] for e in doc["entries"]] == ["a", "b"]


def test_preserves_existing_entries(tmp_path):
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        json.dump({"entries": [{"workload": "old"}]}, f)
    append_bench_entry({"workload": "new"}, path)
    assert [e["workload"] for e in _read(path)["entries"]] == ["old", "new"]


def test_corrupt_existing_file_starts_fresh(tmp_path):
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        f.write('{"entries": [{"worklo')      # truncated by a crash
    append_bench_entry({"workload": "recovered"}, path)
    assert [e["workload"] for e in _read(path)["entries"]] == ["recovered"]


def test_empty_entry_raises(tmp_path):
    with pytest.raises(ValueError, match="empty bench entry"):
        append_bench_entry({}, str(tmp_path / "bench.json"))


def test_crash_mid_write_never_truncates(tmp_path, monkeypatch):
    """A failure while serializing must leave the previous file intact —
    the whole point of writing to a temp file and ``os.replace``-ing."""
    path = str(tmp_path / "bench.json")
    append_bench_entry({"workload": "safe"}, path)
    before = _read(path)

    real_dump = json.dump

    def exploding_dump(obj, fp, **kw):
        fp.write('{"entries": [{"torn')       # partial bytes, then die
        raise OSError("disk full")

    monkeypatch.setattr(json, "dump", exploding_dump)
    with pytest.raises(OSError):
        append_bench_entry({"workload": "doomed"}, path)
    monkeypatch.setattr(json, "dump", real_dump)
    assert _read(path) == before              # original bytes untouched
    # and the helper still works afterwards
    append_bench_entry({"workload": "after"}, path)
    assert [e["workload"] for e in _read(path)["entries"]] == \
        ["safe", "after"]


def test_append_is_verified(tmp_path, monkeypatch):
    """The helper re-reads the file to prove the append landed."""
    path = str(tmp_path / "bench.json")
    real_replace = os.replace

    def dropping_replace(src, dst):
        os.remove(src)                        # "replace" that loses data

    monkeypatch.setattr(os, "replace", dropping_replace)
    with pytest.raises((RuntimeError, FileNotFoundError)):
        append_bench_entry({"workload": "lost"}, path)
    monkeypatch.setattr(os, "replace", real_replace)
