"""Pallas TPU kernel: nonzero-balanced segmented-sum SpMV.

The row-tiled ELL kernel (spmv_ell.py) inherits the paper's §IV-D failure
mode at tile granularity: a power-law row makes its tile's reduction width
explode while every other tile pads.  This kernel is the nonzero-split fix
(merge-path style, cf. Elafrou et al. / Merrill & Garland): the flat nnz
stream is cut into equal-size lane-aligned chunks — every grid step owns
exactly ``chunk`` non-zeros no matter how skewed the rows are — and the
kernel computes, per chunk, the products and their within-chunk inclusive
prefix sums:

    psum[c, l] = sum_{k <= l} vals[c, k] * x[cols[c, k]]

Row results are then assembled by the cross-chunk carry fix-up (a cheap
jit'd gather/scatter in ops.seg_spmv): each (chunk, row) *piece* contributes
``psum[c, hi] - psum[c, lo-1]`` to its row, so a row spanning many chunks
sums one carry per chunk and a chunk holding many short rows yields them
all from one scan.  The grid is therefore load-balance-aware rather than
shape-aware — the first kernel in this repo whose work distribution, not
its operand shape, defines the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["seg_psum"]


def _seg_kernel(vals_ref, cols_ref, x_ref, psum_ref):
    vals = vals_ref[...]                       # (TC, L)
    cols = cols_ref[...]                       # (TC, L)
    x = x_ref[...]                             # (N,) resident in VMEM
    prod = vals * jnp.take(x, cols, axis=0)    # VMEM dynamic gather
    psum_ref[...] = jnp.cumsum(prod, axis=1)   # within-chunk inclusive scan


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def seg_psum(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray,
             *, tile_c: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Per-chunk inclusive prefix sums of ``vals * x[cols]``.

    vals/cols: (C, L) nnz-stream slab with L % 128 == 0, C % 8 == 0.
    x: (N,) — fits VMEM alongside the tiles (the distributed layer shards
    x so each local slab sees only its gathered vector).
    Returns psum: (C, L) in x.dtype.
    """
    C, L = vals.shape
    tc = min(tile_c, C)
    if C % tc:
        raise ValueError(f"tile_c must divide chunk count: {C} vs {tc}")
    grid = (C // tc,)
    return pl.pallas_call(
        _seg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tc, L), lambda c: (c, 0)),           # vals tile
            pl.BlockSpec((tc, L), lambda c: (c, 0)),           # cols tile
            pl.BlockSpec((x.shape[0],), lambda c: (0,)),       # full x in VMEM
        ],
        out_specs=pl.BlockSpec((tc, L), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, L), x.dtype),
        interpret=interpret,
    )(vals, cols, x)
