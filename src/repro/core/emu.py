"""Discrete-tick model of the Emu Chick (paper §II + §IV-D dynamics).

This is the reproduction vehicle for the paper's *Emu-side* results: the
container has no Emu hardware, so we model the machine the paper describes —

* P nodelets, each with one single-issue Gossamer Core (1 instr/cycle,
  150 MHz) and up to 64 resident threads;
* thread migration on any remote load, ~2x the cost of a local access;
* a finite egress migration queue per nodelet, serviced by the Migration
  Engine at a fixed packet rate, with per-nodelet ingress acceptance;
* thread-activity throttling when the migration queue fills (the mechanism
  behind Fig. 8's nodelet-0 collapse).

Threads execute compressed *segment traces* (nodelet, n_instructions) built
from the same walk the migration accounting uses, so the simulator and the
counter agree by construction.  Outputs: per-tick residency traces
(Figs. 8/11), total runtime -> bandwidth (Figs. 3/6/10), and per-nodelet
instruction counts (Fig. 7).

Three engines implement the same machine, tick for tick:

* ``engine="vectorized"`` (the default) keeps all thread state in flat
  ``(nthreads,)`` / ``(P,)`` arrays plus flattened segment traces.  When a
  C toolchain is available it runs the whole tick loop in a tiny compiled
  kernel (``_emu_tick.c``, built on demand by :mod:`repro.core._emu_cext`);
  otherwise it runs the pure-numpy structure-of-arrays engine — no Python
  loop over threads, one short loop over nodelets per tick (the Migration
  Engine's sequential credit scan).  This is what lets the autotuner probe
  run at serving time and the Fig. 8/11 benchmarks run the full Table-I
  matrix sizes.
* ``engine="numpy"`` / ``engine="cext"`` force a specific vectorized
  backend (tests use these to pin both).
* :func:`simulate_reference` (``engine="reference"``) is the original
  per-thread Python loop, kept as the executable specification;
  ``tests/test_emu_vectorized.py`` pins exact equivalence (ticks,
  migrations, per-nodelet instruction counts, residency traces) across
  every engine.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .layout import VectorLayout
from .partition import Partition
from .sparse_matrix import CSRMatrix

__all__ = ["EmuConfig", "EmuResult", "build_thread_traces", "simulate",
           "simulate_reference", "run_spmv", "useful_bytes"]


def useful_bytes(csr: "CSRMatrix") -> float:
    """Bytes of useful work per SpMV: values + colIndex + x loads (8 B
    each) + rowPtr + b — the bandwidth denominator every Emu benchmark
    shares."""
    return 8.0 * (3 * csr.nnz + 2 * csr.nrows)

# Thread states
_RUNNING, _WANT, _QUEUED, _FLIGHT, _DONE = range(5)


@dataclasses.dataclass(frozen=True)
class EmuConfig:
    nodelets: int = 8
    threads_per_nodelet: int = 64
    clock_hz: float = 150e6
    tick_cycles: int = 250
    migration_queue_cap: int = 64      # egress packets per nodelet
    me_rate: int = 24                  # packets/tick a nodelet can send
    ingress_rate: int = 24             # NQM per-dest acceptance/tick
    resident_cap: int = 80             # register sets + run-queue contexts
    migration_latency_ticks: int = 1
    migration_overhead_cycles: int = 2  # ~2x a local access (paper §II-A)
    # A single-issue GC only reaches 1 instr/cycle when enough threads are
    # resident to hide DRAM latency; below this count throughput scales
    # linearly with active threads.  This is the mechanism that makes the
    # Fig. 8 throttling collapse hurt: a starved/throttled nodelet loses
    # issue bandwidth, not just queue slots.
    latency_hide_threads: int = 32
    # Cycles per memory instruction (narrow-channel DDR4 at a 150 MHz GC:
    # row activation + transfer amortize to ~8 GC cycles per 8-byte access).
    access_cycles: int = 8
    # Congestion collapse (paper §IV-D): thread contexts in a saturated
    # migration queue are staged in the nodelet's narrow-channel DRAM, so a
    # full queue steals memory bandwidth from the GC, the memory-side
    # processor *and* the NQM itself — service capacity drops with queue
    # occupancy instead of merely queueing.  ``congestion_floor`` is the
    # residual capacity at full saturation.  The paper observes exactly
    # this: "the nodelet reduces the number of threads that can be
    # executed" and fewer threads/nodelet relieve the pressure.
    congestion_floor: float = 0.3
    # Residency-trace budget: the sampling stride is derived so a run keeps
    # roughly this many (P,) samples instead of one per tick (full Table-I
    # matrices run for ~10^5-10^6 ticks; an unbounded trace is the old
    # out-of-memory failure mode).  <= 0 forces stride 1 (sample every
    # tick, the legacy behaviour).
    target_samples: int = 2048
    max_ticks: int = 2_000_000


@dataclasses.dataclass
class EmuResult:
    ticks: int
    seconds: float
    bandwidth_mbs: float
    migrations: int
    residency: np.ndarray        # (ticks_sampled, P)
    instr_per_nodelet: np.ndarray  # (P,)
    sample_every: int

    @property
    def instr_cv(self) -> float:
        """CV of per-nodelet instruction counts (the Fig. 7 balance metric).

        This was historically (mis)named ``residency_cv``; it has nothing
        to do with the residency trace.
        """
        m = self.instr_per_nodelet
        return float(m.std() / m.mean()) if m.mean() else 0.0

    @property
    def residency_cv(self) -> float:
        """CV of the *time-averaged per-nodelet thread residency*.

        Computed over the sampled residency trace: high values mean
        threads spent the run converged on few nodelets (the Fig. 8
        hot-spot signature), independent of how instructions balanced.
        """
        if self.residency.size == 0:
            return 0.0
        m = self.residency.astype(np.float64).mean(axis=0)
        return float(m.std() / m.mean()) if m.mean() else 0.0


#: Serialized carry fix-up instructions per spanned chunk boundary in the
#: seg/split home streams: each carry is a read-modify-write on the output
#: row that cannot overlap the scan (the §IV-D monster-row chain, seen by
#: the tick machine instead of only by the analytic slot model).
_KERNEL_CARRY_INSTR = 8
#: Scatter-add instructions per HYB overflow entry (indexed read-modify-
#: write on b, no scan amortization).
_KERNEL_OVF_INSTR = 4


def _home_row_weights(rows: np.ndarray, kernel: str | None) -> np.ndarray:
    """Per-row home-nodelet instruction counts for one shard's row slice.

    ``rows`` is the shard's per-row nnz vector; ``kernel`` selects the
    format's instruction stream.  ``None`` is the format-agnostic CSR walk
    (``2 + 2*nnz``) that every pre-oracle trace used — callers that do not
    pass ``shard_kernels`` get byte-identical traces.
    """
    rows = rows.astype(np.int64)
    if kernel is None:
        return 2 + 2 * rows
    if kernel == "ell":
        # Padded slab stream: every row walks the shard's widest row.
        W = int(rows.max()) if rows.size else 0
        return np.full(rows.shape, 2 + 2 * max(W, 1), dtype=np.int64)
    from ..kernels.ops import SEG_CHUNK
    if kernel == "seg":
        spans = -(-rows // SEG_CHUNK)
        carries = np.maximum(spans - 1, 0)
        return 2 + 3 * rows + _KERNEL_CARRY_INSTR * carries
    if kernel == "hyb":
        from .sparse_matrix import hyb_cap_width
        Wc = int(hyb_cap_width(rows)) if rows.size else 1
        ovf = np.maximum(rows - Wc, 0)
        return 2 + 2 * np.minimum(rows, Wc) + _KERNEL_OVF_INSTR * ovf
    if kernel == "split":
        from .plan import split_meta
        ns = split_meta(int(rows.sum()), int(rows.max()) if rows.size else 0)
        spans = -(-rows // SEG_CHUNK)
        carries = np.maximum(-(-spans // ns) - 1, 0)
        # Stage-2 combine reads ns partials back into each output row.
        return 2 + 3 * rows + _KERNEL_CARRY_INSTR * carries + ns
    if kernel == "tile":
        from .sparse_matrix import ELL_LANE, ELL_SUBLANE
        # Bitmask-tiled stream: one data load per walked cell and NO
        # per-element column-index loads (one block-col id serves a whole
        # (8, 128) tile), so a row costs half an ELL row of equal width.
        # Padding is *block-granular and block-local*: each 8-row block
        # walks ceil(widest row in the block / 128) lane tiles — a heavy
        # row widens its own block's walk, not the whole shard's (the
        # shard-wide max-width tax is ELL's, not tile's).  Dense-extent
        # approximation of the occupied-tile count; the analytic slot
        # model (plan.kernel_shard_costs) owns the scattered worst case.
        nb = rows.size
        pad = (-nb) % ELL_SUBLANE
        blk = np.pad(rows, (0, pad)).reshape(-1, ELL_SUBLANE)
        wb = np.maximum(-(-blk.max(axis=1) // ELL_LANE), 1)
        return 2 + np.repeat(wb * ELL_LANE, ELL_SUBLANE)[:nb]
    raise ValueError(f"unknown kernel format: {kernel!r}")


def build_thread_traces(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
                        threads_per_nodelet: int,
                        shard_kernels: Sequence[str] | None = None,
                        ) -> tuple[List[np.ndarray], List[np.ndarray], np.ndarray]:
    """Compressed (node, weight) segments per thread.

    Per row: the home nodelet executes 2 instrs/nnz (value+colIndex loads) +
    2 instrs (rowPtr read, b accumulate/remote-update issue); each x load is
    1 instr on the owner nodelet.  Consecutive same-node entries merge.

    ``shard_kernels`` (one format name per shard, as produced by
    ``SpmvPlan.resolved_shard_kernels()``) switches each shard's *home*
    stream to that format's instruction shape — ELL walks the padded slab
    width, seg adds the scan pass and the serialized cross-chunk carry
    fix-up, hyb caps the slab and scatter-adds the overflow, split cuts
    each carry chain by the policy split count and pays the stage-2
    combine, tile streams block-local dense tiles with no per-element
    index loads (:func:`_home_row_weights`).  The x-load stream (owner-side,
    1 instr each) is format-independent.  ``None`` keeps the historic
    format-agnostic walk, byte for byte.
    """
    P = part.num_shards
    thread_starts = part.thread_splits(csr, threads_per_nodelet)
    seg_nodes: List[np.ndarray] = []
    seg_weights: List[np.ndarray] = []
    homes = []
    owners_all = x_layout.owner_of(csr.col_index).astype(np.int32)
    rp = csr.row_ptr
    if shard_kernels is not None and len(shard_kernels) != P:
        raise ValueError(f"shard_kernels has {len(shard_kernels)} entries "
                         f"for {P} shards")
    home_w_all = np.empty(csr.nrows, dtype=np.int64)
    all_rows = np.diff(rp).astype(np.int64)
    for p in range(P):
        s0, s1 = int(part.starts[p]), int(part.starts[p + 1])
        kern = None if shard_kernels is None else shard_kernels[p]
        home_w_all[s0:s1] = _home_row_weights(all_rows[s0:s1], kern)
    for p in range(P):
        starts = thread_starts[p]
        for t in range(threads_per_nodelet):
            r0, r1 = int(starts[t]), int(starts[t + 1])
            homes.append(p)
            if r1 <= r0:
                seg_nodes.append(np.zeros(0, np.int32))
                seg_weights.append(np.zeros(0, np.int64))
                continue
            lo, hi = int(rp[r0]), int(rp[r1])
            k = hi - lo
            nrows = r1 - r0
            # Interleaved walk: home-entry at every row start, owner per nnz.
            seq = np.empty(k + nrows, dtype=np.int32)
            wts = np.empty(k + nrows, dtype=np.int64)
            home_pos = (rp[r0:r1] - lo + np.arange(nrows)).astype(np.int64)
            mask = np.zeros(k + nrows, dtype=bool)
            mask[home_pos] = True
            seq[mask] = p
            wts[mask] = home_w_all[r0:r1]      # format-shaped home stream
            seq[~mask] = owners_all[lo:hi]
            wts[~mask] = 1                      # the x load itself

            # Compress consecutive equal nodes.
            if seq.size:
                bound = np.empty(seq.size, dtype=bool)
                bound[0] = True
                bound[1:] = seq[1:] != seq[:-1]
                idx = np.flatnonzero(bound)
                nodes = seq[idx]
                csum = np.concatenate([[0], np.cumsum(wts)])
                ends = np.concatenate([idx[1:], [seq.size]])
                weights = csum[ends] - csum[idx]
            else:
                nodes = np.zeros(0, np.int32)
                weights = np.zeros(0, np.int64)
            seg_nodes.append(nodes)
            seg_weights.append(weights)
    return seg_nodes, seg_weights, np.asarray(homes, dtype=np.int32)


def _sample_stride(total_cycles: int, cfg: EmuConfig) -> int:
    """Residency-sampling stride shared by both engines.

    The true tick count is unknowable up front (congestion inflates it),
    so the stride targets ``cfg.target_samples`` rows against the
    *congestion-free lower bound* on ticks — total trace cycles spread
    over P nodelets at full issue rate.  Congestion then only inflates the
    stored trace by the (bounded) slowdown factor, instead of growing one
    row per tick up to ``max_ticks``.
    """
    if cfg.target_samples <= 0:
        return 1
    est_ticks = max(total_cycles // (cfg.nodelets * cfg.tick_cycles), 1)
    return max(1, est_ticks // cfg.target_samples)


def simulate(seg_nodes: Sequence[np.ndarray], seg_weights: Sequence[np.ndarray],
             homes: np.ndarray, cfg: EmuConfig, useful_bytes: float, *,
             engine: str = "vectorized") -> EmuResult:
    """Run the tick machine over compressed thread traces.

    ``engine="vectorized"`` (default) runs the structure-of-arrays engine,
    through the compiled tick kernel when a C toolchain is available and
    as pure numpy otherwise; ``engine="cext"`` / ``engine="numpy"`` force
    one backend (``cext`` raises if the kernel cannot be built);
    ``engine="reference"`` runs the legacy per-thread Python loop.  All
    engines produce identical results (see
    ``tests/test_emu_vectorized.py``); the reference engine is O(threads)
    Python work per tick and exists as the executable specification.
    """
    if engine in ("vectorized", "cext"):
        res = _simulate_cext(seg_nodes, seg_weights, homes, cfg,
                             useful_bytes)
        if res is not None:
            return res
        if engine == "cext":
            raise RuntimeError("the compiled Emu tick kernel is unavailable "
                               "(no C toolchain, or REPRO_EMU_DISABLE_CEXT "
                               "is set)")
        return _simulate_numpy(seg_nodes, seg_weights, homes, cfg,
                               useful_bytes)
    if engine == "numpy":
        return _simulate_numpy(seg_nodes, seg_weights, homes, cfg,
                               useful_bytes)
    if engine == "reference":
        return simulate_reference(seg_nodes, seg_weights, homes, cfg,
                                  useful_bytes)
    raise ValueError(f"unknown engine: {engine!r}; expected 'vectorized', "
                     f"'cext', 'numpy' or 'reference'")


def _flatten_state(seg_nodes: Sequence[np.ndarray],
                   seg_weights: Sequence[np.ndarray],
                   homes: np.ndarray, cfg: EmuConfig) -> dict:
    """Shared structure-of-arrays initial state for the fast engines.

    Flattens the per-thread segment lists into ``(total_segments,)`` node /
    cost arrays addressed by an absolute per-thread pointer, and applies
    the reference engine's initialization (empty threads are DONE, a
    remote first segment starts in WANT).
    """
    nthreads = len(seg_nodes)
    nseg = np.fromiter((s.size for s in seg_nodes), dtype=np.int64,
                       count=nthreads)
    seg_off = np.concatenate([[0], np.cumsum(nseg)]).astype(np.int64)
    if seg_off[-1]:
        flat_nodes = np.ascontiguousarray(
            np.concatenate(seg_nodes).astype(np.int64, copy=False))
        flat_cost = np.ascontiguousarray(
            np.concatenate(seg_weights).astype(np.int64) * cfg.access_cycles)
    else:
        flat_nodes = np.zeros(1, np.int64)
        flat_cost = np.zeros(1, np.int64)

    loc = np.asarray(homes, dtype=np.int64).copy()
    state = np.full(nthreads, _RUNNING, dtype=np.int8)
    ptr = seg_off[:-1].copy()              # absolute index into flat arrays
    seg_end = np.ascontiguousarray(seg_off[1:])
    rem = np.zeros(nthreads, dtype=np.int64)
    dest = np.full(nthreads, -1, dtype=np.int64)

    empty = nseg == 0
    state[empty] = _DONE
    ne = np.flatnonzero(~empty)
    if ne.size:
        rem[ne] = flat_cost[ptr[ne]]
        first = flat_nodes[ptr[ne]]
        away = first != loc[ne]
        # First segment is remote (possible under nnz distribution).
        state[ne[away]] = _WANT
        dest[ne[away]] = first[away]

    total_cycles = int(flat_cost.sum()) if seg_off[-1] else 0
    return dict(nthreads=nthreads, flat_nodes=flat_nodes,
                flat_cost=flat_cost, seg_end=seg_end, loc=loc, state=state,
                ptr=ptr, rem=rem, dest=dest, n_done=int(empty.sum()),
                sample_every=_sample_stride(total_cycles, cfg))


def _simulate_cext(seg_nodes: Sequence[np.ndarray],
                   seg_weights: Sequence[np.ndarray],
                   homes: np.ndarray, cfg: EmuConfig,
                   useful_bytes: float) -> EmuResult | None:
    """Run the compiled tick kernel; None when it cannot be built/loaded.

    The kernel advances the whole tick loop in C over the same flat state
    arrays the numpy engine uses; when the residency sample buffer fills
    (congestion can inflate the tick count well past the stride's
    estimate) it returns with all state written back, the buffer is grown,
    and the kernel resumes at the same tick.
    """
    from . import _emu_cext
    kernel = _emu_cext.load_kernel()
    if kernel is None:
        return None
    st = _flatten_state(seg_nodes, seg_weights, homes, cfg)
    nthreads = st["nthreads"]
    P = cfg.nodelets
    sample_every = st["sample_every"]
    arrive = np.full(nthreads, -1, dtype=np.int64)
    egress = np.zeros((P, cfg.migration_queue_cap), dtype=np.int64)
    qlen = np.zeros(P, dtype=np.int64)
    instr = np.zeros(P, dtype=np.int64)
    scratch_n = max(nthreads, 1)
    run_buf = np.empty(scratch_n, dtype=np.int64)
    run_cnt = np.empty(P, dtype=np.int64)
    run_off = np.empty(P + 1, dtype=np.int64)
    cur = np.empty(scratch_n, dtype=np.int64)
    alive = np.empty(scratch_n, dtype=np.int64)
    residents = np.empty(P, dtype=np.int64)
    credits = np.empty(P, dtype=np.int64)
    cong = np.empty(P, dtype=np.float64)
    res_cap = max(2 * cfg.target_samples, 1024)
    res_buf = np.zeros((res_cap, P), dtype=np.int32)
    res_len = np.zeros(1, dtype=np.int64)
    regs = np.zeros(4, dtype=np.int64)     # tick, rr, migrations, n_done
    regs[3] = st["n_done"]
    while True:
        paused = kernel(
            nthreads, P, cfg.threads_per_nodelet, cfg.tick_cycles,
            cfg.migration_queue_cap, cfg.me_rate, cfg.ingress_rate,
            cfg.resident_cap, cfg.migration_latency_ticks,
            cfg.migration_overhead_cycles, cfg.latency_hide_threads,
            cfg.congestion_floor, cfg.max_ticks, sample_every,
            st["flat_nodes"], st["flat_cost"], st["seg_end"],
            st["loc"], st["state"], st["ptr"], st["rem"], st["dest"],
            arrive, egress.reshape(-1), qlen, instr,
            run_buf, run_cnt, run_off, cur, alive, residents, credits,
            cong, res_buf.reshape(-1), res_cap, res_len,
            regs[0:1], regs[1:2], regs[2:3], regs[3:4])
        if not paused:
            break
        grown = np.zeros((2 * res_cap, P), dtype=np.int32)
        grown[:res_cap] = res_buf
        res_buf, res_cap = grown, 2 * res_cap
    tick = int(regs[0])
    seconds = tick * cfg.tick_cycles / cfg.clock_hz
    bw = useful_bytes / seconds / 1e6 if seconds > 0 else 0.0
    return EmuResult(ticks=tick, seconds=seconds, bandwidth_mbs=bw,
                     migrations=int(regs[2]),
                     residency=res_buf[:int(res_len[0])].copy(),
                     instr_per_nodelet=instr, sample_every=sample_every)


def _simulate_numpy(seg_nodes: Sequence[np.ndarray],
                    seg_weights: Sequence[np.ndarray],
                    homes: np.ndarray, cfg: EmuConfig,
                    useful_bytes: float) -> EmuResult:
    """Pure-numpy structure-of-arrays tick engine.

    All per-thread state lives in flat ``(nthreads,)`` arrays; the segment
    traces are flattened to ``(total_segments,)`` arrays indexed by an
    absolute per-thread pointer.  Each tick runs four phases as array ops:

    1. *Execute*: per-nodelet selection (throttle cap + round-robin
       rotation) scatters the selected threads into a dense
       ``(P, threads_per_nodelet)`` slot matrix in rotation order; the
       fair-share budget split then runs as short vectorized passes over
       that matrix across **all** nodelets at once (a pass is one round
       of the reference engine's inner ``while budget`` loop — the
       rotation-order rank is the row position, so the "first *budget*
       threads get one cycle" tail case is a single masked compare).
    2. *Enqueue*: WANT threads enter their nodelet's egress queue in
       thread-id order while slots remain (queues are plain per-nodelet
       id arrays in FIFO order).
    3. *Migration Engine*: queues are serviced in nodelet order against a
       shared per-destination credit vector — the one Python loop over
       nodelets per tick (the credit handoff is inherently sequential).
       Within a queue, the reference's FIFO-with-skip scan reduces to:
       the first ``credits[d]`` entries per destination are candidates,
       and the first ``rate_p`` candidates in queue order are sent.
    4. *Arrivals* pop the in-flight FIFO (everything sent at tick T lands
       at T + latency, so the FIFO is sorted by construction).
    """
    st = _flatten_state(seg_nodes, seg_weights, homes, cfg)
    nthreads = st["nthreads"]
    P = cfg.nodelets
    qcap = cfg.migration_queue_cap
    tpn = cfg.threads_per_nodelet
    W = max(tpn, 2)                        # slot width (throttle floor is 2)
    flat_nodes, flat_cost = st["flat_nodes"], st["flat_cost"]
    loc, state = st["loc"], st["state"]
    ptr, seg_end = st["ptr"], st["seg_end"]
    rem, dest = st["rem"], st["dest"]

    instr = np.zeros(P, dtype=np.int64)
    migrations = 0
    res_trace: list[np.ndarray] = []
    sample_every = st["sample_every"]
    rr = 0  # round-robin offset for fairness
    n_done = st["n_done"]

    # Egress queues: per-nodelet id arrays in FIFO order, occupancy mirror.
    EMPTY_Q = np.empty(0, dtype=np.int64)
    queues: list[np.ndarray] = [EMPTY_Q] * P
    occ = np.zeros(P, dtype=np.int64)
    total_q = 0
    # In-flight FIFO: (landing_tick, [id arrays]) appended once per tick.
    in_flight: list[tuple[int, list[np.ndarray]]] = []

    AR_P = np.arange(P, dtype=np.int64)
    AR_PC = AR_P[:, None]
    ARQ = np.arange(qcap, dtype=np.int64)
    CONG_IDLE = np.ones(P)
    CAP_IDLE = np.full(P, W, dtype=np.int64)    # max(2, tpn) when idle
    # Dense execution slots: (P, W) thread id / active / remaining-cycles.
    slot_id = np.empty((P, W), dtype=np.int64)
    slot_idf = slot_id.ravel()
    mig_cycles = cfg.migration_overhead_cycles
    latency = cfg.migration_latency_ticks

    tick = 0
    while tick < cfg.max_ticks and n_done < nthreads:
        # Congestion factor per nodelet from egress-queue occupancy.
        if total_q:
            t_frac = occ / qcap
            cong = 1.0 - (1.0 - cfg.congestion_floor) * t_frac
            # Throttle thread activity as the migration queue fills
            # (paper §IV-D: ~32 of 64 threads active on the hot nodelet).
            cap = np.maximum(2, (tpn * (1.0 - t_frac)).astype(np.int64))
        else:
            cong = CONG_IDLE
            cap = CAP_IDLE
        # --- 1. execute on each nodelet ---------------------------------
        run_mask = state == _RUNNING
        if run_mask.any():
            # Rank of each running thread within its nodelet (ascending
            # id): cumulative count along a (P, nthreads) membership map.
            member = (loc == AR_PC) & run_mask
            csum = member.cumsum(axis=1, dtype=np.int64)
            counts = csum[:, -1]
            rank = csum.reshape(-1).take(loc * nthreads +
                                         np.arange(nthreads)) - 1
            rot = (rank - rr) % np.maximum(counts, 1).take(loc)
            sel = run_mask & (rot < cap.take(loc))
            sel_ids = np.flatnonzero(sel)
            pos = loc.take(sel_ids) * W + rot.take(sel_ids)
            slot_idf.fill(-1)
            slot_idf[pos] = sel_ids
            active = slot_id >= 0
            activef = active.ravel()
            rem_b = np.zeros((P, W), dtype=np.int64)
            rem_bf = rem_b.ravel()
            rem_bf[pos] = rem.take(sel_ids)
            nsel = np.minimum(counts, cap)
            # Issue bandwidth degrades when too few threads hide latency,
            # and when the migration queue steals DRAM bandwidth.
            eff = np.minimum(1.0, nsel / cfg.latency_hide_threads) * cong
            budget = (cfg.tick_cycles * eff).astype(np.int64)
            # Fair-share passes: every nodelet's threads split its budget
            # until budgets or work run out (one pass == one round of the
            # reference engine's inner loop, all nodelets at once).
            while True:
                n_act = active.sum(axis=1)
                if not ((budget > 0) & (n_act > 0)).any():
                    break
                share = np.maximum(budget // np.maximum(n_act, 1), 1)
                take = np.minimum(share[:, None], rem_b)
                # Budget below the thread count: share is 1 and only the
                # first ``budget`` threads in rotation order get a cycle.
                lowb = budget < n_act
                if lowb.any():
                    rank_b = active.cumsum(axis=1, dtype=np.int64)
                    low_take = (rank_b <= budget[:, None]) & active
                    take = np.where(lowb[:, None], low_take, take)
                spent = take.sum(axis=1)
                instr += spent
                budget -= spent
                rem_b -= take
                fin = active & (rem_b == 0)
                if fin.any():
                    # Segment finished: advance to the next one.
                    posf = np.flatnonzero(fin.ravel())
                    ft = slot_idf.take(posf)
                    nptr = ptr.take(ft) + 1
                    ptr[ft] = nptr
                    over = nptr >= seg_end.take(ft)
                    done_ids = ft[over]
                    if done_ids.size:
                        state[done_ids] = _DONE
                        n_done += done_ids.size
                        activef[posf[over]] = False
                    cont = ft[~over]
                    if cont.size:
                        cpos = posf[~over]
                        ncost = flat_cost.take(nptr[~over])
                        nxt = flat_nodes.take(nptr[~over])
                        away = nxt != loc.take(cont)
                        aw = cont[away]
                        if aw.size:
                            state[aw] = _WANT
                            dest[aw] = nxt[away]
                            rem[aw] = ncost[away]
                            activef[cpos[away]] = False
                        rem_bf[cpos] = np.where(away, 0, ncost)
            # Write the partial segment progress back to the master state.
            aidx = np.flatnonzero(activef)
            if aidx.size:
                rem[slot_idf.take(aidx)] = rem_bf.take(aidx)
        rr += 1

        # --- 2. migration requests -> egress queues ----------------------
        want_ids = np.flatnonzero(state == _WANT)
        if want_ids.size:
            wloc = loc.take(want_ids)
            if int(wloc.max()) == int(wloc.min()):
                groups = [(int(wloc[0]), want_ids)]
            else:
                worder = np.argsort(wloc, kind="stable")
                ws = want_ids.take(worder)
                wcnt = np.bincount(wloc, minlength=P)
                woff = np.concatenate([[0], np.cumsum(wcnt)])
                groups = [(p, ws[woff[p]: woff[p + 1]])
                          for p in np.flatnonzero(wcnt)]
            for p, grp in groups:
                room = qcap - int(occ[p])
                if room <= 0:
                    continue
                acc = grp[:room]
                queues[p] = np.concatenate([queues[p], acc]) \
                    if queues[p].size else acc
                occ[p] += acc.size
                total_q += acc.size
                state[acc] = _QUEUED
        # --- 3. Migration Engine service with destination backpressure ---
        # Egress service degrades with the source's congestion; a packet is
        # accepted only while the destination has run-queue slots left, so a
        # hot nodelet's overflow backs up into every parent's egress queue
        # (the paper's Fig. 8 pile-up on the non-hot nodelets).
        if total_q:
            on_node = (state != _FLIGHT) & (state != _DONE)
            residents = np.bincount(loc[on_node], minlength=P)
            # Floor of 1 credit: a full nodelet still trickle-accepts, which
            # is both what the hardware does and the anti-deadlock guarantee.
            credits = np.maximum(
                np.minimum(cfg.ingress_rate, cfg.resident_cap - residents), 1)
            sent_this_tick: list[np.ndarray] = []
            for p in range(P):
                k = int(occ[p])
                if k == 0:
                    continue
                seg = queues[p]                        # FIFO order
                d = dest.take(seg)
                rate_p = max(int(cfg.me_rate * cong[p]), 1)
                if k <= rate_p and k <= int(credits.min()):
                    # Uncontended: every packet is sent.
                    sent = seg
                    queues[p] = EMPTY_Q
                    credits -= np.bincount(d, minlength=P)
                else:
                    # FIFO-with-skip == first credits[d] entries per dest
                    # are candidates; first rate_p candidates are sent.
                    oh = d[:, None] == AR_P
                    drank = oh.cumsum(axis=0).reshape(-1).take(
                        ARQ[:k] * P + d) - 1
                    cand = drank < credits.take(d)
                    sent_m = cand & (np.cumsum(cand) <= rate_p)
                    nsent = int(sent_m.sum())
                    if nsent == 0:
                        continue
                    if nsent == k:
                        sent = seg
                        queues[p] = EMPTY_Q
                    else:
                        sent = seg[sent_m]
                        queues[p] = seg[~sent_m]
                    credits -= np.bincount(d[sent_m], minlength=P)
                state[sent] = _FLIGHT
                occ[p] -= sent.size
                total_q -= sent.size
                migrations += sent.size
                instr[p] += sent.size * mig_cycles
                sent_this_tick.append(sent)
            if sent_this_tick:
                in_flight.append((tick + latency, sent_this_tick))
        # --- 4. arrivals --------------------------------------------------
        while in_flight and in_flight[0][0] <= tick:
            _, chunks = in_flight.pop(0)
            land = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
            loc[land] = dest.take(land)
            state[land] = _RUNNING

        # --- residency sample (threads on nodelet: running/waiting/queued) -
        if tick % sample_every == 0:
            live = (state != _FLIGHT) & (state != _DONE)
            res_trace.append(
                np.bincount(loc[live], minlength=P).astype(np.int32))
        tick += 1

    seconds = tick * cfg.tick_cycles / cfg.clock_hz
    bw = useful_bytes / seconds / 1e6 if seconds > 0 else 0.0
    return EmuResult(ticks=tick, seconds=seconds, bandwidth_mbs=bw,
                     migrations=migrations,
                     residency=np.asarray(res_trace), instr_per_nodelet=instr,
                     sample_every=sample_every)


def simulate_reference(seg_nodes: Sequence[np.ndarray],
                       seg_weights: Sequence[np.ndarray],
                       homes: np.ndarray, cfg: EmuConfig,
                       useful_bytes: float) -> EmuResult:
    """Per-thread Python-loop engine: the executable specification.

    O(threads) Python work per tick — orders of magnitude slower than the
    vectorized engine, but trivially auditable against the paper's §II /
    §IV-D machine description.  Kept so the equivalence suite can pin the
    vectorized engine tick-for-tick.
    """
    nthreads = len(seg_nodes)
    P = cfg.nodelets
    loc = homes.copy()
    state = np.full(nthreads, _RUNNING, dtype=np.int8)
    ptr = np.zeros(nthreads, dtype=np.int64)
    rem = np.zeros(nthreads, dtype=np.int64)
    dest = np.full(nthreads, -1, dtype=np.int32)
    arrive = np.full(nthreads, -1, dtype=np.int64)
    nseg = np.array([s.size for s in seg_nodes], dtype=np.int64)
    for t in range(nthreads):
        if nseg[t] == 0:
            state[t] = _DONE
        else:
            rem[t] = seg_weights[t][0] * cfg.access_cycles
            if seg_nodes[t][0] != homes[t]:
                # First segment is remote (possible under nnz distribution).
                state[t] = _WANT
                dest[t] = seg_nodes[t][0]
            else:
                loc[t] = seg_nodes[t][0]

    egress: list[list[int]] = [[] for _ in range(P)]
    instr = np.zeros(P, dtype=np.int64)
    migrations = 0
    res_trace = []
    total_cycles = sum(int(w.sum()) for w in seg_weights) * cfg.access_cycles
    sample_every = _sample_stride(total_cycles, cfg)
    rr = 0  # round-robin offset for fairness

    def advance(t: int) -> None:
        """Thread t finished its segment; set up the next one."""
        nonlocal migrations
        ptr[t] += 1
        if ptr[t] >= nseg[t]:
            state[t] = _DONE
            return
        rem[t] = seg_weights[t][ptr[t]] * cfg.access_cycles
        nxt = seg_nodes[t][ptr[t]]
        if nxt != loc[t]:
            state[t] = _WANT
            dest[t] = nxt
        # else: stays RUNNING on the same nodelet

    tick = 0
    while tick < cfg.max_ticks:
        if not (state != _DONE).any():
            break
        # Congestion factor per nodelet from egress-queue occupancy.
        cong = np.array([1.0 - (1.0 - cfg.congestion_floor) *
                         (len(egress[p]) / cfg.migration_queue_cap)
                         for p in range(P)])
        # --- 1. execute on each nodelet ---------------------------------
        for p in range(P):
            running = np.flatnonzero((state == _RUNNING) & (loc == p))
            if running.size == 0:
                continue
            occ = len(egress[p])
            # Throttle thread activity as the migration queue fills
            # (paper §IV-D: ~32 of 64 threads active on the hot nodelet).
            cap = max(2, int(cfg.threads_per_nodelet *
                             (1.0 - occ / cfg.migration_queue_cap)))
            running = np.roll(running, -rr)[:cap]
            # Issue bandwidth degrades when too few threads hide latency,
            # and when the migration queue steals DRAM bandwidth.
            eff = min(1.0, running.size / cfg.latency_hide_threads) * cong[p]
            budget = int(cfg.tick_cycles * eff)
            # Fair-share pass: threads cycle until budget or work runs out.
            while budget > 0 and running.size:
                share = max(budget // running.size, 1)
                alive = []
                for t in running:
                    if budget <= 0:
                        break
                    take = min(share, int(rem[t]), budget)
                    rem[t] -= take
                    budget -= take
                    instr[p] += take
                    if rem[t] == 0:
                        advance(int(t))
                    if state[t] == _RUNNING and loc[t] == p:
                        alive.append(t)
                running = np.asarray(alive, dtype=np.int64)
        rr += 1

        # --- 2. migration requests -> egress queues ----------------------
        want = np.flatnonzero(state == _WANT)
        for t in want:
            p = int(loc[t])
            if len(egress[p]) < cfg.migration_queue_cap:
                egress[p].append(int(t))
                state[t] = _QUEUED
        # --- 3. Migration Engine service with destination backpressure ---
        # Egress service degrades with the source's congestion; a packet is
        # accepted only while the destination has run-queue slots left, so a
        # hot nodelet's overflow backs up into every parent's egress queue
        # (the paper's Fig. 8 pile-up on the non-hot nodelets).
        residents = np.zeros(P, dtype=np.int64)
        on_node = (state != _FLIGHT) & (state != _DONE)
        np.add.at(residents, loc[on_node], 1)
        # Floor of 1 credit: a full nodelet still trickle-accepts, which is
        # both what the hardware does and the anti-deadlock guarantee.
        credits = np.maximum(
            np.minimum(cfg.ingress_rate, cfg.resident_cap - residents), 1)
        for p in range(P):
            q = egress[p]
            rate_p = max(int(cfg.me_rate * cong[p]), 1)
            sent, kept = 0, []
            for t in q:
                d = int(dest[t])
                if sent < rate_p and credits[d] > 0:
                    credits[d] -= 1
                    sent += 1
                    state[t] = _FLIGHT
                    arrive[t] = tick + cfg.migration_latency_ticks
                    migrations += 1
                    instr[p] += cfg.migration_overhead_cycles
                else:
                    kept.append(t)
            egress[p] = kept
        # --- 4. arrivals --------------------------------------------------
        landing = np.flatnonzero((state == _FLIGHT) & (arrive <= tick))
        for t in landing:
            loc[t] = dest[t]
            dest[t] = -1
            state[t] = _RUNNING

        # --- residency sample (threads on nodelet: running/waiting/queued) -
        if tick % sample_every == 0:
            counts = np.zeros(P, dtype=np.int32)
            on_node = state != _FLIGHT
            live = on_node & (state != _DONE)
            np.add.at(counts, loc[live], 1)
            res_trace.append(counts)
        tick += 1

    seconds = tick * cfg.tick_cycles / cfg.clock_hz
    bw = useful_bytes / seconds / 1e6 if seconds > 0 else 0.0
    return EmuResult(ticks=tick, seconds=seconds, bandwidth_mbs=bw,
                     migrations=migrations,
                     residency=np.asarray(res_trace), instr_per_nodelet=instr,
                     sample_every=sample_every)


def run_spmv(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
             cfg: EmuConfig | None = None, *,
             engine: str = "vectorized",
             shard_kernels: Sequence[str] | None = None) -> EmuResult:
    """End-to-end: build traces for (matrix, partition, layout) and simulate.

    ``shard_kernels`` forwards to :func:`build_thread_traces` so a probe
    can replay the *format-shaped* instruction streams of a lowered
    per-shard program instead of the format-agnostic CSR walk.
    """
    cfg = cfg or EmuConfig(nodelets=part.num_shards)
    nodes, weights, homes = build_thread_traces(csr, part, x_layout,
                                                cfg.threads_per_nodelet,
                                                shard_kernels=shard_kernels)
    return simulate(nodes, weights, homes, cfg, useful_bytes(csr),
                    engine=engine)
