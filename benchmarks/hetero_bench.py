"""Heterogeneous-program benchmarks: per-shard kernel selection vs the
best uniform/non-split alternative, on two workloads.

``--workload mixed`` (default): ``data.matrices.mixed_structure`` — a
dense FEM-style band (regular ~lane-width rows, ELL-friendly) glued to a
short-row scattered sparse block with zipf row lengths (webbase-like,
where the 128-lane ELL/HYB slab floor wastes >90% of its slots and the
nonzero-balanced segmented format wins) — so under a contiguous row
partition the two regimes land on *different shards*.  One global
(kernel) choice must either pay the lane floor on the sparse shards
(ell/hyb) or pay scan/scatter overhead on the regular band (seg); the
per-shard autotuner pays ``sum_p min_k`` instead of ``min_k sum_p``.

``--workload pipeline``: ``data.matrices.halo_spikes`` — broad-reader
rows over a tight local band, the exchange-bound regime.  The headline
is the modeled **device-path** (SPMD) latency of the pre-pipeline serial
schedule vs the pipelined one (:func:`repro.core.plan.device_path_model`
over the full ranking, best-achievable vs best-achievable); the
acceptance gate is >= 1.15x on the full run, recorded via ``perf_probe
--pipeline``.  With enough visible devices the two schedules are also
run through the real shard_map executor and checked bitwise-equal.

``--workload blocked``: ``data.matrices.blocked_band`` — (8, 128)-aligned
dense tiles along a band (1-4 tiles per 8-row block, so ELL pays the
shard-wide max width on every row and seg pays scan bookkeeping on
perfectly regular rows) glued to a short-row scattered block where a
stray nonzero would drag a whole 1024-cell tile in.  The headline is the
kernel-slot term of the best **tile**-using per-shard program vs the best
program whose kernels avoid ``tile`` entirely — the acceptance gate is
>= 1.2x on the full run, recorded via ``perf_probe --tile``.

``--workload powerlaw_tail``: ``data.matrices.powerlaw_tail`` — a
handful of fully-dense *monster rows* over a uniform short-row
background (the paper's §IV-D hot-spot distilled).  A nonzero-balanced
partition hands a shard a couple of monster rows; the seg carry chain
then serializes one carry per chunk of the longest row, and the
split-nnz two-stage ``split`` family is the cure.  The headline is the
kernel-slot term of the best split-using program vs the best *non-split*
program (autotuned over the same grid minus ``split``) — the acceptance
gate is >= 1.1x on the full run.

Reported (and recorded in ``BENCH_emu.json`` via ``perf_probe --hetero``
/ ``perf_probe --split``):

* modeled total cycles of the best baseline candidate vs the best
  per-shard (mixed) / split-using (powerlaw_tail) candidate;
* the kernel-execution-slot term alone (the axis the per-shard choice
  actually moves);
* host wall-clock per served SpMV for both lowered programs through the
  numpy executor backend, for reference;
* an oracle check: both programs reproduce ``csr_matvec``.

Usage::

    PYTHONPATH=src python -m benchmarks.hetero_bench              # full
    PYTHONPATH=src python -m benchmarks.hetero_bench --fast \\
        --budget-seconds 120                                      # CI smoke
    PYTHONPATH=src python -m benchmarks.hetero_bench \\
        --workload powerlaw_tail --fast --budget-seconds 120      # CI split
    PYTHONPATH=src python -m benchmarks.perf_probe --hetero       # + record
    PYTHONPATH=src python -m benchmarks.perf_probe --split        # + record
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.partition import make_partition
from repro.core.plan import DEFAULT_PROBE, autotune, device_path_model
from repro.core.program import execute, lower
from repro.core.reorder import reordering_permutation
from repro.core.sparse_matrix import csr_matvec
from repro.data.matrices import blocked_band, halo_spikes, mixed_structure, \
    powerlaw_tail


def _plan_str(p) -> str:
    ex = p.exchange if p.shard_exchanges is None else \
        f"[{'+'.join(p.shard_exchanges)}]"
    s = f"{p.reordering}/{p.layout}/{p.distribution}/{ex}"
    if p.shard_kernels is not None:
        return f"{s}/[{'+'.join(p.shard_kernels)}]"
    return f"{s}/{p.kernel}"


def _host_us_per_spmv(prog, x, repeats: int = 10) -> float:
    """Median-of-repeats wall clock of the serving (numpy) executor."""
    execute(prog, x)                      # warm-up
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        execute(prog, x)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def run_hetero_bench(*, M: int = 4096, nnz_per_row: int = 33,
                     shards: int = 8, probe: int | str | None = None,
                     seed: int = 0, fast: bool = False) -> dict:
    """Run the mixed-structure scenario; returns the headline dict.

    ``probe=None`` defaults to :data:`repro.core.plan.DEFAULT_PROBE`.
    The recorded full run (``perf_probe --hetero``) passes
    ``probe="auto"``: the structure-preserving bases this matrix rewards
    rank poorly on the analytic issue term (the dense band is
    locality-rich but load-imbalanced), so the analytic-vs-measured
    inversion rate stays unstable and adaptive probing keeps spending
    probes until those bases are measured — no fixed full-grid budget
    required.
    """
    probe = DEFAULT_PROBE if probe is None else probe
    if fast:
        M, shards = 1024, 4
    A = mixed_structure(M, M * nnz_per_row, seed=seed)
    choice = autotune(A, num_shards=shards, seed=seed, probe=probe)
    # The ranking is probe-aware (measured bases first), so "best" is the
    # first candidate of each class in ranking order — not min by the
    # analytic total, which would compare across unprobed bases.
    uniform = [r for r in choice.ranking if r.plan.shard_kernels is None]
    hetero = [r for r in choice.ranking if r.plan.shard_kernels is not None]
    best_uni = uniform[0]
    best_het = hetero[0] if hetero else None

    entry = {
        "workload": "hetero/mixed_structure", "M": A.nrows, "nnz": A.nnz,
        "shards": shards, "probe": probe,
        "chosen_plan": _plan_str(choice.plan),
        "chosen_is_per_shard": choice.plan.shard_kernels is not None,
        "best_global_plan": _plan_str(best_uni.plan),
        "per_shard_plan": None if best_het is None else
        _plan_str(best_het.plan),
        "shard_kernels": None if best_het is None else
        list(best_het.plan.shard_kernels),
    }
    if best_het is None:
        entry["model_total_cycles"] = {
            "best_global": round(best_uni.cost.total, 1),
            "per_shard": None, "speedup": 0.0}
        entry["oracle_ok"] = False
        return entry

    entry["model_total_cycles"] = {
        "best_global": round(best_uni.cost.total, 1),
        "per_shard": round(best_het.cost.total, 1),
        "speedup": round(best_uni.cost.total /
                         max(best_het.cost.total, 1e-12), 3)}
    entry["model_kernel_cycles"] = {
        "best_global": round(best_uni.cost.padding_cycles, 1),
        "per_shard": round(best_het.cost.padding_cycles, 1),
        "speedup": round(best_uni.cost.padding_cycles /
                         max(best_het.cost.padding_cycles, 1e-12), 3)}

    prog_uni = lower(A, best_uni.plan)
    prog_het = lower(A, best_het.plan)
    x = np.random.default_rng(seed).standard_normal(A.ncols)
    ref = csr_matvec(A, x)
    entry["oracle_ok"] = bool(
        np.allclose(execute(prog_uni, x), ref, atol=1e-4, rtol=1e-5) and
        np.allclose(execute(prog_het, x), ref, atol=1e-4, rtol=1e-5))
    entry["host_us_per_spmv"] = {
        "best_global": round(_host_us_per_spmv(prog_uni, x), 1),
        "per_shard": round(_host_us_per_spmv(prog_het, x), 1)}
    return entry


def check(entry: dict) -> bool:
    """Acceptance gates CI smoke-tests: the autotuner's winner is a
    genuinely heterogeneous per-shard program, it strictly beats the best
    global (uniform-kernel) plan on the analytic model, and both programs
    reproduce the exact oracle."""
    return (entry.get("shard_kernels") is not None and
            len(set(entry["shard_kernels"])) > 1 and
            entry["chosen_is_per_shard"] and
            entry["model_total_cycles"]["speedup"] > 1.0 and
            entry["oracle_ok"])


def _plan_kernels(plan, shards: int) -> tuple:
    return plan.shard_kernels if plan.shard_kernels is not None \
        else (plan.kernel,) * shards


def run_split_bench(*, M: int = 8192, shards: int = 8, n_monster: int = 8,
                    probe: int | str | None = None, seed: int = 0,
                    fast: bool = False) -> dict:
    """Run the power-law-tail (monster-row) scenario.

    Autotunes the full kernel grid and, on the *same* ranking, compares
    the best split-using candidate against the best candidate whose
    kernels avoid ``split`` entirely, on the kernel-slot term (the axis
    the split family moves; the shared Emu-visible terms cancel).  Full
    scale puts a 16-chunk carry chain on each monster row (M=8192 dense
    rows over 512-element chunks); ``fast`` shrinks to a 4-chunk span —
    still split-selectable, smaller margin.
    """
    probe = DEFAULT_PROBE if probe is None else probe
    if fast:
        M, shards, n_monster = 2048, 4, 4
    A = powerlaw_tail(M, 2 * n_monster * M, n_monster=n_monster, seed=seed)
    choice = autotune(A, num_shards=shards, seed=seed, probe=probe)

    with_split = [r for r in choice.ranking
                  if "split" in _plan_kernels(r.plan, shards)]
    no_split = [r for r in choice.ranking
                if "split" not in _plan_kernels(r.plan, shards)]
    best_split = min(with_split, key=lambda r: r.cost.padding_cycles) \
        if with_split else None
    best_ns = min(no_split, key=lambda r: r.cost.padding_cycles)

    entry = {
        "workload": "split/powerlaw_tail", "M": A.nrows, "nnz": A.nnz,
        "shards": shards, "probe": probe, "n_monster": n_monster,
        "chosen_plan": _plan_str(choice.plan),
        "split_in_winner":
            "split" in _plan_kernels(choice.plan, shards),
        "best_nonsplit_plan": _plan_str(best_ns.plan),
        "split_plan": None if best_split is None else
        _plan_str(best_split.plan),
        "split_kernels": None if best_split is None else
        list(_plan_kernels(best_split.plan, shards)),
    }
    if best_split is None:
        entry["model_kernel_cycles"] = {
            "best_nonsplit": round(best_ns.cost.padding_cycles, 1),
            "split": None, "speedup": 0.0}
        entry["oracle_ok"] = False
        return entry

    entry["model_kernel_cycles"] = {
        "best_nonsplit": round(best_ns.cost.padding_cycles, 1),
        "split": round(best_split.cost.padding_cycles, 1),
        "speedup": round(best_ns.cost.padding_cycles /
                         max(best_split.cost.padding_cycles, 1e-12), 3)}
    entry["model_total_cycles"] = {
        "best_nonsplit": round(best_ns.cost.total, 1),
        "split": round(best_split.cost.total, 1),
        "speedup": round(best_ns.cost.total /
                         max(best_split.cost.total, 1e-12), 3)}

    prog_ns = lower(A, best_ns.plan)
    prog_spl = lower(A, best_split.plan)
    entry["split_counts"] = [
        st.split.num_splits if st.split is not None else 1
        for st in prog_spl.stages]
    x = np.random.default_rng(seed).standard_normal(A.ncols)
    ref = csr_matvec(A, x)
    entry["oracle_ok"] = bool(
        np.allclose(execute(prog_ns, x), ref, atol=1e-4, rtol=1e-5) and
        np.allclose(execute(prog_spl, x), ref, atol=1e-4, rtol=1e-5))
    entry["host_us_per_spmv"] = {
        "best_nonsplit": round(_host_us_per_spmv(prog_ns, x), 1),
        "split": round(_host_us_per_spmv(prog_spl, x), 1)}
    return entry


def check_split(entry: dict, *, fast: bool = False) -> bool:
    """Acceptance gates for the powerlaw_tail workload: the autotuner
    reaches ``split`` on its own, the best split-using program beats the
    best non-split one on the kernel-slot term (>= 1.1x on the recorded
    full run; a strict win suffices at CI-smoke scale, where the carry
    chain is only 4 chunks), and both programs reproduce the oracle."""
    bar = 1.0 if fast else 1.1
    mk = entry.get("model_kernel_cycles", {})
    return (entry.get("split_in_winner", False) and
            mk.get("split") is not None and
            (mk["speedup"] > bar if fast else mk["speedup"] >= bar) and
            entry.get("oracle_ok", False))


def run_tile_bench(*, M: int = 2048, nnz_per_row: int = 215,
                   shards: int = 8, probe: int | str | None = None,
                   seed: int = 0, fast: bool = False) -> dict:
    """Run the blocked-band (bitmask-tiled) scenario.

    Autotunes the full kernel grid and, on the *same* ranking, compares
    the best tile-using candidate against the best candidate whose
    kernels avoid ``tile`` entirely, on the kernel-slot term (the axis
    the tiled format moves; the shared Emu-visible terms cancel).
    ``nnz_per_row`` ~215 makes the dense band span about half the rows
    (the generator sizes the band from the nnz budget: ~2.5 fully dense
    (8, 128) tiles per 8-row block), so under a contiguous partition the
    banded and scattered regimes land on different shards and the winner
    is a mixed tile/scalar program.
    """
    probe = DEFAULT_PROBE if probe is None else probe
    if fast:
        M, shards = 512, 4
    A = blocked_band(M, M * nnz_per_row, seed=seed)
    choice = autotune(A, num_shards=shards, seed=seed, probe=probe)

    with_tile = [r for r in choice.ranking
                 if "tile" in _plan_kernels(r.plan, shards)]
    no_tile = [r for r in choice.ranking
               if "tile" not in _plan_kernels(r.plan, shards)]
    best_tile = min(with_tile, key=lambda r: r.cost.padding_cycles) \
        if with_tile else None
    best_nt = min(no_tile, key=lambda r: r.cost.padding_cycles)

    entry = {
        "workload": "tile/blocked_band", "M": A.nrows, "nnz": A.nnz,
        "shards": shards, "probe": probe,
        "chosen_plan": _plan_str(choice.plan),
        "tile_in_winner": "tile" in _plan_kernels(choice.plan, shards),
        "best_nontile_plan": _plan_str(best_nt.plan),
        "tile_plan": None if best_tile is None else _plan_str(best_tile.plan),
        "tile_kernels": None if best_tile is None else
        list(_plan_kernels(best_tile.plan, shards)),
    }
    if best_tile is None:
        entry["model_kernel_cycles"] = {
            "best_nontile": round(best_nt.cost.padding_cycles, 1),
            "tile": None, "speedup": 0.0}
        entry["oracle_ok"] = False
        return entry

    entry["model_kernel_cycles"] = {
        "best_nontile": round(best_nt.cost.padding_cycles, 1),
        "tile": round(best_tile.cost.padding_cycles, 1),
        "speedup": round(best_nt.cost.padding_cycles /
                         max(best_tile.cost.padding_cycles, 1e-12), 3)}
    entry["model_total_cycles"] = {
        "best_nontile": round(best_nt.cost.total, 1),
        "tile": round(best_tile.cost.total, 1),
        "speedup": round(best_nt.cost.total /
                         max(best_tile.cost.total, 1e-12), 3)}

    prog_nt = lower(A, best_nt.plan)
    prog_tile = lower(A, best_tile.plan)
    entry["tile_counts"] = [
        st.tile.num_tiles if st.tile is not None else 0
        for st in prog_tile.stages]
    x = np.random.default_rng(seed).standard_normal(A.ncols)
    ref = csr_matvec(A, x)
    entry["oracle_ok"] = bool(
        np.allclose(execute(prog_nt, x), ref, atol=1e-4, rtol=1e-5) and
        np.allclose(execute(prog_tile, x), ref, atol=1e-4, rtol=1e-5))
    entry["host_us_per_spmv"] = {
        "best_nontile": round(_host_us_per_spmv(prog_nt, x), 1),
        "tile": round(_host_us_per_spmv(prog_tile, x), 1)}
    return entry


def check_tile(entry: dict, *, fast: bool = False) -> bool:
    """Acceptance gates for the blocked workload: the autotuner's own
    grid reaches ``tile`` (the tile candidate is ranked, not forced),
    the best tile-using program beats the best tile-free one on the
    kernel-slot term (>= 1.2x on the recorded full run; a strict win
    suffices at CI-smoke scale), and both programs reproduce the
    oracle.  The *overall* winner is not required to use tile: the
    Emu-probed ranking may prefer a random-reordering base — which
    destroys the block structure tile feeds on — for migration-balance
    reasons the kernel-slot axis cannot see."""
    bar = 1.0 if fast else 1.2
    mk = entry.get("model_kernel_cycles", {})
    return (entry.get("tile_kernels") is not None and
            "tile" in entry["tile_kernels"] and
            mk.get("tile") is not None and
            (mk["speedup"] > bar if fast else mk["speedup"] >= bar) and
            entry.get("oracle_ok", False))


def run_pipeline_bench(*, M: int = 8192, nnz_per_row: int = 8,
                       shards: int = 8, seed: int = 0,
                       fast: bool = False) -> dict:
    """Run the exchange-bound pipelining scenario on ``halo_spikes``.

    The headline is the modeled **device-path** (SPMD shard_map) latency:
    serial schedule (exchange completes before any kernel work, the
    pre-pipeline executor) vs the pipelined schedule (all-local rows run
    while the collective is in flight) — :func:`device_path_model` over
    the full autotune ranking, best-achievable vs best-achievable, so a
    plan change cannot manufacture the win.  ``halo_spikes`` puts a few
    broad-reader rows on every shard over a tight local band: each
    shard's unique remote-column set is large (the exchange term rivals
    the kernel term) while most rows stay local (there is work to hide
    the exchange behind).

    When enough devices are visible (``XLA_FLAGS
    --xla_force_host_platform_device_count``), the pipelined and serial
    schedules are additionally executed through the real shard_map path
    and checked bitwise-equal, with wall-clock recorded for reference.
    """
    if fast:
        M, shards = 2048, 4
    A0 = halo_spikes(M, M * nnz_per_row, seed=seed)
    choice = autotune(A0, num_shards=shards, seed=seed, probe=0)

    cache: dict = {}
    best_ser = best_pipe = None
    for r in choice.ranking:
        plan = r.plan
        bk = (plan.reordering, plan.distribution)
        if bk not in cache:
            perm = reordering_permutation(A0, plan.reordering,
                                          seed=plan.seed, parts=shards)
            Ar = A0 if plan.reordering == "none" else A0.permuted(perm, perm)
            cache[bk] = (Ar, make_partition(Ar, shards, plan.distribution))
        Ar, part = cache[bk]
        m = device_path_model(Ar, part, plan)
        if best_ser is None or m["serial_cycles"] < best_ser[0]:
            best_ser = (m["serial_cycles"], plan)
        if best_pipe is None or m["pipelined_cycles"] < best_pipe[0]:
            best_pipe = (m["pipelined_cycles"], plan, m)

    ser_cycles, ser_plan = best_ser
    pipe_cycles, pipe_plan, pipe_terms = best_pipe
    entry = {
        "workload": "pipeline/halo_spikes", "M": A0.nrows, "nnz": A0.nnz,
        "shards": shards,
        "serial_plan": _plan_str(ser_plan),
        "pipelined_plan": _plan_str(pipe_plan),
        "shard_exchanges": list(pipe_plan.resolved_shard_exchanges()),
        "model_device_cycles": {
            "serial": round(ser_cycles, 1),
            "pipelined": round(pipe_cycles, 1),
            "speedup": round(ser_cycles / max(pipe_cycles, 1e-12), 3)},
        "pipelined_terms": {k: round(v, 1) for k, v in pipe_terms.items()
                            if k != "speedup"},
    }

    prog = lower(A0, pipe_plan)
    x = np.random.default_rng(seed).standard_normal(A0.ncols)
    ref = csr_matvec(A0, x)
    entry["oracle_ok"] = bool(np.allclose(execute(prog, x), ref,
                                          atol=1e-4, rtol=1e-5))

    try:
        import jax
        from repro.launch.mesh import auto_axis_types
        n_dev = jax.device_count()
    except Exception:
        n_dev = 0
    if n_dev >= shards:
        mesh = jax.make_mesh((shards,), ("model",), **auto_axis_types(1))
        y_pipe = execute(prog, x, backend="shard_map", mesh=mesh)
        y_ser = execute(prog, x, backend="shard_map", mesh=mesh,
                        pipeline=False)
        entry["device_bitwise_ok"] = bool(
            np.array_equal(np.asarray(y_pipe), np.asarray(y_ser)))
        entry["device_oracle_ok"] = bool(
            np.allclose(np.asarray(y_pipe), ref, atol=2e-4, rtol=1e-4))
        from repro.core.program import make_program_spmv_fn
        xs = prog.x_to_device(np.asarray(x, dtype=np.float32))
        for key, flag in (("pipelined", True), ("serial", False)):
            fn = make_program_spmv_fn(prog, mesh, pipeline=flag)
            with mesh:
                jax.block_until_ready(fn(xs))   # compile outside the clock
                fn_t = []
                for _ in range(5):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(xs))
                    fn_t.append(time.perf_counter() - t0)
            entry.setdefault("device_host_us_per_spmv", {})[key] = \
                round(float(np.median(fn_t)) * 1e6, 1)
    return entry


def check_pipeline(entry: dict, *, fast: bool = False) -> bool:
    """Acceptance gates for the pipeline workload: the best-achievable
    pipelined device-path latency beats the best-achievable serial one by
    >= 1.15x on the recorded full run (a strict win suffices at CI-smoke
    scale), the pipelined plan's program reproduces the oracle, and —
    when enough devices were visible to run the real shard_map path —
    the two schedules are bitwise-equal."""
    bar = 1.0 if fast else 1.15
    sp = entry.get("model_device_cycles", {}).get("speedup", 0.0)
    return ((sp > bar if fast else sp >= bar) and
            entry.get("oracle_ok", False) and
            entry.get("device_bitwise_ok", True) and
            entry.get("device_oracle_ok", True))


def _probe_arg(s: str):
    """CLI probe budget: an int, or the literal string ``auto``."""
    if s == "auto":
        return s
    return int(s)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload",
                    choices=("mixed", "powerlaw_tail", "pipeline",
                             "blocked"),
                    default="mixed",
                    help="mixed: per-shard vs best-global on "
                         "mixed_structure; powerlaw_tail: split vs best "
                         "non-split on monster rows; pipeline: serial vs "
                         "pipelined device schedule on halo_spikes; "
                         "blocked: tile vs best non-tile on blocked_band")
    ap.add_argument("--m", type=int, default=None, help="matrix dimension "
                    "(default: per-workload)")
    ap.add_argument("--nnz-per-row", type=int, default=33,
                    help="mixed workload only")
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--probe", type=_probe_arg, default=None,
                    help="autotune probe budget: an int, or 'auto' for "
                         "adaptive probing (probe until the "
                         "measured-vs-analytic inversion rate stabilizes; "
                         "default: repro.core.plan.DEFAULT_PROBE)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller matrix, analytic-only ranking, "
                         "same gates")
    ap.add_argument("--budget-seconds", type=float, default=None,
                    help="fail if the whole run exceeds this wall-clock "
                         "budget (CI tripwire)")
    ap.add_argument("--json", action="store_true",
                    help="print the entry as JSON only")
    args = ap.parse_args()

    t0 = time.perf_counter()
    if args.workload == "pipeline":
        kwargs = {} if args.m is None else {"M": args.m}
        entry = run_pipeline_bench(shards=args.shards, seed=args.seed,
                                   fast=args.fast, **kwargs)
        ok = check_pipeline(entry, fast=args.fast)
    elif args.workload == "powerlaw_tail":
        kwargs = {} if args.m is None else {"M": args.m}
        entry = run_split_bench(shards=args.shards, probe=args.probe,
                                seed=args.seed, fast=args.fast, **kwargs)
        ok = check_split(entry, fast=args.fast)
    elif args.workload == "blocked":
        kwargs = {} if args.m is None else {"M": args.m}
        entry = run_tile_bench(shards=args.shards, probe=args.probe,
                               seed=args.seed, fast=args.fast, **kwargs)
        ok = check_tile(entry, fast=args.fast)
    else:
        entry = run_hetero_bench(M=args.m if args.m is not None else 4096,
                                 nnz_per_row=args.nnz_per_row,
                                 shards=args.shards, probe=args.probe,
                                 seed=args.seed, fast=args.fast)
        ok = check(entry)
    wall = time.perf_counter() - t0
    entry["wall_seconds"] = round(wall, 2)
    if args.budget_seconds is not None and wall > args.budget_seconds:
        ok = False
        entry["budget_exceeded"] = True

    if args.json:
        print(json.dumps(entry, indent=2))
    elif args.workload == "pipeline":
        print(f"hetero bench: {entry['workload']} M={entry['M']} "
              f"nnz={entry['nnz']} shards={entry['shards']}")
        print(f"  serial plan : {entry['serial_plan']}")
        print(f"  pipelined   : {entry['pipelined_plan']} "
              f"(exchanges {entry['shard_exchanges']})")
        md = entry["model_device_cycles"]
        bar = "> 1.0 (fast)" if args.fast else ">= 1.15"
        print(f"  device path : {md['serial']} -> {md['pipelined']} "
              f"cycles ({md['speedup']}x, bar {bar})")
        t = entry["pipelined_terms"]
        print(f"  terms       : kernel {t['kernel_cycles']} = local "
              f"{t['local_slice_cycles']} || comm {t['comm_cycles']} "
              f"then remote {t['remote_slice_cycles']}")
        if "device_bitwise_ok" in entry:
            h = entry.get("device_host_us_per_spmv", {})
            print(f"  shard_map   : bitwise_ok={entry['device_bitwise_ok']} "
                  f"oracle_ok={entry['device_oracle_ok']} host "
                  f"{h.get('serial')} -> {h.get('pipelined')} us/SpMV "
                  f"(reference only)")
        budget = f", wall {wall:.1f}s <= {args.budget_seconds:.0f}s" \
            if args.budget_seconds is not None else f", wall {wall:.1f}s"
        print(f"  -> {'PASS' if ok else 'FAIL'} "
              f"(oracle_ok={entry['oracle_ok']}{budget})")
    elif args.workload == "blocked":
        print(f"hetero bench: {entry['workload']} M={entry['M']} "
              f"nnz={entry['nnz']} shards={entry['shards']}")
        print(f"  chosen      : {entry['chosen_plan']} "
              f"(tile_in_winner={entry['tile_in_winner']})")
        print(f"  non-tile    : {entry['best_nontile_plan']}")
        print(f"  tile        : {entry['tile_plan']}")
        mk = entry["model_kernel_cycles"]
        bar = "> 1.0 (fast)" if args.fast else ">= 1.2"
        print(f"  kernel term : {mk['best_nontile']} -> {mk['tile']} "
              f"cycles ({mk['speedup']}x, bar {bar})")
        if "model_total_cycles" in entry:
            mt = entry["model_total_cycles"]
            print(f"  model total : {mt['best_nontile']} -> {mt['tile']} "
                  f"cycles ({mt['speedup']}x)")
        if "tile_counts" in entry:
            print(f"  tile counts : {entry['tile_counts']} "
                  f"(kernels {entry['tile_kernels']})")
        if "host_us_per_spmv" in entry:
            h = entry["host_us_per_spmv"]
            print(f"  host        : {h['best_nontile']} -> {h['tile']} "
                  f"us/SpMV (numpy executor; reference only)")
        budget = f", wall {wall:.1f}s <= {args.budget_seconds:.0f}s" \
            if args.budget_seconds is not None else f", wall {wall:.1f}s"
        print(f"  -> {'PASS' if ok else 'FAIL'} "
              f"(oracle_ok={entry['oracle_ok']}{budget})")
    elif args.workload == "powerlaw_tail":
        print(f"hetero bench: {entry['workload']} M={entry['M']} "
              f"nnz={entry['nnz']} shards={entry['shards']}")
        print(f"  chosen      : {entry['chosen_plan']} "
              f"(split_in_winner={entry['split_in_winner']})")
        print(f"  non-split   : {entry['best_nonsplit_plan']}")
        print(f"  split       : {entry['split_plan']}")
        mk = entry["model_kernel_cycles"]
        bar = "> 1.0 (fast)" if args.fast else ">= 1.1"
        print(f"  kernel term : {mk['best_nonsplit']} -> {mk['split']} "
              f"cycles ({mk['speedup']}x, bar {bar})")
        if "model_total_cycles" in entry:
            mt = entry["model_total_cycles"]
            print(f"  model total : {mt['best_nonsplit']} -> {mt['split']} "
                  f"cycles ({mt['speedup']}x)")
        if "split_counts" in entry:
            print(f"  split counts: {entry['split_counts']} "
                  f"(kernels {entry['split_kernels']})")
        if "host_us_per_spmv" in entry:
            h = entry["host_us_per_spmv"]
            print(f"  host        : {h['best_nonsplit']} -> {h['split']} "
                  f"us/SpMV (numpy executor; reference only)")
        budget = f", wall {wall:.1f}s <= {args.budget_seconds:.0f}s" \
            if args.budget_seconds is not None else f", wall {wall:.1f}s"
        print(f"  -> {'PASS' if ok else 'FAIL'} "
              f"(oracle_ok={entry['oracle_ok']}{budget})")
    else:
        print(f"hetero bench: {entry['workload']} M={entry['M']} "
              f"nnz={entry['nnz']} shards={entry['shards']}")
        print(f"  best global : {entry['best_global_plan']}")
        print(f"  per-shard   : {entry['per_shard_plan']}")
        mt = entry["model_total_cycles"]
        print(f"  model total : {mt['best_global']} -> {mt['per_shard']} "
              f"cycles ({mt['speedup']}x, bar > 1.0)")
        if "model_kernel_cycles" in entry:
            mk = entry["model_kernel_cycles"]
            print(f"  kernel term : {mk['best_global']} -> "
                  f"{mk['per_shard']} cycles ({mk['speedup']}x)")
        if "host_us_per_spmv" in entry:
            h = entry["host_us_per_spmv"]
            print(f"  host        : {h['best_global']} -> {h['per_shard']} "
                  f"us/SpMV (numpy executor; reference only)")
        budget = f", wall {wall:.1f}s <= {args.budget_seconds:.0f}s" \
            if args.budget_seconds is not None else f", wall {wall:.1f}s"
        print(f"  -> {'PASS' if ok else 'FAIL'} "
              f"(oracle_ok={entry['oracle_ok']}{budget})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
