"""Golden determinism for the Emu tick simulator.

The simulator is the reproduction vehicle for every Emu-side figure, so its
output must be a pure function of (config, matrix, partition, layout):
identical tick counts, migration totals, per-nodelet instruction counts and
residency traces across repeated runs — no hidden RNG, no dict-order or
wall-clock dependence.
"""
import numpy as np
import pytest

from repro.core.emu import EmuConfig, build_thread_traces, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.data.matrices import make_matrix

CFG = EmuConfig()


@pytest.fixture(scope="module")
def cop():
    return make_matrix("cop20k_A", scale=0.01)


@pytest.mark.parametrize("strategy", ["row", "nnz"])
def test_simulation_is_deterministic(cop, strategy):
    part = make_partition(cop, CFG.nodelets, strategy)
    lay = make_layout("block", cop.ncols, CFG.nodelets)
    r1 = run_spmv(cop, part, lay, CFG)
    r2 = run_spmv(cop, part, lay, CFG)
    assert r1.ticks == r2.ticks
    assert r1.migrations == r2.migrations
    assert r1.seconds == r2.seconds
    np.testing.assert_array_equal(r1.instr_per_nodelet, r2.instr_per_nodelet)
    np.testing.assert_array_equal(r1.residency, r2.residency)


def test_traces_are_deterministic(cop):
    part = make_partition(cop, 8, "nnz")
    lay = make_layout("block", cop.ncols, 8)
    n1, w1, h1 = build_thread_traces(cop, part, lay, 16)
    n2, w2, h2 = build_thread_traces(cop, part, lay, 16)
    np.testing.assert_array_equal(h1, h2)
    assert len(n1) == len(n2)
    for a, b in zip(n1, n2):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(w1, w2):
        np.testing.assert_array_equal(a, b)


def test_matrix_generation_is_deterministic():
    """The synthetic suite is seeded: same name+scale+seed -> same matrix
    (the precondition for any golden simulator numbers)."""
    A = make_matrix("rmat", scale=0.005, seed=3)
    B = make_matrix("rmat", scale=0.005, seed=3)
    np.testing.assert_array_equal(A.row_ptr, B.row_ptr)
    np.testing.assert_array_equal(A.col_index, B.col_index)
    np.testing.assert_array_equal(A.values, B.values)
