"""SpmvProgram IR tests: lowering, per-shard stages, and executor
equivalence — the numpy oracle, the one shard_map device program (jnp
oracle *and* Pallas-interpret kernels), and the Emu probe all consume the
same lowered program.  The multi-device backend runs in a subprocess so
the fake devices never leak into this session.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.program import execute, lower, probe_program, relower
from repro.core.sparse_matrix import csr_matvec
from repro.core.spmv import SpmvPlan
from repro.data.matrices import make_matrix, mixed_structure, powerlaw, \
    powerlaw_tail

KERNEL_CONFIGS = [
    ("ell", None),
    ("seg", None),
    ("hyb", None),
    ("split", None),
    ("tile", None),
    ("seg", ("ell", "seg", "hyb", "seg")),      # heterogeneous program
    ("seg", ("ell", "split", "hyb", "seg")),    # heterogeneous with split
    ("seg", ("tile", "split", "tile", "ell")),  # heterogeneous tile/split
]


@pytest.mark.parametrize("layout", ["block", "cyclic"])
@pytest.mark.parametrize("distribution", ["row", "nonzero"])
@pytest.mark.parametrize("kernel,shard_kernels", KERNEL_CONFIGS)
def test_numpy_backend_matches_oracle_on_grid(layout, distribution, kernel,
                                              shard_kernels):
    A = make_matrix("cop20k_A", scale=0.003)
    plan = SpmvPlan(layout=layout, distribution=distribution, kernel=kernel,
                    shard_kernels=shard_kernels, num_shards=4)
    prog = lower(A, plan)
    assert prog.shard_kernels() == plan.resolved_shard_kernels()
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(prog, x), csr_matvec(A, x),
                               atol=1e-5, rtol=1e-6)


def test_numpy_backend_batched_bitwise_per_column():
    A = make_matrix("cop20k_A", scale=0.003)
    X = np.random.default_rng(1).standard_normal((A.ncols, 4))
    for kernel, sk in KERNEL_CONFIGS:
        prog = lower(A, SpmvPlan(kernel=kernel, shard_kernels=sk,
                                 num_shards=4, reordering="bfs"))
        Y = execute(prog, X)
        assert Y.shape == (A.nrows, 4)
        for b in range(4):
            assert np.array_equal(Y[:, b], execute(prog, X[:, b])), \
                (kernel, sk, b)
        np.testing.assert_allclose(Y, csr_matvec(A, X), atol=1e-5,
                                   rtol=1e-6)


def test_hyb_stage_really_overflows_and_matches():
    """The capped slab must actually spill on a skewed matrix (otherwise
    HYB degenerates to ELL and the test proves nothing)."""
    A = powerlaw(1024, 40_000, seed=2)
    prog = lower(A, SpmvPlan(kernel="hyb", distribution="row", num_shards=4))
    ovf = sum(st.ell.overflow_vals.size for st in prog.stages)
    assert ovf > 0
    x = np.random.default_rng(3).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(prog, x), csr_matvec(A, x),
                               atol=1e-4, rtol=1e-5)


def test_relower_shares_unchanged_stages():
    A = mixed_structure(1024, 120_000, seed=0)
    p1 = SpmvPlan(num_shards=4, shard_kernels=("ell", "seg", "hyb", "seg"))
    prog = lower(A, p1)
    p2 = SpmvPlan(num_shards=4, shard_kernels=("ell", "ell", "hyb", "seg"))
    prog2 = relower(prog, p2)
    assert prog2.stages[0] is prog.stages[0]
    assert prog2.stages[2] is prog.stages[2]
    assert prog2.stages[3] is prog.stages[3]
    assert prog2.stages[1] is not prog.stages[1]
    assert prog2.stages[1].kernel == "ell"
    x = np.random.default_rng(4).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(prog2, x), csr_matvec(A, x),
                               atol=1e-5, rtol=1e-6)
    # structural objects are shared, not copied
    assert prog2.matrix is prog.matrix and prog2.partition is prog.partition
    with pytest.raises(ValueError, match="base field"):
        relower(prog, SpmvPlan(num_shards=4, layout="cyclic",
                               shard_kernels=("ell", "ell", "hyb", "seg")))


def test_relower_shares_stages_on_unchanged_split_count():
    """Re-planning that keeps a shard's *effective* split count must share
    the stage object; changing the count rebuilds only that stage."""
    A = powerlaw_tail(2048, 2 * 4 * 2048, n_monster=4, seed=0)
    p1 = SpmvPlan(num_shards=4, shard_kernels=("split", "seg", "seg", "seg"),
                  split_counts=(4, 1, 1, 1))
    prog = lower(A, p1)
    assert prog.stages[0].split is not None
    assert prog.stages[0].split.num_splits == 4
    # same requested count -> all stages shared
    prog2 = relower(prog, SpmvPlan(
        num_shards=4, shard_kernels=("split", "seg", "seg", "seg"),
        split_counts=(4, 1, 1, 1)))
    assert all(prog2.stages[p] is prog.stages[p] for p in range(4))
    # different effective count -> only the split stage rebuilds
    prog3 = relower(prog, SpmvPlan(
        num_shards=4, shard_kernels=("split", "seg", "seg", "seg"),
        split_counts=(2, 1, 1, 1)))
    assert prog3.stages[0] is not prog.stages[0]
    assert prog3.stages[0].split.num_splits == 2
    assert all(prog3.stages[p] is prog.stages[p] for p in (1, 2, 3))
    x = np.random.default_rng(5).standard_normal(A.ncols)
    for pr in (prog, prog2, prog3):
        np.testing.assert_allclose(execute(pr, x), csr_matvec(A, x),
                                   atol=1e-4, rtol=1e-5)


def test_degenerate_matrix_empty_shards_all_families():
    """A 6x6 matrix lowered over 4 shards leaves shards with zero rows
    and/or zero nnz; every kernel family must produce a valid no-op stage
    and the exact result (empty-shard lowering regression)."""
    from repro.core.sparse_matrix import csr_from_coo
    A = csr_from_coo([0, 0, 5], [1, 4, 0], [2.0, -1.0, 3.0], (6, 6))
    x = np.arange(6, dtype=np.float64)
    for kernel in ("ell", "seg", "hyb", "split", "tile"):
        for dist in ("row", "nonzero"):
            prog = lower(A, SpmvPlan(kernel=kernel, distribution=dist,
                                     num_shards=4))
            nnz_per_shard = [
                int(A.row_ptr[prog.partition.starts[p + 1]] -
                    A.row_ptr[prog.partition.starts[p]])
                for p in range(4)]
            assert 0 in nnz_per_shard, (kernel, dist)   # genuinely empty
            np.testing.assert_allclose(execute(prog, x), csr_matvec(A, x),
                                       atol=1e-6, err_msg=f"{kernel}/{dist}")
            res = probe_program(prog)               # emu backend runs too
            assert res.ticks > 0


def test_monster_row_numpy_and_emu_backends():
    """Monster-row shard (rows spanning many chunks) through the numpy
    executor and the Emu probe, for seg and split programs."""
    A = powerlaw_tail(2048, 2 * 4 * 2048, n_monster=4, seed=3)
    x = np.random.default_rng(3).standard_normal(A.ncols)
    for sk in (None, ("split", "split", "seg", "seg")):
        plan = SpmvPlan(kernel="seg", shard_kernels=sk,
                        distribution="nonzero", num_shards=4)
        prog = lower(A, plan)
        np.testing.assert_allclose(execute(prog, x), csr_matvec(A, x),
                                   atol=1e-4, rtol=1e-5)
        assert probe_program(prog).ticks > 0


def test_emu_backend_is_deterministic_and_plan_driven():
    A = make_matrix("cop20k_A", scale=0.003)
    prog = lower(A, SpmvPlan(num_shards=4, kernel="seg"))
    r1 = execute(prog, backend="emu")
    r2 = probe_program(prog)
    assert r1.ticks == r2.ticks and r1.migrations == r2.migrations
    # a worse layout really probes slower (cyclic on the banded-ish matrix)
    slow = lower(A, SpmvPlan(num_shards=4, layout="cyclic", kernel="seg"))
    assert probe_program(slow).seconds != r1.seconds


def test_execute_rejects_unknown_backend_and_missing_x():
    A = make_matrix("ford1", scale=0.05)
    prog = lower(A, SpmvPlan(num_shards=4))
    with pytest.raises(ValueError, match="backend"):
        execute(prog, np.zeros(A.ncols), backend="tpu")
    with pytest.raises(ValueError, match="needs an input"):
        execute(prog, backend="numpy")
    with pytest.raises(ValueError, match="mesh"):
        execute(prog, np.zeros(A.ncols), backend="shard_map")


def test_legacy_stacked_views_still_available():
    """Old callers (build_halo, spmv_exchange) read stacked .data/.cols —
    they must exist for any program, and seg_* for uniform-seg ones."""
    A = make_matrix("ford1", scale=0.05)
    het = lower(A, SpmvPlan(num_shards=4,
                            shard_kernels=("ell", "seg", "hyb", "seg")))
    assert het.data.shape[0] == 4 and het.cols.shape == het.data.shape
    assert het.seg_vals is None                 # not a uniform-seg program
    seg = lower(A, SpmvPlan(num_shards=4, kernel="seg"))
    assert seg.seg_vals is not None and seg.seg_pieces.shape[-1] == 4
    from repro.core.spmv import DistributedSpmv, build_halo
    assert isinstance(het, DistributedSpmv)     # deprecated alias
    h = build_halo(het)
    assert h.halo >= 1 and h.send_idx.shape[:2] == (4, 4)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.program import execute, lower, make_program_spmv_fn, \\
        gather_b
    from repro.core.sparse_matrix import csr_matvec
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import make_matrix
    from repro.launch.mesh import auto_axis_types

    mesh = jax.make_mesh((4,), ("model",), **auto_axis_types(1))
    A = make_matrix("cop20k_A", scale=0.003)
    x = np.random.default_rng(1).standard_normal(A.ncols).astype(np.float32)
    X = np.random.default_rng(2).standard_normal((A.ncols, 3)) \\
        .astype(np.float32)
    ref = csr_matvec(A, x)
    out = {}
    # executor equivalence: numpy oracle vs shard_map (jnp oracle) vs
    # shard_map (Pallas interpret), on a cross-section of the
    # exchange x layout x distribution x per-shard-kernel grid (the full
    # grid is pinned in-process against the numpy backend; the device
    # backend compiles, so it samples every axis value instead)
    bases = (("allgather", "block", "row"),
             ("allgather", "cyclic", "nonzero"),
             ("halo", "block", "nonzero"),
             ("halo", "cyclic", "row"))
    for exch, layout, dist_s in bases:
        for sk in (None, ("ell", "seg", "hyb", "seg"),
                   ("ell", "split", "hyb", "seg"),
                   ("tile", "seg", "split", "tile")):
            plan = SpmvPlan(layout=layout, distribution=dist_s,
                            exchange=exch, kernel="seg",
                            shard_kernels=sk, num_shards=4)
            prog = lower(A, plan)
            y_np = execute(prog, x)
            y_sm = execute(prog, x, backend="shard_map", mesh=mesh)
            tag = "seg" if sk is None else \\
                ("het+tile" if "tile" in sk else
                 "het+split" if "split" in sk else "het")
            key = f"{exch}/{layout}/{dist_s}/{tag}"
            out[key] = bool(
                np.allclose(y_np, ref, atol=1e-3) and
                np.allclose(y_sm, ref, atol=1e-3) and
                np.allclose(y_sm, y_np, atol=1e-3))
    # Pallas-interpret kernels through the same executor
    plan = SpmvPlan(exchange="halo", num_shards=4,
                    shard_kernels=("ell", "seg", "hyb", "seg"))
    prog = lower(A, plan)
    y_pal = execute(prog, x, backend="shard_map", mesh=mesh,
                    use_kernel=True, interpret=True)
    out["pallas"] = bool(np.allclose(y_pal, ref, atol=1e-3))
    # batched (N, B) through the device path
    Y = execute(prog, X, backend="shard_map", mesh=mesh)
    out["batched"] = bool(np.allclose(Y, csr_matvec(A, X), atol=1e-3))
    # reusable compiled fn + shard-form output
    fn = make_program_spmv_fn(prog, mesh)
    with mesh:
        ys = fn(jnp.asarray(prog.x_to_device(x)))
    out["fn_form"] = bool(np.allclose(gather_b(prog, ys), ref, atol=1e-3))
    # monster-row shards through the device split path (jnp oracle,
    # Pallas interpret, and batched), vs the numpy backend and csr_matvec
    from repro.data.matrices import powerlaw_tail
    Am = powerlaw_tail(1024, 2 * 4 * 1024, n_monster=4, seed=3)
    xm = np.random.default_rng(3).standard_normal(Am.ncols) \\
        .astype(np.float32)
    refm = csr_matvec(Am, xm)
    pm = lower(Am, SpmvPlan(num_shards=4, distribution="nonzero",
                            shard_kernels=("split", "split", "seg", "seg")))
    y_np = execute(pm, xm)
    y_sm = execute(pm, xm, backend="shard_map", mesh=mesh)
    y_pk = execute(pm, xm, backend="shard_map", mesh=mesh,
                   use_kernel=True, interpret=True)
    out["monster_split"] = bool(
        np.allclose(y_np, refm, atol=1e-2) and
        np.allclose(y_sm, refm, atol=1e-2) and
        np.allclose(y_pk, refm, atol=1e-2))
    Xm = np.random.default_rng(4).standard_normal((Am.ncols, 3)) \\
        .astype(np.float32)
    Ym = execute(pm, Xm, backend="shard_map", mesh=mesh)
    out["monster_split_batched"] = bool(
        np.allclose(Ym, csr_matvec(Am, Xm), atol=1e-2))
    # blocked-band shards through the device tile path (jnp oracle,
    # Pallas interpret, and batched), mixed with the split family
    from repro.data.matrices import blocked_band
    At = blocked_band(512, 215 * 512, seed=0)
    xt = np.random.default_rng(8).standard_normal(At.ncols) \\
        .astype(np.float32)
    reft = csr_matvec(At, xt)
    pt = lower(At, SpmvPlan(num_shards=4, exchange="halo",
                            shard_kernels=("tile", "tile", "split", "seg")))
    y_np = execute(pt, xt)
    y_sm = execute(pt, xt, backend="shard_map", mesh=mesh)
    y_pk = execute(pt, xt, backend="shard_map", mesh=mesh,
                   use_kernel=True, interpret=True)
    out["blocked_tile"] = bool(
        np.allclose(y_np, reft, atol=1e-2) and
        np.allclose(y_sm, reft, atol=1e-2) and
        np.allclose(y_pk, reft, atol=1e-2))
    Xt = np.random.default_rng(9).standard_normal((At.ncols, 3)) \\
        .astype(np.float32)
    Yt = execute(pt, Xt, backend="shard_map", mesh=mesh)
    out["blocked_tile_batched"] = bool(
        np.allclose(Yt, csr_matvec(At, Xt), atol=1e-2))
    # empty shards on the device path, all five families (the 6x6 matrix
    # leaves zero-nnz shards, so the tile stage here is the zero-tile
    # no-op slab)
    from repro.core.sparse_matrix import csr_from_coo
    Ad = csr_from_coo([0, 0, 5], [1, 4, 0], [2.0, -1.0, 3.0], (6, 6))
    xd = np.arange(6, dtype=np.float32)
    refd = csr_matvec(Ad, xd)
    for kern in ("ell", "seg", "hyb", "split", "tile"):
        pd = lower(Ad, SpmvPlan(kernel=kern, num_shards=4))
        yd = execute(pd, xd, backend="shard_map", mesh=mesh)
        out[f"empty_{kern}"] = bool(np.allclose(yd, refd, atol=1e-5))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_executor_equivalence_4dev_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(res.values()), res


_SUBPROC_PIPELINE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, numpy as np
    from repro.core.program import execute, lower
    from repro.core.sparse_matrix import csr_matvec
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import make_matrix, mixed_structure, \\
        powerlaw_tail
    from repro.launch.mesh import auto_axis_types

    mesh = jax.make_mesh((4,), ("model",), **auto_axis_types(1))
    A_mixed = mixed_structure(1024, 1024 * 8, seed=0)
    A_tail = powerlaw_tail(1024, 2 * 4 * 1024, n_monster=4, seed=3)
    A_cop = make_matrix("cop20k_A", scale=0.003)
    # "drifted" cop20k_A: the serving-path failure mode — an ordering
    # artifact scrambles the ingest-time structure out from under the plan
    perm = np.random.default_rng(7).permutation(A_cop.nrows)
    A_drift = A_cop.permuted(perm, perm)

    cases = {
        "mixed_structure": (A_mixed, SpmvPlan(
            num_shards=4, exchange="halo",
            shard_kernels=("ell", "seg", "hyb", "split"))),
        "mixed_structure_mixed_exchange": (A_mixed, SpmvPlan(
            num_shards=4, exchange="halo", kernel="seg",
            shard_exchanges=("halo", "allgather", "halo", "allgather"))),
        "powerlaw_tail": (A_tail, SpmvPlan(
            num_shards=4, distribution="nonzero",
            shard_kernels=("split", "split", "seg", "seg"))),
        "cop20k_A_drifted_allgather": (A_drift, SpmvPlan(
            num_shards=4, exchange="allgather", kernel="seg")),
        "cop20k_A_drifted_halo": (A_drift, SpmvPlan(
            num_shards=4, exchange="halo", kernel="hyb",
            layout="cyclic", distribution="nonzero")),
    }
    out = {}
    for name, (A, plan) in cases.items():
        x = np.random.default_rng(5).standard_normal(A.ncols) \\
            .astype(np.float32)
        X = np.random.default_rng(6).standard_normal((A.ncols, 3)) \\
            .astype(np.float32)
        prog = lower(A, plan)
        ref = csr_matvec(A, x)
        y_pipe = np.asarray(execute(prog, x, backend="shard_map",
                                    mesh=mesh))
        y_ser = np.asarray(execute(prog, x, backend="shard_map", mesh=mesh,
                                   pipeline=False))
        Y_pipe = np.asarray(execute(prog, X, backend="shard_map",
                                    mesh=mesh))
        Y_ser = np.asarray(execute(prog, X, backend="shard_map", mesh=mesh,
                                   pipeline=False))
        out[name] = bool(np.array_equal(y_pipe, y_ser) and
                         np.array_equal(Y_pipe, Y_ser) and
                         np.allclose(y_pipe, ref, atol=1e-2, rtol=1e-4))
    # Pallas-interpret kernels: the pipelined and serial schedules feed
    # the same kernel bodies, so bitwise equality must hold there too
    xk = np.random.default_rng(5).standard_normal(A_mixed.ncols) \\
        .astype(np.float32)
    prog = lower(A_mixed, SpmvPlan(
        num_shards=4, exchange="halo",
        shard_kernels=("ell", "seg", "hyb", "seg")))
    y_pipe = np.asarray(execute(prog, xk, backend="shard_map", mesh=mesh,
                                use_kernel=True, interpret=True))
    y_ser = np.asarray(execute(prog, xk, backend="shard_map", mesh=mesh,
                               use_kernel=True, interpret=True,
                               pipeline=False))
    out["pallas_interpret_bitwise"] = bool(
        np.array_equal(y_pipe, y_ser) and
        np.allclose(y_pipe, csr_matvec(A_mixed, xk), atol=1e-2, rtol=1e-4))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_pipelined_executor_bitwise_equals_serial_4dev_subprocess():
    """The pipelined schedule (local slice overlapping the exchange) must
    be bitwise-identical to the pre-pipeline serial execution order on
    every workload/backend — the serial path runs the identical slice
    split behind an optimization barrier, so any divergence is a real
    operand bug, not float reassociation."""
    r = subprocess.run([sys.executable, "-c", _SUBPROC_PIPELINE],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(res.values()), res
