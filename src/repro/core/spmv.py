"""Distributed SpMV: the paper's optimization axes as one plan object.

``SpmvPlan`` is the first-class configuration: layout x distribution x
reordering x exchange x kernel — exactly the paper's study grid, plus the
per-shard ``shard_kernels`` axis (each shard independently ``ell`` /
``seg`` / ``hyb``) that the per-region selection literature argues for.

Since the SpmvProgram refactor the *lowering and execution* live in
:mod:`repro.core.program`: ``lower(csr, plan)`` produces the per-shard
staged program and ``execute`` / ``make_program_spmv_fn`` are the single
executor entry points (numpy oracle, one shard_map device program, Emu
probe).  This module keeps the plan itself, the halo-exchange accounting
(:func:`build_halo`), and thin **deprecated shims** for the pre-IR API:
``build_distributed``, ``local_spmv``, ``make_spmv_fn``,
``make_seg_spmv_fn``, ``make_halo_spmv_fn`` — all of which now delegate to
the one program executor.

* ``allgather``  — every device gathers the full x then gathers locally;
                   the Hein et al. baseline the paper contrasts against
                   (x replicated), maximal ICI bytes, zero imbalance.
* ``halo``       — each device fetches only the x shards it actually reads
                   (block layout + reordered matrices make this cheap); the
                   faithful analogue of migratory access.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

import jax
import numpy as np
from jax.sharding import Mesh

try:                                   # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_norep(fn, **kw):
    """shard_map with replication checking off (pallas_call has no rep rule);
    the flag is ``check_rep`` on 0.4.x and ``check_vma`` on newer jax."""
    try:
        return _shard_map(fn, check_rep=False, **kw)
    except TypeError:
        return _shard_map(fn, check_vma=False, **kw)

from .sparse_matrix import CSRMatrix

__all__ = ["SpmvPlan", "DistributedSpmv", "build_distributed",
           "make_spmv_fn", "make_seg_spmv_fn", "build_halo",
           "make_halo_spmv_fn", "local_spmv"]

#: Kernel spellings a plan accepts (per-shard or uniform), in tie-break
#: preference order (the regular ELL stream wins ties against formats that
#: pay scan/scatter overheads; the dense-tile stream comes last — it only
#: wins when the blocked structure makes it *strictly* cheaper).  The
#: SINGLE definition: ``plan.KERNELS`` (selector/majority order) and
#: ``program.PROGRAM_KERNELS`` (the ``lax.switch`` branch ids) are aliases
#: of this tuple, so the three layers cannot drift.  New families are
#: appended, never inserted, so lowered branch ids stay stable.
PLAN_KERNELS = ("ell", "seg", "hyb", "split", "tile")

#: Exchange policies a plan accepts (uniform or per-shard).  ``halo``
#: first: on a cost tie the exact-entries exchange wins over full
#: replication.  Single definition — ``plan.select_shard_exchanges`` and
#: the executor's prologue both read this tuple.
PLAN_EXCHANGES = ("halo", "allgather")


@dataclasses.dataclass(frozen=True)
class SpmvPlan:
    """The paper's optimization grid as one config object.

    ``distribution="nnz"`` is the nonzero-balanced split (alias of
    ``"nonzero"``): device row-ranges are chosen by cumulative-nnz split
    instead of equal rows, so a power-law matrix cannot converge all the
    work on one device the way it converges threads on one nodelet in the
    paper's §IV-D.  ``kernel`` picks the per-shard device format:
    ``"ell"`` (row-tiled padded slabs), ``"seg"`` (nonzero-balanced
    segmented chunks whose *grid* is load-balance-aware too), ``"hyb"``
    (p95-capped ELL + COO overflow tail for skew-tolerant padding),
    ``"split"`` (split-nnz two-stage split-K: the seg chunk grid cut into
    NS partial accumulators plus a tiny combine — the monster-row cure),
    or ``"tile"`` (bitmask-tiled: a coarse pointer grid over dense
    (8, 128) tiles streamed with whole-tile FMAs and no per-element
    column indices — the blocked answer for banded/block matrices).

    ``shard_kernels`` (optional) overrides the kernel **per shard** — one
    entry per shard, each in :data:`PLAN_KERNELS` — producing the
    heterogeneous programs the per-shard autotuner emits for
    mixed-structure matrices.  ``None`` (the default, and what legacy
    JSON without the field deserializes to) means the uniform program:
    every shard uses ``kernel``.  ``split_counts`` (optional) pins the
    per-shard split count NS for ``split`` shards — one entry per shard,
    ignored (must be 1 or None-like) on non-split shards; ``None`` means
    the lowering asks ``plan.split_meta`` (the occupancy-driven
    ``get_meta_param`` analogue) per shard.

    ``shard_exchanges`` (optional) overrides the exchange **per shard**
    — one entry per shard, each in :data:`PLAN_EXCHANGES`.  A skewed
    shard that reads most of x pays less streaming the full replication
    (``allgather``) than assembling a near-total halo; a banded shard
    keeps the exact-entries ``halo``.  ``None`` (the default, and what
    legacy JSON deserializes to) means every shard uses ``exchange``.
    Plans remain frozen, hashable and JSON-round-trippable either way.
    """

    layout: Literal["block", "cyclic"] = "block"
    distribution: Literal["row", "nonzero", "nnz"] = "nonzero"
    reordering: Literal["none", "random", "bfs", "metis", "degree"] = "none"
    exchange: Literal["allgather", "halo"] = "halo"
    kernel: Literal["ell", "seg", "hyb", "split", "tile"] = "ell"
    num_shards: int = 8
    seed: int = 0
    shard_kernels: tuple | None = None
    split_counts: tuple | None = None
    shard_exchanges: tuple | None = None

    def __post_init__(self):
        if self.shard_kernels is not None:
            sk = tuple(self.shard_kernels)   # JSON lists -> hashable tuple
            bad = [k for k in sk if k not in PLAN_KERNELS]
            if bad:
                raise ValueError(f"unknown shard kernel(s) {bad!r}; expected "
                                 f"entries from {PLAN_KERNELS}")
            object.__setattr__(self, "shard_kernels", sk)
        if self.split_counts is not None:
            sc = tuple(int(c) for c in self.split_counts)
            if any(c < 1 for c in sc):
                raise ValueError(f"split_counts must be >= 1, got {sc!r}")
            object.__setattr__(self, "split_counts", sc)
        if self.shard_exchanges is not None:
            se = tuple(self.shard_exchanges)  # JSON lists -> hashable tuple
            bad = [e for e in se if e not in PLAN_EXCHANGES]
            if bad:
                raise ValueError(f"unknown shard exchange(s) {bad!r}; "
                                 f"expected entries from {PLAN_EXCHANGES}")
            object.__setattr__(self, "shard_exchanges", se)

    def resolved_shard_kernels(self) -> tuple:
        """The per-shard kernel tuple this plan lowers to (length S)."""
        if self.shard_kernels is None:
            return (self.kernel,) * self.num_shards
        if len(self.shard_kernels) != self.num_shards:
            raise ValueError(
                f"shard_kernels has {len(self.shard_kernels)} entries but "
                f"num_shards={self.num_shards}")
        return self.shard_kernels

    def resolved_shard_exchanges(self) -> tuple:
        """The per-shard exchange tuple this plan executes with (length S)."""
        if self.shard_exchanges is None:
            return (self.exchange,) * self.num_shards
        if len(self.shard_exchanges) != self.num_shards:
            raise ValueError(
                f"shard_exchanges has {len(self.shard_exchanges)} entries "
                f"but num_shards={self.num_shards}")
        return self.shard_exchanges

    def resolved_split_counts(self) -> tuple:
        """Per-shard split-count requests (length S; 0 = let the policy
        decide).  Entries only matter for shards lowered as ``split``."""
        if self.split_counts is None:
            return (0,) * self.num_shards
        if len(self.split_counts) != self.num_shards:
            raise ValueError(
                f"split_counts has {len(self.split_counts)} entries but "
                f"num_shards={self.num_shards}")
        return self.split_counts

    def retarget(self, num_shards: int) -> "SpmvPlan":
        """Re-target to a different shard count.

        Per-shard kernel/split/exchange tuples are only meaningful for
        the shard count they were tuned on, so a mismatched
        ``shard_kernels`` (or ``split_counts``, or ``shard_exchanges``)
        is dropped (the plan falls back to its uniform ``kernel`` / the
        split policy / its uniform ``exchange``) instead of producing an
        unlowerable plan.
        """
        sk = self.shard_kernels
        if sk is not None and len(sk) != num_shards:
            sk = None
        sc = self.split_counts
        if sc is not None and len(sc) != num_shards:
            sc = None
        se = self.shard_exchanges
        if se is not None and len(se) != num_shards:
            se = None
        return dataclasses.replace(self, num_shards=num_shards,
                                   shard_kernels=sk, split_counts=sc,
                                   shard_exchanges=se)

    @classmethod
    def auto(cls, csr: CSRMatrix, *, num_shards: int = 8, seed: int = 0,
             probe: int | str | None = None, **grid) -> "SpmvPlan":
        """Pick a plan for ``csr`` with the cost-model autotuner.

        Thin wrapper over :func:`repro.core.plan.autotune` (which see for
        the candidate grid — including per-shard kernel selection — and
        the ``probe`` refinement: simulator re-ranking of the top
        ``plan.DEFAULT_PROBE`` bases unless overridden; ``probe="auto"``
        probes adaptively until the measured-vs-analytic inversion rate
        stabilizes); returns only the winning plan.  Use ``autotune``
        directly when the full ranking or the JSON-serializable
        :class:`~repro.core.plan.PlanChoice` is needed (the serving
        engine persists it per ingested matrix).
        """
        from .plan import autotune
        return autotune(csr, num_shards=num_shards, seed=seed, probe=probe,
                        **grid).plan



#: Shims that already warned this process — each deprecated ``make_*`` shim
#: emits its DeprecationWarning exactly once, so a tight legacy serving
#: loop is not spammed while migration off the pre-IR API is in flight.
_DEPRECATION_WARNED: set = set()


def _warn_deprecated(name: str, replacement: str) -> None:
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} instead",
        DeprecationWarning, stacklevel=3)


def build_distributed(csr: CSRMatrix, plan: SpmvPlan):
    """Deprecated alias of :func:`repro.core.program.lower`."""
    from .program import lower
    return lower(csr, plan)


def local_spmv(dist, x: np.ndarray) -> np.ndarray:
    """Single-host execution of a lowered program: y = A @ x, caller order.

    Deprecated alias of ``program.execute(dist, x, backend="numpy")`` —
    the exact float64 oracle every serving request runs through
    (``serve.engine.SparseMatrixEngine``).  ``x`` may be a single (N,)
    vector or a multi-RHS block (N, B); column b of a batched call is
    *bitwise* equal to the per-vector call on ``x[:, b]``.
    """
    from .program import execute
    return execute(dist, x, backend="numpy")


def make_spmv_fn(dist, mesh: Mesh, axis: str = "model",
                 *, use_kernel: bool = False, interpret: bool = True):
    """Deprecated shim over :func:`repro.core.program.make_program_spmv_fn`.

    Returns the old ``f(data, cols, x_shards) -> b_shards`` signature; the
    slab arguments are accepted for compatibility but the program's own
    lowered operands (identical content) are what execute.  Matching the
    historical factory, the exchange is always all-gather — a halo plan is
    re-bound (stages shared) first; use
    :func:`~repro.core.program.make_program_spmv_fn` for plan-driven
    exchange selection.
    """
    _warn_deprecated("make_spmv_fn", "repro.core.program.make_program_spmv_fn")
    from .program import make_program_spmv_fn
    prog = dist
    if prog.plan.exchange != "allgather" or prog.plan.shard_exchanges:
        prog = lower_with_exchange(
            prog, dataclasses.replace(prog.plan, exchange="allgather",
                                      shard_exchanges=None))
    inner = make_program_spmv_fn(prog, mesh, axis=axis,
                                 use_kernel=use_kernel, interpret=interpret)

    @jax.jit
    def fn(data, cols, x_shards):
        del data, cols                      # the program carries its slabs
        return inner(x_shards)
    return fn


def make_seg_spmv_fn(dist, mesh: Mesh, axis: str = "model",
                     *, use_kernel: bool = False, interpret: bool = True):
    """Deprecated shim over :func:`repro.core.program.make_program_spmv_fn`
    for uniform-seg programs (old ``f(vals, cols, rows, pieces, x_shards)``
    signature)."""
    _warn_deprecated("make_seg_spmv_fn",
                     "repro.core.program.make_program_spmv_fn")
    if any(st.kernel != "seg" for st in dist.stages):
        raise ValueError("build_distributed was not run with plan.kernel='seg'")
    from .program import make_program_spmv_fn
    prog = dist
    if prog.plan.exchange != "allgather" or prog.plan.shard_exchanges:
        # historical factory: uniform all-gather
        prog = lower_with_exchange(
            prog, dataclasses.replace(prog.plan, exchange="allgather",
                                      shard_exchanges=None))
    inner = make_program_spmv_fn(prog, mesh, axis=axis,
                                 use_kernel=use_kernel, interpret=interpret)
    rows_pad = int(dist.rows_per_shard.max())

    @jax.jit
    def fn(vals, cols, rows, pieces, x_shards):
        del vals, cols, rows, pieces
        return inner(x_shards)[:, :rows_pad]
    return fn


def _apply_perm(v: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """v in old order -> v in new order (perm[old] = new)."""
    out = np.empty_like(v)
    out[perm] = v
    return out


# --------------------------------------------------------------------------
# halo exchange accounting — the migratory-access analogue (beyond the
# all-gather baseline, which is the Hein et al. x-replication the paper
# contrasts).  The executor's halo prologue lives in core/program.py; this
# host-side builder remains the ICI-bytes accounting surface
# (benchmarks/spmv_exchange.py) and the legacy shim's operand source.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class HaloProgram:
    """Host-precomputed halo exchange for one lowered program.

    Shard q sends to shard p exactly the x entries p's rows read from q
    (``send_idx[q, p]``, padded to the max halo H).  On device one
    ``all_to_all`` moves S*H elements per shard instead of the full vector;
    the ELL column ids are remapped into [local_x ++ recv_buffer].
    """

    send_idx: np.ndarray      # (S, S, H) local indices on the sender
    cols_remap: np.ndarray    # (S, rows_pad, W) into the augmented buffer
    halo: int                 # H
    comm_elems_per_shard: int  # S * H (vs padded_length for all-gather)


def build_halo(dist) -> HaloProgram:
    S = dist.plan.num_shards
    lay = dist.x_layout
    per = lay.padded_length() // S
    # Padded ELL slots (and stored explicit zeros) carry value 0 and point
    # at col 0; they contribute nothing to y, so they must not widen the
    # halo — otherwise every shard p != 0 appears to read global id 0 from
    # shard 0 and H (hence comm_elems_per_shard) is inflated.
    needed = [[None] * S for _ in range(S)]
    for p in range(S):
        cols_p = dist.cols[p].reshape(-1)
        act_p = dist.data[p].reshape(-1) != 0
        own_p = lay.owner_of(cols_p)
        for q in range(S):
            ids = np.unique(cols_p[act_p & (own_p == q)]) if q != p \
                else np.zeros(0, np.int64)
            needed[p][q] = ids
    H = max((ids.size for row in needed for ids in row), default=1)
    H = max(H, 1)
    send_idx = np.zeros((S, S, H), dtype=np.int32)
    # augmented-buffer position of each global id, per receiving shard p
    recv_pos = [dict() for _ in range(S)]
    for p in range(S):
        for q in range(S):
            ids = needed[p][q]
            send_idx[q, p, : ids.size] = lay.local_index(ids)
            base = per + q * H
            for slot, gid in enumerate(ids):
                recv_pos[p][int(gid)] = base + slot
    cols_remap = np.zeros_like(dist.cols)
    for p in range(S):
        cols_p = dist.cols[p]
        own_p = lay.owner_of(cols_p)
        local = lay.local_index(cols_p)
        remap = np.where(own_p == p, local, 0)
        # Zero-value slots keep remap 0: x_local[0] times value 0 is 0.
        rem_mask = (own_p != p) & (dist.data[p] != 0)
        if rem_mask.any():
            flat = cols_p[rem_mask]
            remap_rem = np.array([recv_pos[p][int(g)] for g in flat],
                                 dtype=np.int32)
            remap[rem_mask] = remap_rem
        cols_remap[p] = remap
    return HaloProgram(send_idx=send_idx, cols_remap=cols_remap, halo=H,
                       comm_elems_per_shard=S * H)


def make_halo_spmv_fn(dist, halo: HaloProgram, mesh: Mesh,
                      axis: str = "model", *, use_kernel: bool = False,
                      interpret: bool = True):
    """Deprecated shim over :func:`repro.core.program.make_program_spmv_fn`
    (old ``f(data, cols_remap, send_idx, x_shards)`` signature).

    Collective volume: S*H elements/shard (halo) vs padded_length
    (all-gather) — the ratio is exactly the paper's block-layout locality
    win, measured in ICI bytes.  The executed program uses the plan's own
    halo prologue; a non-halo plan is re-lowered with ``exchange="halo"``
    first so the shim keeps its historical meaning.
    """
    _warn_deprecated("make_halo_spmv_fn",
                     "repro.core.program.make_program_spmv_fn")
    from .program import make_program_spmv_fn
    prog = dist
    if prog.plan.exchange != "halo" or prog.plan.shard_exchanges:
        # Historical behaviour: this factory always produced the uniform
        # halo program for the plan's base, whatever plan.exchange said.
        prog = lower_with_exchange(
            prog, dataclasses.replace(prog.plan, exchange="halo",
                                      shard_exchanges=None))
    inner = make_program_spmv_fn(prog, mesh, axis=axis,
                                 use_kernel=use_kernel, interpret=interpret)

    @jax.jit
    def fn(data, cols_remap, send_idx, x_shards):
        del data, cols_remap, send_idx
        return inner(x_shards)
    return fn


def lower_with_exchange(program, new_plan: SpmvPlan):
    """Clone a program under a different exchange (same base otherwise).

    The exchange only changes the executor's prologue, not the stages, so
    every stage/accounting object is shared with the source program."""
    return dataclasses.replace(program, plan=new_plan)


def __getattr__(name):
    if name == "DistributedSpmv":       # deprecated alias of the program IR
        from .program import SpmvProgram
        return SpmvProgram
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
