"""Jit'd public wrappers around the Pallas kernels (+ oracle fallbacks).

On TPU the Pallas path is used; on CPU (this container) the kernels run
under ``interpret=True`` in tests and the pure-jnp oracle is the default
execution path, so every higher layer works identically on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .spmv_ell import ell_spmv as _ell_spmv_pallas
from .spmv_seg import seg_psum as _seg_psum_pallas
from .spmv_split import split_combine as _split_combine_pallas, \
    split_psum as _split_psum_pallas
from .spmv_tile import tile_contrib as _tile_contrib_pallas, \
    tile_walk_spmv as _tile_walk_pallas
from repro.core.partition import nnz_chunk_starts
from repro.core.sparse_matrix import EllMatrix, SegMatrix, SplitMatrix, \
    TileMatrix, csr_to_tile, hyb_cap_width

__all__ = ["SEG_CHUNK", "ell_spmv_ref", "ell_spmv", "hyb_spmv", "hyb_from_csr",
           "bell_spmv", "bell_spmm", "bell_from_bcsr", "seg_spmv",
           "seg_spmv_ref", "seg_from_csr", "split_from_csr", "split_spmv",
           "split_spmv_ref", "split_flat_spmv", "tile_from_csr", "tile_spmv",
           "tile_spmv_ref", "tile_flat_spmv"]

#: Default elements per segmented chunk (lane-aligned).  Single source of
#: truth shared with the plan cost model's padding arithmetic.
SEG_CHUNK = 512

ell_spmv_ref = jax.jit(ref.ell_spmv_ref)
bell_spmv_ref = jax.jit(ref.bell_spmv_ref)
bell_spmm_ref = jax.jit(ref.bell_spmm_ref)
seg_spmv_ref = jax.jit(ref.seg_spmv_ref, static_argnames=("num_rows",))
split_spmv_ref = jax.jit(ref.split_spmv_ref, static_argnames=("num_rows",))
tile_spmv_ref = jax.jit(ref.tile_spmv_ref, static_argnames=("num_rows",))
tile_flat_spmv_ref = jax.jit(ref.tile_flat_spmv_ref,
                             static_argnames=("num_rows",))


def ell_spmv(data, cols, x, *, interpret: bool = False, **tiles):
    """Pallas ELL SpMV (TPU); set interpret=True on CPU.

    Accepts a multi-RHS block x of shape (N, B) as well as a single (N,)
    vector; the batched case vmaps the single-vector kernel over the
    trailing axis, so each column reproduces the per-vector result.
    """
    if jnp.asarray(x).ndim == 2:
        return jax.vmap(
            lambda xb: _ell_spmv_pallas(data, cols, xb, interpret=interpret,
                                        **tiles),
            in_axes=1, out_axes=1)(jnp.asarray(x))
    return _ell_spmv_pallas(data, cols, x, interpret=interpret, **tiles)


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _overflow_add(y, rows, cols, vals, x, num_rows: int):
    xs = jnp.take(x, cols, axis=0)           # (O,) or (O, B)
    if xs.ndim == 2:
        vals = vals[:, None]
    return y.at[rows].add(vals * xs)


def hyb_from_csr(csr, *, lane: int | None = None,
                 sublane: int | None = None) -> EllMatrix:
    """Convert host CSRMatrix -> HYB (capped ELL + COO overflow tail).

    The ELL width is capped at :func:`~repro.core.sparse_matrix.hyb_cap_width`
    (lane-aligned p95 of row lengths), so skewed rows spill into the COO
    overflow arrays instead of inflating every row's padded width —
    the format :func:`hyb_spmv` executes.
    """
    from repro.core.sparse_matrix import ELL_LANE, ELL_SUBLANE, csr_row_nnz, \
        csr_to_ell
    lane = ELL_LANE if lane is None else lane
    sublane = ELL_SUBLANE if sublane is None else sublane
    cap = hyb_cap_width(csr_row_nnz(csr), lane=lane)
    return csr_to_ell(csr, lane=lane, sublane=sublane, max_width=cap)


def hyb_spmv(ell_data, ell_cols, ovf_rows, ovf_cols, ovf_vals, x,
             *, use_kernel: bool = False, interpret: bool = False):
    """HYB = padded-ELL kernel + COO overflow scatter-add tail.

    Accepts a single (N,) vector or a multi-RHS block (N, B), matching the
    other kernel wrappers; the overflow scatter broadcasts over the
    trailing batch axis."""
    if use_kernel:
        y = ell_spmv(ell_data, ell_cols, x, interpret=interpret)
    else:
        y = ell_spmv_ref(ell_data, ell_cols, x)
    if ovf_vals.shape[0]:
        y = _overflow_add(y, ovf_rows, ovf_cols, ovf_vals, x, num_rows=y.shape[0])
    return y


def _bell_walk_tables(blocks, bcols):
    """Flatten padded Block-ELL tables into a rectangular tile walk.

    Block-ELL *is* a dense tile walk whose walk table happens to be
    rectangular: slot (mb, k) streams tile ``mb*K + k`` against block
    column ``bcols[mb, k]``; padded slots hold zero blocks so the walk
    visits them harmlessly (counts = K everywhere).
    """
    Mb, K, bm, bn = blocks.shape
    data = jnp.asarray(blocks).reshape(Mb * K, bm, bn)
    counts = jnp.full((Mb,), K, dtype=jnp.int32)
    tid = jnp.arange(Mb * K, dtype=jnp.int32).reshape(Mb, K)
    return data, counts, tid, jnp.asarray(bcols, dtype=jnp.int32)


def bell_spmv(blocks, bcols, x, *, use_kernel: bool = False,
              interpret: bool = False):
    """Deprecated Block-ELL SpMV — absorbed by the tile family.

    Thin shim: the padded (Mb, K) Block-ELL tables are one special case
    of the bitmask-tiled walk (rectangular walk table, all slots
    visited), so the kernel path runs
    :func:`~repro.kernels.spmv_tile.tile_walk_spmv`.  New code should
    build a :class:`TileMatrix` via :func:`tile_from_csr` and call
    :func:`tile_spmv`.
    """
    from repro.core.spmv import _warn_deprecated
    _warn_deprecated("bell_spmv", "repro.kernels.ops.tile_spmv")
    if use_kernel:
        data, counts, tid, bc = _bell_walk_tables(blocks, bcols)
        return _tile_walk_pallas(data, counts, tid, bc, jnp.asarray(x),
                                 interpret=interpret)
    return bell_spmv_ref(blocks, bcols, x)


def bell_spmm(blocks, bcols, X, *, use_kernel: bool = False,
              interpret: bool = False, tile_b: int = 128):
    """Deprecated Block-ELL SpMM — absorbed by the tile family.

    Thin shim over the tile walk, vmapped over the RHS columns
    (``tile_b`` is accepted for signature compatibility and ignored).
    New code should call :func:`tile_spmv` with a (N, B) block.
    """
    from repro.core.spmv import _warn_deprecated
    _warn_deprecated("bell_spmm", "repro.kernels.ops.tile_spmv")
    del tile_b
    if use_kernel:
        data, counts, tid, bc = _bell_walk_tables(blocks, bcols)
        return jax.vmap(
            lambda xb: _tile_walk_pallas(data, counts, tid, bc, xb,
                                         interpret=interpret),
            in_axes=1, out_axes=1)(jnp.asarray(X))
    return bell_spmm_ref(blocks, bcols, X)


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _seg_fixup(psum, piece_chunk, piece_lo, piece_hi, piece_row,
               num_rows: int):
    """Cross-chunk carry fix-up: scatter per-(chunk, row) pieces into y.

    A piece covering in-chunk offsets [lo, hi] contributes
    ``psum[chunk, hi] - psum[chunk, lo-1]`` (0 when lo == 0) to its row.
    Prefix differences stay chunk-local, so fp32 error is bounded by one
    chunk's scan, not the whole stream's.
    """
    hi = psum[piece_chunk, piece_hi]
    lo = jnp.where(piece_lo > 0,
                   psum[piece_chunk, jnp.maximum(piece_lo - 1, 0)],
                   jnp.zeros((), dtype=psum.dtype))
    y = jnp.zeros((num_rows,), dtype=psum.dtype)
    return y.at[piece_row].add(hi - lo)


def seg_spmv(seg: "SegMatrix | tuple", x, *, num_rows: int | None = None,
             use_kernel: bool = False, interpret: bool = False,
             tile_c: int = 8):
    """Nonzero-balanced segmented SpMV: y = A @ x over the chunked stream.

    ``seg`` is a host :class:`SegMatrix` (or the equivalent array tuple
    ``(vals, cols, rows, piece_chunk, piece_lo, piece_hi, piece_row)``).
    Same contract as the other ops: the jnp scatter-add oracle is the
    default execution path; ``use_kernel=True`` runs the Pallas per-chunk
    prefix-sum kernel (``interpret=True`` on CPU) followed by the jit'd
    cross-chunk carry fix-up.
    """
    if isinstance(seg, SegMatrix):
        arrays = (seg.vals, seg.cols, seg.rows, seg.piece_chunk,
                  seg.piece_lo, seg.piece_hi, seg.piece_row)
        if num_rows is None:
            num_rows = seg.shape[0]
    else:
        arrays = seg
        if num_rows is None:
            raise ValueError("num_rows is required with raw seg arrays")
    vals, cols, rows, p_chunk, p_lo, p_hi, p_row = map(jnp.asarray, arrays)
    if use_kernel:
        def one(xb):
            psum = _seg_psum_pallas(vals, cols, xb, tile_c=tile_c,
                                    interpret=interpret)
            return _seg_fixup(psum, p_chunk, p_lo, p_hi, p_row, num_rows)
        if jnp.asarray(x).ndim == 2:    # multi-RHS: vmap the kernel path
            return jax.vmap(one, in_axes=1, out_axes=1)(jnp.asarray(x))
        return one(x)
    return seg_spmv_ref(vals, cols, rows, x, num_rows=num_rows)


def seg_from_csr(csr, *, chunk: int = SEG_CHUNK, lane: int = 128,
                 sublane: int = 8) -> SegMatrix:
    """Convert host CSRMatrix -> nonzero-balanced SegMatrix.

    ``chunk`` is rounded up to a ``lane`` multiple and the chunk count to a
    ``sublane`` multiple (TPU tiling).  Chunk boundaries come from
    :func:`repro.core.partition.nnz_chunk_starts` — the same element-level
    work-distribution definition the partition layer owns — so the kernel
    grid and the Emu-side accounting agree on what a chunk is.
    """
    L = ((max(chunk, 1) + lane - 1) // lane) * lane
    nnz = csr.nnz
    starts = nnz_chunk_starts(nnz, L)
    C = starts.shape[0] - 1
    C_pad = ((C + sublane - 1) // sublane) * sublane

    vals = np.zeros((C_pad, L), dtype=np.float32)
    cols = np.zeros((C_pad, L), dtype=np.int32)
    rows = np.zeros((C_pad, L), dtype=np.int32)
    row_of_nnz = np.repeat(np.arange(csr.nrows, dtype=np.int64),
                           np.diff(csr.row_ptr))
    flat_c = np.arange(nnz, dtype=np.int64) // L
    flat_l = np.arange(nnz, dtype=np.int64) % L
    vals[flat_c, flat_l] = csr.values
    cols[flat_c, flat_l] = csr.col_index
    rows[flat_c, flat_l] = row_of_nnz

    # Pieces: maximal same-row runs within a chunk.  A new piece starts at
    # every chunk boundary and every row change; padded tail slots are
    # excluded entirely (they carry value 0 anyway).
    if nnz:
        is_start = np.zeros(nnz, dtype=bool)
        is_start[0] = True
        is_start[1:] = row_of_nnz[1:] != row_of_nnz[:-1]
        is_start[np.arange(0, nnz, L)] = True
        p_start = np.flatnonzero(is_start)
        p_end = np.concatenate([p_start[1:] - 1, [nnz - 1]])
        piece_chunk = (p_start // L).astype(np.int32)
        piece_lo = (p_start % L).astype(np.int32)
        piece_hi = (p_end % L).astype(np.int32)
        piece_row = row_of_nnz[p_start].astype(np.int32)
    else:
        piece_chunk = piece_lo = piece_hi = piece_row = np.zeros(0, np.int32)
    return SegMatrix(shape=csr.shape, chunk=L, vals=vals, cols=cols,
                     rows=rows, piece_chunk=piece_chunk, piece_lo=piece_lo,
                     piece_hi=piece_hi, piece_row=piece_row, nnz=nnz)


@functools.partial(jax.jit, static_argnames=("num_splits", "num_rows"))
def _split_fixup(psum, piece_split, piece_chunk, piece_lo, piece_hi,
                 piece_row, *, num_splits: int, num_rows: int):
    """Carry fix-up into per-split partials: (NS, Cs, L) -> (NS, R).

    Same prefix-difference contract as :func:`_seg_fixup`, but each piece
    lands in its *split's* partial row sum — stage 2 reduces the split
    axis afterwards, so no scatter ever crosses a split boundary.
    """
    hi = psum[piece_split, piece_chunk, piece_hi]
    lo = jnp.where(piece_lo > 0,
                   psum[piece_split, piece_chunk,
                        jnp.maximum(piece_lo - 1, 0)],
                   jnp.zeros((), dtype=psum.dtype))
    part = jnp.zeros((num_splits, num_rows), dtype=psum.dtype)
    return part.at[piece_split, piece_row].add(hi - lo)


@functools.partial(jax.jit, static_argnames=("num_splits", "num_rows"))
def _split_flat_fixup(psum, pieces, *, num_splits: int, num_rows: int):
    """Flat-slab variant for the device path: psum is (NS*Cs, L) and
    ``pieces`` is the (P, 5) table [flat_chunk, lo, hi, row, split]."""
    p_chunk, p_lo, p_hi, p_row, p_split = (pieces[:, 0], pieces[:, 1],
                                           pieces[:, 2], pieces[:, 3],
                                           pieces[:, 4])
    hi = psum[p_chunk, p_hi]
    lo = jnp.where(p_lo > 0, psum[p_chunk, jnp.maximum(p_lo - 1, 0)],
                   jnp.zeros((), dtype=psum.dtype))
    part = jnp.zeros((num_splits, num_rows), dtype=psum.dtype)
    return part.at[p_split, p_row].add(hi - lo)


def split_spmv(spl: "SplitMatrix | tuple", x, *, num_rows: int | None = None,
               use_kernel: bool = False, interpret: bool = False,
               tile_c: int = 8):
    """Split-nnz two-stage SpMV: y = A @ x with split-K partials.

    ``spl`` is a host :class:`SplitMatrix` (or the equivalent array tuple
    ``(vals, cols, rows, piece_split, piece_chunk, piece_lo, piece_hi,
    piece_row)``).  The jnp scatter-add oracle is the default execution
    path; ``use_kernel=True`` runs stage 1 (Pallas per-chunk prefix sums
    on a 2-D (split, chunk-tile) grid), the jit'd per-split carry fix-up,
    and stage 2 (Pallas split-axis combine).
    """
    if isinstance(spl, SplitMatrix):
        arrays = (spl.vals, spl.cols, spl.rows, spl.piece_split,
                  spl.piece_chunk, spl.piece_lo, spl.piece_hi, spl.piece_row)
        if num_rows is None:
            num_rows = spl.shape[0]
    else:
        arrays = spl
        if num_rows is None:
            raise ValueError("num_rows is required with raw split arrays")
    vals, cols, rows, p_s, p_c, p_lo, p_hi, p_row = map(jnp.asarray, arrays)
    NS = int(vals.shape[0])
    if use_kernel:
        def one(xb):
            psum = _split_psum_pallas(vals, cols, xb, tile_c=tile_c,
                                      interpret=interpret)
            part = _split_fixup(psum, p_s, p_c, p_lo, p_hi, p_row,
                                num_splits=NS, num_rows=num_rows)
            return _split_combine_pallas(part, interpret=interpret)
        if jnp.asarray(x).ndim == 2:    # multi-RHS: vmap the kernel path
            return jax.vmap(one, in_axes=1, out_axes=1)(jnp.asarray(x))
        return one(x)
    return split_spmv_ref(vals, cols, rows, x, num_rows=num_rows)


def split_flat_spmv(vals, cols, rows, pieces, x, *, num_rows: int,
                    num_splits: int, use_kernel: bool = False,
                    interpret: bool = False, tile_c: int = 8):
    """Split SpMV over the *flattened* (NS*Cs, L) device slab.

    The distributed executor stacks every shard's slab into one uniform
    (C, L) operand, so the split structure travels in the (P, 5) int32
    piece table [flat_chunk, lo, hi, row, split] instead of a third slab
    axis (padded piece rows hold [0, 1, 0, 0, 0] — an exact zero).  The
    oracle path is the seg scatter-add on the flat slab (the split axis
    only partitions the stream); the kernel path is the two-stage
    pipeline sharing :func:`~repro.kernels.spmv_seg.seg_psum` for stage 1.
    """
    if use_kernel:
        def one(xb):
            psum = _seg_psum_pallas(vals, cols, xb, tile_c=tile_c,
                                    interpret=interpret)
            part = _split_flat_fixup(psum, pieces, num_splits=num_splits,
                                     num_rows=num_rows)
            return _split_combine_pallas(part, interpret=interpret)
        if jnp.asarray(x).ndim == 2:
            return jax.vmap(one, in_axes=1, out_axes=1)(jnp.asarray(x))
        return one(x)
    return seg_spmv_ref(vals, cols, rows, x, num_rows=num_rows)


def split_from_csr(csr, num_splits: int, *, chunk: int = SEG_CHUNK,
                   lane: int = 128, sublane: int = 8) -> SplitMatrix:
    """Convert host CSRMatrix -> split-nnz SplitMatrix.

    The seg chunk grid is cut into ``num_splits`` contiguous groups of
    ``Cs = ceil(C / num_splits)`` chunks; ``num_splits`` is clamped to
    [1, C] so the slab never holds an all-padding split.  Unlike
    :func:`seg_from_csr` the per-split chunk count is *not* sublane-padded
    — stage 1 adapts its tile to a divisor of Cs — so a small split count
    never multiplies the padding by NS.
    """
    L = ((max(chunk, 1) + lane - 1) // lane) * lane
    nnz = csr.nnz
    starts = nnz_chunk_starts(nnz, L)
    C = starts.shape[0] - 1
    ns = max(1, min(int(num_splits), C))
    Cs = (C + ns - 1) // ns

    vals = np.zeros((ns, Cs, L), dtype=np.float32)
    cols = np.zeros((ns, Cs, L), dtype=np.int32)
    rows = np.zeros((ns, Cs, L), dtype=np.int32)
    row_of_nnz = np.repeat(np.arange(csr.nrows, dtype=np.int64),
                           np.diff(csr.row_ptr))
    flat_g = np.arange(nnz, dtype=np.int64) // L
    s_idx = flat_g // Cs
    c_idx = flat_g % Cs
    l_idx = np.arange(nnz, dtype=np.int64) % L
    vals[s_idx, c_idx, l_idx] = csr.values
    cols[s_idx, c_idx, l_idx] = csr.col_index
    rows[s_idx, c_idx, l_idx] = row_of_nnz

    # Pieces: identical runs to seg_from_csr (cut at row changes and chunk
    # boundaries); the owning chunk is just re-indexed as (split, within).
    if nnz:
        is_start = np.zeros(nnz, dtype=bool)
        is_start[0] = True
        is_start[1:] = row_of_nnz[1:] != row_of_nnz[:-1]
        is_start[np.arange(0, nnz, L)] = True
        p_start = np.flatnonzero(is_start)
        p_end = np.concatenate([p_start[1:] - 1, [nnz - 1]])
        p_g = p_start // L
        piece_split = (p_g // Cs).astype(np.int32)
        piece_chunk = (p_g % Cs).astype(np.int32)
        piece_lo = (p_start % L).astype(np.int32)
        piece_hi = (p_end % L).astype(np.int32)
        piece_row = row_of_nnz[p_start].astype(np.int32)
    else:
        piece_split = piece_chunk = piece_lo = piece_hi = piece_row = \
            np.zeros(0, np.int32)
    return SplitMatrix(shape=csr.shape, chunk=L, num_splits=ns, vals=vals,
                       cols=cols, rows=rows, piece_split=piece_split,
                       piece_chunk=piece_chunk, piece_lo=piece_lo,
                       piece_hi=piece_hi, piece_row=piece_row, nnz=nnz)


def tile_from_csr(csr, *, bm: int | None = None,
                  bn: int | None = None) -> TileMatrix:
    """Convert host CSRMatrix -> bitmask-tiled :class:`TileMatrix`.

    Thin wrapper over :func:`repro.core.sparse_matrix.csr_to_tile`; tiles
    default to the fp32 native (8, 128) vector tile.  The format
    :func:`tile_spmv` executes — and the fifth per-shard kernel family
    the plan grid / lowering / autotuner select as ``"tile"``.
    """
    from repro.core.sparse_matrix import ELL_LANE, ELL_SUBLANE
    return csr_to_tile(csr, bm=ELL_SUBLANE if bm is None else bm,
                       bn=ELL_LANE if bn is None else bn)


def _tile_walk_tables(tile: TileMatrix):
    """Flatten the pointer grid into (counts, tid, bc) prefetch tables.

    K = max occupied tiles per block row; slots past ``counts[mb]`` clamp
    to a valid tile id (their contribution is masked in-kernel), so the
    index maps never read out of bounds.
    """
    counts = np.diff(tile.tile_ptr).astype(np.int32)        # (Mb,)
    Mb = counts.shape[0]
    T = tile.num_tiles
    K = max(int(counts.max()) if counts.size else 0, 1)
    tid = tile.tile_ptr[:-1, None].astype(np.int64) + np.arange(K)[None, :]
    tid = np.minimum(tid, max(T - 1, 0)).astype(np.int32)
    bc = (tile.tile_cols[tid.reshape(-1)].reshape(Mb, K)
          if T else np.zeros((Mb, K), np.int32))
    return counts, tid, bc


def tile_spmv(tile: TileMatrix, x, *, num_rows: int | None = None,
              use_kernel: bool = False, interpret: bool = False):
    """Bitmask-tiled SpMV: y = A @ x over the occupied-tile walk.

    Same contract as the other ops: the jnp gather/einsum/scatter oracle
    (:func:`repro.kernels.ref.tile_spmv_ref`) is the default execution
    path; ``use_kernel=True`` runs the Pallas scalar-prefetch tile walk
    (``interpret=True`` on CPU).  ``x`` may be a single (N,) vector or a
    multi-RHS block (N, B); the kernel path vmaps over the trailing axis.
    """
    if num_rows is None:
        num_rows = tile.shape[0]
    if not use_kernel or tile.num_tiles == 0:
        return tile_spmv_ref(jnp.asarray(tile.data),
                             jnp.asarray(tile.tile_rows),
                             jnp.asarray(tile.tile_cols),
                             jnp.asarray(x), num_rows=num_rows)
    counts, tid, bc = _tile_walk_tables(tile)
    bn = tile.bn
    xa = jnp.asarray(x)
    n = xa.shape[0]
    Nb = max(-(-n // bn), 1)
    pad = [(0, Nb * bn - n)] + [(0, 0)] * (xa.ndim - 1)
    xp = jnp.pad(xa, pad)

    def one(xb):
        y = _tile_walk_pallas(jnp.asarray(tile.data), jnp.asarray(counts),
                              jnp.asarray(tid), jnp.asarray(bc), xb,
                              interpret=interpret)
        return y[:num_rows]
    if xa.ndim == 2:
        return jax.vmap(one, in_axes=1, out_axes=1)(xp)
    return one(xp)


def tile_flat_spmv(data, xcols, trows, x, *, num_rows: int,
                   use_kernel: bool = False, interpret: bool = False):
    """Tile SpMV over the *flat pre-gathered* device operands.

    The distributed executor has no block grid to index — x lives in the
    remapped augmented [local ++ halo] buffer — so each tile carries its
    per-lane x positions ``xcols`` (T, bn) and block row ``trows`` (T,)
    (padding tiles point past the last block row and drop).  The oracle
    path is :func:`repro.kernels.ref.tile_flat_spmv_ref`; the kernel path
    gathers x lanes with jnp (like the HYB overflow scatter) and runs the
    dense per-tile FMA stream through the Pallas ``tile_contrib`` kernel.
    """
    T, bm, bn = data.shape
    if not use_kernel:
        return tile_flat_spmv_ref(data, xcols, trows, x, num_rows=num_rows)
    Mb = max(-(-num_rows // bm), 1)

    def one(xb):
        xg = jnp.take(xb, xcols, axis=0)                 # (T, bn)
        contrib = _tile_contrib_pallas(data, xg, interpret=interpret)
        out = jnp.zeros((Mb, bm), dtype=contrib.dtype)
        out = out.at[trows].add(contrib, mode="drop")
        return out.reshape(Mb * bm)[:num_rows]
    if jnp.asarray(x).ndim == 2:
        return jax.vmap(one, in_axes=1, out_axes=1)(jnp.asarray(x))
    return one(jnp.asarray(x))


def bell_from_bcsr(bcsr) -> tuple[np.ndarray, np.ndarray]:
    """Deprecated: convert host BcsrMatrix -> padded Block-ELL arrays.

    K = max blocks per block-row; padded slots hold zero blocks and bcol 0,
    which the kernels treat as a no-op contribution.  Block-ELL is now a
    special case of the bitmask-tiled family — build a
    :class:`TileMatrix` with :func:`tile_from_csr` instead (pointer-grid
    walk, no padded slots, occupancy bitmask).
    """
    from repro.core.spmv import _warn_deprecated
    _warn_deprecated("bell_from_bcsr", "repro.kernels.ops.tile_from_csr")
    Mb = bcsr.block_row_ptr.shape[0] - 1
    bm, bn = bcsr.block_shape
    per_row = np.diff(bcsr.block_row_ptr)
    K = max(int(per_row.max()) if Mb else 1, 1)
    blocks = np.zeros((Mb, K, bm, bn), dtype=bcsr.blocks.dtype)
    bcols = np.zeros((Mb, K), dtype=np.int32)
    for r in range(Mb):
        lo, hi = int(bcsr.block_row_ptr[r]), int(bcsr.block_row_ptr[r + 1])
        blocks[r, : hi - lo] = bcsr.blocks[lo:hi]
        bcols[r, : hi - lo] = bcsr.block_cols[lo:hi]
    return blocks, bcols
