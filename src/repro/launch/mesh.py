"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
carries only DP traffic (gradient all-reduce, optionally int8-compressed)
since it maps to the slower inter-pod links.
"""
from __future__ import annotations

import jax


def auto_axis_types(n: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` only exists on jax >= 0.5; earlier versions
    (no explicit-sharding mode) take no kwarg and behave as Auto.
    """
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_host_mesh(model_parallel: int | None = None):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    mp = model_parallel or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         **auto_axis_types(2))
