"""The lowered SpMV program IR: per-shard heterogeneous kernels, one executor.

The paper's hot-spot result (§IV-D) is fundamentally *local*: sparsity
structure differs shard-to-shard, so one global (layout, kernel) choice
under-serves skewed shards while over-paying on regular ones — the
per-region strategy selection of feature-based SpMV optimization (Elafrou
et al., 2017), resolved per-nodelet as the Emu programming studies
recommend (Hein et al.).  This module is the single lowering path that
makes that selectable:

* :func:`lower` — ``lower(csr, plan)`` turns a host CSR matrix plus an
  :class:`~repro.core.spmv.SpmvPlan` into an :class:`SpmvProgram`: the
  reordered matrix, partition, vector layouts, exact traffic accounting,
  and one :class:`ShardStage` per shard.  Each stage independently holds
  an ``ell`` slab, a ``seg`` chunk stream, a ``hyb`` capped-ELL + COO
  overflow pair, a ``split`` two-stage split-nnz slab, or a ``tile``
  bitmask-tiled pointer grid (``plan.shard_kernels``); the exchange
  prologue
  (all-gather vs halo all-to-all) is part of the program, not of any
  particular executor.
* :func:`relower` — rebuilds **only** the stages whose kernel changed
  (same base: layout/distribution/reordering), sharing every other stage
  with the old program; exchange-policy changes (uniform or per-shard)
  share *all* stages.  This is the per-shard double-buffered swap the
  serving rebalancer uses for hot-shard-only re-plans
  (``serve/rebalance.py``).
* :func:`execute` — one entry point, three backends:

  - ``"numpy"``: the exact host oracle (float64, bitwise-stable batched
    multi-RHS) — the serving path of ``SparseMatrixEngine`` and the
    correctness reference;
  - ``"shard_map"``: the device executor.  One ``shard_map`` program runs
    every shard; per-shard kernel dispatch is a ``lax.switch`` over the
    stage's kernel id, so heterogeneous programs lower to a single SPMD
    computation.  This collapses the old ``make_spmv_fn`` /
    ``make_seg_spmv_fn`` / ``make_halo_spmv_fn`` triplet (kept as thin
    deprecated shims in ``core/spmv.py``);
  - ``"emu"``: the Emu timeline probe (:func:`probe_program`) — the
    migratory-thread cost of the same (matrix, partition, layout) walk,
    which is what the autotuner's simulator re-ranking runs.

Every backend consumes the same :class:`SpmvProgram`, so the numpy
oracle, the TPU program, and the Emu model cannot drift apart.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

from .emu import EmuConfig, EmuResult, run_spmv
from .layout import VectorLayout, make_layout
from .migration import TrafficReport, count_migrations, remote_access_matrix
from .partition import Partition, make_partition
from .reorder import reordering_permutation
from .plan import split_meta
from .sparse_matrix import CSRMatrix, ELL_LANE, ELL_SUBLANE, EllMatrix, \
    SegMatrix, SplitMatrix, TileMatrix, csr_to_ell
from .spmv import PLAN_KERNELS, SpmvPlan
from repro.kernels import ops as kops

__all__ = ["ShardStage", "SpmvProgram", "lower", "relower", "execute",
           "make_program_spmv_fn", "probe_program", "gather_b",
           "PROGRAM_KERNELS"]

#: Kernels a shard stage may select — alias of the single definition in
#: ``spmv.PLAN_KERNELS`` (tie-break preference order; the ``lax.switch``
#: branch ids in the device executor follow this order).
PROGRAM_KERNELS = PLAN_KERNELS


@dataclasses.dataclass(frozen=True)
class ShardStage:
    """One shard's stage of a lowered program: its kernel + device payload.

    ``kernel`` selects the format actually stored: ``"ell"`` (uncapped
    padded slab) and ``"hyb"`` (p95-capped slab + COO overflow, see
    :func:`~repro.kernels.ops.hyb_from_csr`) populate ``ell``; ``"seg"``
    populates ``seg``; ``"split"`` populates ``split`` (the split-nnz
    two-stage slab, NS partial accumulators + combine); ``"tile"``
    populates ``tile`` (the bitmask-tiled pointer grid over dense
    (8, 128) tiles).  ``rows``/``row_offset`` locate the shard's row
    range in the program's (reordered) matrix.
    """

    shard: int
    kernel: str                    # "ell" | "seg" | "hyb" | "split" | "tile"
    rows: int                      # true row count
    row_offset: int                # absolute first row
    nnz: int
    ell: EllMatrix | None = None   # kernel in ("ell", "hyb")
    seg: SegMatrix | None = None   # kernel == "seg"
    split: SplitMatrix | None = None   # kernel == "split"
    tile: TileMatrix | None = None     # kernel == "tile"


def _shard_max_row_nnz(A: CSRMatrix, part: Partition, p: int) -> int:
    r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
    if r1 <= r0:
        return 0
    return int((A.row_ptr[r0 + 1: r1 + 1] - A.row_ptr[r0: r1]).max())


def _resolved_split_count(A: CSRMatrix, part: Partition, p: int,
                          requested: int) -> int:
    """The split count shard p actually lowers with: the plan's request
    (or the :func:`~repro.core.plan.split_meta` policy when the request
    is 0/absent), clamped to the shard's chunk count exactly as
    :func:`~repro.kernels.ops.split_from_csr` clamps it — so
    :func:`relower` can compare effective counts, not raw requests."""
    r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
    nnz_p = int(A.row_ptr[r1] - A.row_ptr[r0])
    L = ((kops.SEG_CHUNK + ELL_LANE - 1) // ELL_LANE) * ELL_LANE
    C = max(-(-nnz_p // L), 1)
    ns = requested if requested > 0 else \
        split_meta(nnz_p, _shard_max_row_nnz(A, part, p))
    return max(1, min(int(ns), C))


def _build_stage(A: CSRMatrix, part: Partition, p: int,
                 kernel: str, split_count: int = 0) -> ShardStage:
    r0, r1 = int(part.starts[p]), int(part.starts[p + 1])
    sub = part.shard_csr(A, p)
    ell = seg = split = tile = None
    if kernel == "ell":
        ell = csr_to_ell(sub)
        if ell.overflow_vals.size:
            raise AssertionError("uncapped ELL conversion cannot overflow")
    elif kernel == "hyb":
        ell = kops.hyb_from_csr(sub)
    elif kernel == "seg":
        seg = kops.seg_from_csr(sub)
    elif kernel == "split":
        ns = _resolved_split_count(A, part, p, split_count)
        split = kops.split_from_csr(sub, ns)
    elif kernel == "tile":
        tile = kops.tile_from_csr(sub)
    else:
        raise ValueError(f"unknown shard kernel {kernel!r}; expected one of "
                         f"{PROGRAM_KERNELS}")
    return ShardStage(shard=p, kernel=kernel, rows=r1 - r0, row_offset=r0,
                      nnz=sub.nnz, ell=ell, seg=seg, split=split, tile=tile)


@dataclasses.dataclass
class SpmvProgram:
    """A lowered, device-ready SpMV program + its traffic accounting.

    This is the object every executor backend consumes (and what
    ``build_distributed`` has always returned — ``DistributedSpmv`` is a
    deprecated alias).  The legacy stacked-slab views (``data``/``cols``,
    ``seg_*``) are kept as lazily-built properties for old callers; new
    code should read ``stages``.
    """

    plan: SpmvPlan
    matrix: CSRMatrix                 # reordered matrix (host)
    partition: Partition
    x_layout: VectorLayout
    b_layout: VectorLayout
    rows_per_shard: np.ndarray        # true row counts (S,)
    row_offset: np.ndarray            # absolute first row per shard (S,)
    traffic: TrafficReport
    shard_traffic: np.ndarray         # (S, S) x-elements moved p<-q
    stages: tuple                     # (S,) ShardStage
    # Symmetric permutation applied by plan.reordering: perm[old] = new.
    # None for reordering="none"; the numpy executor uses it to accept and
    # return vectors in the caller's original index order.
    perm: np.ndarray | None = None

    def shard_kernels(self) -> tuple:
        """The per-shard kernels this program was lowered with."""
        return tuple(st.kernel for st in self.stages)

    def x_to_device(self, x: np.ndarray) -> np.ndarray:
        return self.x_layout.to_sharded(x)

    def b_from_device(self, b_shards: np.ndarray) -> np.ndarray:
        return self.b_layout.from_sharded(b_shards)

    # -- legacy stacked-slab views (deprecated; read ``stages`` instead) ----

    @property
    def data(self) -> np.ndarray:
        """(S, rows_pad, W) stacked *uncapped* ELL slabs (legacy view)."""
        return self._ell_stack()[0]

    @property
    def cols(self) -> np.ndarray:
        """(S, rows_pad, W) stacked global ELL column ids (legacy view)."""
        return self._ell_stack()[1]

    def _ell_stack(self):
        cached = getattr(self, "_ell_stack_cache", None)
        if cached is not None:
            return cached
        slabs = []
        for st in self.stages:
            if st.kernel == "ell":
                slabs.append(st.ell)
            else:
                sub = self.matrix.row_slice(st.row_offset,
                                            st.row_offset + st.rows)
                slabs.append(csr_to_ell(sub))
        rows_pad = max(s.data.shape[0] for s in slabs)
        width = max(s.width for s in slabs)
        S = self.plan.num_shards
        data = np.zeros((S, rows_pad, width), dtype=np.float32)
        cols = np.zeros((S, rows_pad, width), dtype=np.int32)
        for p, s in enumerate(slabs):
            r, w = s.data.shape
            data[p, :r, :w] = s.data
            cols[p, :r, :w] = s.cols
        self._ell_stack_cache = (data, cols)
        return self._ell_stack_cache

    @property
    def seg_vals(self):
        s = self._seg_stack()
        return None if s is None else s["seg_vals"]

    @property
    def seg_cols(self):
        s = self._seg_stack()
        return None if s is None else s["seg_cols"]

    @property
    def seg_rows(self):
        s = self._seg_stack()
        return None if s is None else s["seg_rows"]

    @property
    def seg_pieces(self):
        s = self._seg_stack()
        return None if s is None else s["seg_pieces"]

    def _seg_stack(self):
        """Legacy stacked seg slabs (dummy-row piece padding), uniform-seg
        programs only — matches the pre-IR ``build_distributed`` contract."""
        if any(st.kernel != "seg" for st in self.stages):
            return None
        cached = getattr(self, "_seg_stack_cache", None)
        if cached is None:
            cached = _stack_seg_legacy([st.seg for st in self.stages],
                                       self.rows_per_shard)
            self._seg_stack_cache = cached
        return cached


def _stack_seg_legacy(segs, rows_per_shard) -> dict:
    """Stacked per-shard SegMatrix slabs, padded to common shapes.

    Column ids stay global (the allgather path gathers the full x); row ids
    are shard-local.  Piece padding targets the per-shard dummy row
    (``rows_pad``) with (lo=1, hi=0) so ``psum[c, hi] - psum[c, lo-1]``
    evaluates to an exact zero for padded entries.
    """
    S = len(segs)
    C_pad = max(s.num_chunks for s in segs)
    L = segs[0].chunk
    P_pad = max(max(s.n_pieces for s in segs), 1)
    rows_pad = int(np.asarray(rows_per_shard).max())
    vals = np.zeros((S, C_pad, L), dtype=np.float32)
    cols = np.zeros((S, C_pad, L), dtype=np.int32)
    rows = np.zeros((S, C_pad, L), dtype=np.int32)
    pieces = np.zeros((S, P_pad, 4), dtype=np.int32)
    pieces[:, :, 1] = 1                       # (lo=1, hi=0) -> exact zero
    pieces[:, :, 3] = rows_pad                # dummy row, sliced off later
    for p, s in enumerate(segs):
        vals[p, : s.num_chunks] = s.vals
        cols[p, : s.num_chunks] = s.cols
        rows[p, : s.num_chunks] = s.rows
        n = s.n_pieces
        pieces[p, :n, 0] = s.piece_chunk
        pieces[p, :n, 1] = s.piece_lo
        pieces[p, :n, 2] = s.piece_hi
        pieces[p, :n, 3] = s.piece_row
    return dict(seg_vals=vals, seg_cols=cols, seg_rows=rows,
                seg_pieces=pieces)


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------

def lower(csr: CSRMatrix, plan: SpmvPlan) -> SpmvProgram:
    """Lower (matrix, plan) to a per-shard-staged :class:`SpmvProgram`.

    The reordering permutation, partition, vector layouts and exact
    migration accounting are computed once here; each shard then gets the
    stage its (per-shard) kernel calls for.  ``plan.shard_kernels=None``
    lowers the uniform program (every stage uses ``plan.kernel``) — which
    is also how pre-per-shard plans deserialize from legacy JSON.  For
    ``split`` stages the split count comes from ``plan.split_counts`` (0
    or ``None`` = ask :func:`~repro.core.plan.split_meta`), clamped to
    the shard's chunk count.
    """
    if csr.nrows != csr.ncols:
        raise ValueError("paper applies symmetric reorderings to square "
                         "matrices")
    perm = None
    A = csr
    if plan.reordering != "none":
        perm = reordering_permutation(csr, plan.reordering, seed=plan.seed,
                                      parts=plan.num_shards)
        A = csr.permuted(perm, perm)
    part = make_partition(A, plan.num_shards, plan.distribution)
    x_layout = make_layout(plan.layout, A.ncols, plan.num_shards)
    b_layout = make_layout(plan.layout, A.nrows, plan.num_shards)
    kernels = plan.resolved_shard_kernels()
    split_counts = plan.resolved_split_counts()
    stages = tuple(_build_stage(A, part, p, kernels[p], split_counts[p])
                   for p in range(plan.num_shards))
    return SpmvProgram(
        plan=plan, matrix=A, partition=part, x_layout=x_layout,
        b_layout=b_layout,
        rows_per_shard=part.rows_per_shard().astype(np.int64),
        row_offset=part.starts[:-1].astype(np.int64),
        traffic=count_migrations(A, part, x_layout, b_layout),
        shard_traffic=remote_access_matrix(A, part, x_layout),
        stages=stages, perm=perm)


#: Plan fields that force a full :func:`lower` when they change.  The
#: exchange (uniform or per-shard) is *not* one of them: stages, the
#: partition and the traffic accounting are exchange-independent — only
#: the executor's prologue and column remaps move, and those are rebuilt
#: lazily per program object — so an exchange flip relowers with every
#: stage shared (the rebalancer's cheapest partial move).
_BASE_FIELDS = ("layout", "distribution", "reordering", "num_shards", "seed")


def relower(program: SpmvProgram, new_plan: SpmvPlan) -> SpmvProgram:
    """Re-lower only the stages whose kernel (or effective split count)
    changed, keeping the same base.

    The base (layout / distribution / reordering / shards / seed) must
    match the incumbent plan — everything structural (matrix, partition,
    layouts, traffic) is shared, and unchanged stages are the *same
    objects* as the old program's.  Exchange policy changes (uniform or
    ``shard_exchanges``) share **all** stages: the exchange only selects
    the executor prologue.  This is what makes the serving rebalancer's
    hot-shard-only swap cheap: only the re-kerneled shards pay a slab
    rebuild, and the old program keeps serving until the new one
    validates.
    """
    old_plan = program.plan
    for f in _BASE_FIELDS:
        if getattr(new_plan, f) != getattr(old_plan, f):
            raise ValueError(
                f"relower only changes shard kernels; base field {f!r} "
                f"differs ({getattr(old_plan, f)!r} -> "
                f"{getattr(new_plan, f)!r}) — use lower()")
    old_k = old_plan.resolved_shard_kernels()
    new_k = new_plan.resolved_shard_kernels()
    new_sc = new_plan.resolved_split_counts()

    def unchanged(p: int) -> bool:
        if new_k[p] != old_k[p]:
            return False
        if new_k[p] != "split":
            return True
        # split stages also share when the *effective* (clamped/policy)
        # split count is unchanged — a different request that clamps to
        # the same NS must not trigger a rebuild.
        want = _resolved_split_count(program.matrix, program.partition, p,
                                     new_sc[p])
        return program.stages[p].split.num_splits == want

    stages = tuple(
        program.stages[p] if unchanged(p)
        else _build_stage(program.matrix, program.partition, p, new_k[p],
                          new_sc[p])
        for p in range(new_plan.num_shards))
    return dataclasses.replace(program, plan=new_plan, stages=stages)


# --------------------------------------------------------------------------
# numpy executor (exact host oracle; the serving path)
# --------------------------------------------------------------------------

def _apply_perm(v: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """v in old order -> v in new order (perm[old] = new)."""
    out = np.empty_like(v)
    out[perm] = v
    return out


def _execute_numpy(program: SpmvProgram, x: np.ndarray) -> np.ndarray:
    """y = A @ x on one host, caller index order, float64.

    ``x`` may be a single (N,) vector or a multi-RHS block (N, B); the
    result matches ((M,) or (M, B)).  The block is held batch-major so
    every per-row reduction runs over the last *contiguous* axis
    regardless of B — numpy then applies the same pairwise-summation tree
    for every batch width, and the scatter formats (seg rows, hyb
    overflow) loop per RHS so ``np.add.at`` accumulates in identical
    index order per column.  Column b of a batched call is therefore
    *bitwise* equal to the per-vector call on ``x[:, b]``.
    """
    if x.shape[0] != program.matrix.ncols:
        raise ValueError(f"x has {x.shape[0]} elements, matrix expects "
                         f"{program.matrix.ncols}")
    if x.ndim == 1:
        return _execute_numpy_block(program, x[:, None])[:, 0]
    if x.ndim != 2:
        raise ValueError(f"x must be (N,) or (N, B), got shape {x.shape}")
    return _execute_numpy_block(program, x)


def _execute_numpy_block(program: SpmvProgram, x: np.ndarray) -> np.ndarray:
    B = x.shape[1]
    xr = x if program.perm is None else _apply_perm(x, program.perm)
    x_pad = np.zeros((B, program.x_layout.padded_length()), dtype=np.float64)
    x_pad[:, : program.matrix.ncols] = xr.T

    y = np.zeros((B, program.matrix.nrows), dtype=np.float64)
    for st in program.stages:
        if st.rows == 0:
            continue
        o, r = st.row_offset, st.rows
        if st.kernel == "seg":
            seg = st.seg
            contrib = seg.vals.astype(np.float64) * x_pad[:, seg.cols]
            yp = np.zeros((B, r))
            for b in range(B):            # padded slots: row 0, val 0
                np.add.at(yp[b], seg.rows, contrib[b])
            y[:, o:o + r] = yp
        elif st.kernel == "split":
            spl = st.split                # two-stage: partials, then combine
            contrib = spl.vals.astype(np.float64) * x_pad[:, spl.cols]
            s_ix = np.broadcast_to(
                np.arange(spl.num_splits)[:, None, None], spl.rows.shape)
            partial = np.zeros((B, spl.num_splits, r))
            for b in range(B):            # padded slots: row 0, val 0
                np.add.at(partial[b], (s_ix, spl.rows), contrib[b])
            y[:, o:o + r] = partial.sum(axis=1)
        elif st.kernel == "tile":
            tl = st.tile                  # dense tile stream, block scatter
            N = tl.shape[1]
            Nb = max(-(-N // tl.bn), 1)
            xw = np.zeros((B, Nb * tl.bn))
            xw[:, :N] = x_pad[:, :N]
            gathered = xw.reshape(B, Nb, tl.bn)[:, tl.tile_cols]  # (B,T,bn)
            # Contiguous last-axis reduction (like the ELL slab) keeps
            # column b of a batched call bitwise-equal to the per-vector
            # call; the per-b scatter then fixes the accumulation order.
            contrib = (tl.data.astype(np.float64)[None]
                       * gathered[:, :, None, :]).sum(axis=3)     # (B,T,bm)
            Mb = max(-(-r // tl.bm), 1)
            yp = np.zeros((B, Mb, tl.bm))
            for b in range(B):
                np.add.at(yp[b], tl.tile_rows, contrib[b])
            y[:, o:o + r] = yp.reshape(B, Mb * tl.bm)[:, :r]
        else:                             # "ell" / "hyb"
            e = st.ell
            slab = e.data.astype(np.float64) * x_pad[:, e.cols]
            y[:, o:o + r] = np.ascontiguousarray(slab).sum(axis=2)[:, :r]
            if e.overflow_vals.size:      # hyb COO tail
                ovals = e.overflow_vals.astype(np.float64)
                for b in range(B):
                    np.add.at(y[b], o + e.overflow_rows,
                              ovals * x_pad[b, e.overflow_cols])
    yt = y.T
    return yt if program.perm is None else yt[program.perm]


# --------------------------------------------------------------------------
# device executor: one shard_map for every program (the old three-way
# make_spmv_fn / make_seg_spmv_fn / make_halo_spmv_fn collapse to this)
# --------------------------------------------------------------------------

def _halo_tables(program: SpmvProgram):
    """Structure-level exchange tables (format-independent, per policy).

    For a reader p with exchange policy ``"halo"``, shard q sends exactly
    the x entries p's stored non-zeros read from q (zero-valued stored
    entries excluded — they contribute nothing, so they must not widen
    the halo).  For a reader with policy ``"allgather"`` (per-shard mixed
    programs), q sends *all* of its owned real columns — full replication
    for that shard, delivered through the same single ``all_to_all`` that
    serves the halo readers.  Returns ``(send_idx, pos_map, H)``:
    ``send_idx[q, p]`` are sender-local indices (padded to H) and
    ``pos_map[p, g]`` the augmented-buffer position of global id g on
    reader p (the buffer is ``[x_local ++ recv]``, ``per + q * H +
    slot``).
    """
    A, part, lay = program.matrix, program.partition, program.x_layout
    S = part.num_shards
    per = lay.padded_length() // S
    policies = program.plan.resolved_shard_exchanges()
    rows_of_nnz = np.repeat(np.arange(A.nrows), np.diff(A.row_ptr))
    home = part.owner_of_rows(A.nrows)[rows_of_nnz]
    owners = lay.owner_of(A.col_index)
    rem = (A.values != 0) & (owners != home)
    needed = [[np.zeros(0, np.int64)] * S for _ in range(S)]
    if rem.any():
        key = home[rem].astype(np.int64) * A.ncols + \
            A.col_index[rem].astype(np.int64)
        uniq = np.unique(key)             # sorted: per reader, by global id
        up, ucol = uniq // A.ncols, uniq % A.ncols
        uq = lay.owner_of(ucol)
        for p in range(S):
            if policies[p] != "halo":
                continue
            for q in range(S):
                needed[p][q] = ucol[(up == p) & (uq == q)]
    if any(e == "allgather" for e in policies):
        col_owner = lay.owner_of(np.arange(A.ncols))
        owned = [np.flatnonzero(col_owner == q).astype(np.int64)
                 for q in range(S)]
        for p in range(S):
            if policies[p] == "allgather":
                for q in range(S):
                    if q != p:
                        needed[p][q] = owned[q]
    H = max(max((ids.size for row in needed for ids in row), default=1), 1)
    send_idx = np.zeros((S, S, H), dtype=np.int32)
    pos_map = np.zeros((S, A.ncols), dtype=np.int32)
    for p in range(S):
        for q in range(S):
            ids = needed[p][q]
            if ids.size:
                send_idx[q, p, : ids.size] = lay.local_index(ids)
                pos_map[p, ids] = per + q * H + np.arange(ids.size)
    return send_idx, pos_map, H


def _remap_cols(cols: np.ndarray, vals: np.ndarray, lay: VectorLayout,
                p: int, pos_map_p: np.ndarray) -> np.ndarray:
    """Global col ids -> positions in shard p's [x_local ++ recv] buffer.

    Zero-valued slots (padding, stored explicit zeros) keep position 0:
    x_local[0] times value 0 contributes nothing either way."""
    own = lay.owner_of(cols)
    out = np.where(own == p, lay.local_index(cols), 0).astype(np.int32)
    m = (own != p) & (vals != 0)
    if m.any():
        out[m] = pos_map_p[cols[m]]
    return out


def _row_remote_flags(program: SpmvProgram) -> np.ndarray:
    """(nrows,) bool — rows with >= 1 stored non-zero reading a remote x
    entry under the program's layout.  These are the rows whose partial
    products must wait for the exchange; every other row is computable
    from ``x_local`` alone (the pipelined executor's local slice)."""
    A, part, lay = program.matrix, program.partition, program.x_layout
    rows_of_nnz = np.repeat(np.arange(A.nrows), np.diff(A.row_ptr))
    home = part.owner_of_rows(A.nrows)[rows_of_nnz]
    owners = lay.owner_of(A.col_index)
    rem = (A.values != 0) & (owners != home)
    flags = np.zeros(A.nrows, dtype=bool)
    flags[rows_of_nnz[rem]] = True
    return flags


def _row_masked_csr(sub: CSRMatrix, keep: np.ndarray) -> CSRMatrix:
    """Same-shape CSR with the entries of non-kept rows dropped.

    Row count (and shard-local row ids) are preserved so the masked
    stage scatters into the same (R,) output as the full stage; only the
    masked-out rows lower to empty rows."""
    if keep.all():
        return sub
    per_row = np.diff(sub.row_ptr)
    rows = np.repeat(np.arange(sub.nrows), per_row)
    m = keep[rows]
    counts = np.bincount(rows[m], minlength=sub.nrows)
    row_ptr = np.zeros(sub.nrows + 1, dtype=np.int64)
    np.cumsum(counts, out=row_ptr[1:])
    return CSRMatrix(shape=sub.shape, values=sub.values[m],
                     col_index=sub.col_index[m], row_ptr=row_ptr)


def _masked_stage(sub: CSRMatrix, keep: np.ndarray,
                  st: ShardStage) -> ShardStage:
    """Lower one row-slice (local or remote) of a shard into the same
    kernel family as its full stage — the executor-level stage split the
    pipelined schedule runs."""
    m = _row_masked_csr(sub, keep)
    ell = seg = split = tile = None
    if st.kernel == "ell":
        ell = csr_to_ell(m)
    elif st.kernel == "hyb":
        ell = kops.hyb_from_csr(m)
    elif st.kernel == "seg":
        seg = kops.seg_from_csr(m)
    elif st.kernel == "tile":
        tile = kops.tile_from_csr(m)         # row count preserved: same grid
    else:                                    # "split"
        L = ((kops.SEG_CHUNK + ELL_LANE - 1) // ELL_LANE) * ELL_LANE
        C = max(-(-m.nnz // L), 1)
        ns = max(1, min(st.split.num_splits, C))
        split = kops.split_from_csr(m, ns)
    return ShardStage(shard=st.shard, kernel=st.kernel, rows=st.rows,
                      row_offset=st.row_offset, nnz=m.nnz, ell=ell, seg=seg,
                      split=split, tile=tile)


def _stack_stages(stages, R: int, remap) -> dict:
    """Stack a per-shard stage list into one uniform-shape operand set.

    Every format payload exists for every shard (zeros where unused) so
    the per-shard ``lax.switch`` can trace each branch with uniform
    shapes.  Split stages flatten their (NS, Cs, L) slab into the shared
    seg (C, L) operand — the split structure travels in the piece table,
    widened to 5 columns [flat_chunk, lo, hi, row, split] (padded rows
    [0, 1, 0, 0, 0] are an exact zero).  Tile stages expand their
    per-tile block-column id into per-lane x positions (``tile_xcol``) —
    the augmented exchange buffer has no block grid to index, so the
    remap runs on the expanded lanes, with *nonzero lane occupancy* as
    the remap values (dead / stored-zero-only lanes keep position 0 and
    contribute exact zeros); padding tiles point their block row
    (``tile_brow``) one past the last block so the scatter drops them.
    ``remap(cols, vals, p)`` maps global column ids into the buffer this
    set's kernel pass reads.
    """
    S = len(stages)
    ells = [st.ell for st in stages if st.ell is not None]
    W = max((e.width for e in ells), default=ELL_LANE)
    O = max((e.overflow_vals.size for e in ells), default=0)
    O = max(O, 1)
    segs = [st.seg for st in stages if st.seg is not None]
    spls = [st.split for st in stages if st.split is not None]
    slabs = segs + spls
    L = slabs[0].chunk if slabs else kops.SEG_CHUNK
    if slabs and any(s.chunk != L for s in slabs):
        raise AssertionError("seg/split stages must share one chunk size")
    # split slabs flatten to ns * Cs chunks; round the shared chunk count
    # up to the sublane so the Pallas scan's tiling always divides it.
    C = max(max((s.num_chunks for s in segs), default=ELL_SUBLANE),
            max((s.num_splits * s.chunks_per_split for s in spls),
                default=ELL_SUBLANE))
    C = _round_up(C, ELL_SUBLANE)
    NS = max((s.num_splits for s in spls), default=1)
    Pp = max(max((s.n_pieces for s in segs), default=0),
             max((s.n_pieces for s in spls), default=0))
    Pp = max(Pp, 1)

    ell_data = np.zeros((S, R, W), dtype=np.float32)
    ell_cols = np.zeros((S, R, W), dtype=np.int32)
    ovf_rows = np.zeros((S, O), dtype=np.int32)
    ovf_cols = np.zeros((S, O), dtype=np.int32)
    ovf_vals = np.zeros((S, O), dtype=np.float32)
    seg_vals = np.zeros((S, C, L), dtype=np.float32)
    seg_cols = np.zeros((S, C, L), dtype=np.int32)
    seg_rows = np.zeros((S, C, L), dtype=np.int32)
    seg_pieces = np.zeros((S, Pp, 5), dtype=np.int32)
    seg_pieces[:, :, 1] = 1           # (lo=1, hi=0, row=0, split=0) -> zero
    tiles = [st.tile for st in stages if st.tile is not None]
    t_bm = tiles[0].bm if tiles else ELL_SUBLANE
    t_bn = tiles[0].bn if tiles else ELL_LANE
    if any((t.bm, t.bn) != (t_bm, t_bn) for t in tiles):
        raise AssertionError("tile stages must share one tile shape")
    Tp = max(max((t.num_tiles for t in tiles), default=0), 1)
    Rb = -(-R // t_bm)
    tile_data = np.zeros((S, Tp, t_bm, t_bn), dtype=np.float32)
    tile_xcol = np.zeros((S, Tp, t_bn), dtype=np.int32)
    tile_brow = np.full((S, Tp), Rb, dtype=np.int32)   # pad: drops in scatter

    for p, st in enumerate(stages):
        if st.ell is not None:
            e = st.ell
            r, w = e.data.shape
            ell_data[p, :r, :w] = e.data
            ell_cols[p, :r, :w] = remap(e.cols, e.data, p)
            n = e.overflow_vals.size
            if n:
                ovf_rows[p, :n] = e.overflow_rows
                ovf_cols[p, :n] = remap(e.overflow_cols, e.overflow_vals, p)
                ovf_vals[p, :n] = e.overflow_vals
        if st.seg is not None:
            s = st.seg
            seg_vals[p, : s.num_chunks] = s.vals
            seg_cols[p, : s.num_chunks] = remap(s.cols, s.vals, p)
            seg_rows[p, : s.num_chunks] = s.rows
            n = s.n_pieces
            seg_pieces[p, :n, 0] = s.piece_chunk
            seg_pieces[p, :n, 1] = s.piece_lo
            seg_pieces[p, :n, 2] = s.piece_hi
            seg_pieces[p, :n, 3] = s.piece_row
        if st.split is not None:
            s = st.split
            ns, Cs = s.num_splits, s.chunks_per_split
            fv = s.vals.reshape(ns * Cs, L)
            seg_vals[p, : ns * Cs] = fv
            seg_cols[p, : ns * Cs] = remap(s.cols.reshape(ns * Cs, L), fv, p)
            seg_rows[p, : ns * Cs] = s.rows.reshape(ns * Cs, L)
            n = s.n_pieces
            seg_pieces[p, :n, 0] = s.piece_split * Cs + s.piece_chunk
            seg_pieces[p, :n, 1] = s.piece_lo
            seg_pieces[p, :n, 2] = s.piece_hi
            seg_pieces[p, :n, 3] = s.piece_row
            seg_pieces[p, :n, 4] = s.piece_split
        if st.tile is not None and st.tile.num_tiles:
            t = st.tile
            T = t.num_tiles
            tile_data[p, :T] = t.data
            gcols = np.minimum(
                t.tile_cols[:, None].astype(np.int64) * t_bn
                + np.arange(t_bn, dtype=np.int64)[None, :],
                t.shape[1] - 1)                        # (T, bn) global ids
            lane_nz = (t.data != 0).any(axis=1).astype(np.float32)
            tile_xcol[p, :T] = remap(np.where(lane_nz != 0, gcols, 0),
                                     lane_nz, p)
            tile_brow[p, :T] = t.tile_rows
    return dict(ell_data=ell_data, ell_cols=ell_cols, ovf_rows=ovf_rows,
                ovf_cols=ovf_cols, ovf_vals=ovf_vals, seg_vals=seg_vals,
                seg_cols=seg_cols, seg_rows=seg_rows, seg_pieces=seg_pieces,
                tile_data=tile_data, tile_xcol=tile_xcol, tile_brow=tile_brow,
                NS=NS)


def _device_operands(program: SpmvProgram) -> dict:
    """Build the pipelined executor's operand sets (cached on the program).

    Each shard's kernel work is split by row into a **local slice**
    (rows reading only columns the shard owns — runnable from
    ``x_local`` before any communication) and a **remote slice** (rows
    with at least one halo-dependent read — combined when the exchange
    lands).  Both slices are lowered into the shard's own kernel family
    and stacked into two uniform-shape operand sets (``loc_*`` /
    ``rem_*``); ``row_remote`` selects, per output row, which pass owns
    the result.  Column ids in the local set are pre-remapped to
    ``x_local`` positions; the remote set's ids target the exchange
    buffer (``[x_local ++ recv]`` for any program with a halo reader,
    the gathered global x for uniform all-gather).
    """
    cached = getattr(program, "_device_ops_cache", None)
    if cached is not None:
        return cached
    S = program.plan.num_shards
    stages = program.stages
    policies = program.plan.resolved_shard_exchanges()
    use_a2a = any(e == "halo" for e in policies)
    lay = program.x_layout

    if use_a2a:
        send_idx, pos_map, H = _halo_tables(program)
    else:
        send_idx = np.zeros((S, 1, 1), dtype=np.int32)
        pos_map, H = None, 0

    def remap_rem(cols, vals, p):
        if not use_a2a:
            return cols.astype(np.int32)
        return _remap_cols(cols, vals, lay, p, pos_map[p])

    def remap_loc(cols, vals, p):
        # Local-slice entries only read columns owned by p; zero-valued
        # (padding) slots keep position 0 — x_local[0] times 0 is 0.
        out = lay.local_index(cols).astype(np.int32)
        return np.where(vals != 0, out, 0).astype(np.int32)

    R = int(max(_round_up(max(st.rows, 1), ELL_SUBLANE) for st in stages))
    flags = _row_remote_flags(program)
    row_remote = np.zeros((S, R), dtype=bool)
    loc_stages, rem_stages = [], []
    kid = np.zeros(S, dtype=np.int32)
    for p, st in enumerate(stages):
        kid[p] = PROGRAM_KERNELS.index(st.kernel)
        rr = flags[st.row_offset: st.row_offset + st.rows]
        row_remote[p, : st.rows] = rr
        sub = program.partition.shard_csr(program.matrix, p)
        loc_stages.append(_masked_stage(sub, ~rr, st))
        rem_stages.append(_masked_stage(sub, rr, st))
    loc = _stack_stages(loc_stages, R, remap_loc)
    rem = _stack_stages(rem_stages, R, remap_rem)
    cached = dict(kid=kid, send_idx=send_idx, row_remote=row_remote,
                  R=R, halo_H=H, NS_loc=loc.pop("NS"), NS_rem=rem.pop("NS"))
    cached.update({"loc_" + k: v for k, v in loc.items()})
    cached.update({"rem_" + k: v for k, v in rem.items()})
    program._device_ops_cache = cached
    return cached


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


_SET_KEYS = ("ell_data", "ell_cols", "ovf_rows", "ovf_cols", "ovf_vals",
             "seg_vals", "seg_cols", "seg_rows", "seg_pieces",
             "tile_data", "tile_xcol", "tile_brow")

_OPERAND_KEYS = (("kid",)
                 + tuple("loc_" + k for k in _SET_KEYS)
                 + tuple("rem_" + k for k in _SET_KEYS)
                 + ("send_idx", "row_remote"))


def make_program_spmv_fn(program: SpmvProgram, mesh, axis: str = "model", *,
                         use_kernel: bool = False, interpret: bool = True,
                         pipeline: bool = True):
    """THE device executor: one shard_map function for any lowered program.

    Returns ``f(x_shards) -> y_shards`` with ``x_shards`` of shape
    (S, per_shard) or batched (S, per_shard, B) in layout order, and
    ``y_shards`` of shape (S, rows_pad[, B]) (slice each shard to its true
    ``rows_per_shard``, or use :func:`gather_b`).  The exchange prologue
    follows ``plan.resolved_shard_exchanges()``: uniform all-gather when
    every shard picks ``allgather``, otherwise one all-to-all whose
    per-reader payload is the exact halo (``halo`` shards) or the full
    replication (``allgather`` shards).  Each shard dispatches to its
    stage's kernel (``ell`` / ``seg`` / ``hyb`` / ``split`` / ``tile``)
    through a ``lax.switch`` — one SPMD program, heterogeneous per-shard
    execution.

    The schedule is **pipelined** (the ROADMAP item-4 executor): each
    shard's kernel work is pre-split by row into a local slice whose
    pass reads only ``x_local`` — issuable while the collective is in
    flight — and a remote slice whose pass waits for the exchange
    buffer; ``row_remote`` selects per row which pass owns the result.
    ``pipeline=False`` runs the *same* two passes behind an
    ``optimization_barrier`` that ties the local pass's input to the
    completed exchange — the pre-pipeline serial order, bitwise-equal
    output by construction (identical operands and combine, scheduling
    freedom removed).

    ``use_kernel=True`` runs the Pallas kernels (``interpret=True`` on
    CPU); the default runs the pure-jnp oracles, same as the old
    ``make_*_spmv_fn`` triplet this function replaces.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from .spmv import _shard_map_norep

    ops = _device_operands(program)
    R = ops["R"]
    NS_loc, NS_rem = ops["NS_loc"], ops["NS_rem"]
    policies = program.plan.resolved_shard_exchanges()
    use_a2a = any(e == "halo" for e in policies)
    kind = program.x_layout.kind
    if use_kernel:
        ell_op = partial(kops.ell_spmv, interpret=interpret,
                         tile_m=ELL_SUBLANE, tile_w=ELL_LANE)
    else:
        ell_op = kops.ell_spmv_ref

    def _to_global(x_all):
        """(S, per[, B]) gathered shards -> global (padded) order."""
        if kind == "block":
            return x_all.reshape((-1,) + x_all.shape[2:])
        return jnp.swapaxes(x_all, 0, 1).reshape((-1,) + x_all.shape[2:])

    def kernel_pass(kid, ed, ec, orow, ocol, oval, sv, sc, sr, sp,
                    td, txc, tbr, ns, xv):
        """One slice's kernel dispatch against its own x buffer."""

        def ell_branch(_):
            return ell_op(ed[0], ec[0], xv)

        def seg_branch(_):
            pc = sp[0]
            return kops.seg_spmv(
                (sv[0], sc[0], sr[0], pc[:, 0], pc[:, 1], pc[:, 2],
                 pc[:, 3]), xv, num_rows=R,
                use_kernel=use_kernel, interpret=interpret)

        def hyb_branch(_):
            y = ell_op(ed[0], ec[0], xv)
            xs = jnp.take(xv, ocol[0], axis=0)             # (O[, B])
            v = oval[0][:, None] if xs.ndim == 2 else oval[0]
            return y.at[orow[0]].add(v * xs)

        def split_branch(_):
            return kops.split_flat_spmv(
                sv[0], sc[0], sr[0], sp[0], xv, num_rows=R, num_splits=ns,
                use_kernel=use_kernel, interpret=interpret)

        def tile_branch(_):
            return kops.tile_flat_spmv(
                td[0], txc[0], tbr[0], xv, num_rows=R,
                use_kernel=use_kernel, interpret=interpret)

        return jax.lax.switch(kid[0], (ell_branch, seg_branch, hyb_branch,
                                       split_branch, tile_branch), None)

    def shard_fn(kid, led, lec, lorow, locol, loval, lsv, lsc, lsr, lsp,
                 ltd, ltxc, ltbr,
                 red, rec, rorow, rocol, roval, rsv, rsc, rsr, rsp,
                 rtd, rtxc, rtbr,
                 send_idx, row_rem, x_shard):
        x_local = x_shard[0]                               # (per[, B])
        if use_a2a:
            to_send = jnp.take(x_local, send_idx[0], axis=0)   # (S, H[, B])
            recv = jax.lax.all_to_all(to_send, axis, split_axis=0,
                                      concat_axis=0, tiled=True)
            xg = jnp.concatenate(
                [x_local, recv.reshape((-1,) + recv.shape[2:])], axis=0)
        else:
            x_all = jax.lax.all_gather(x_local, axis)      # (S, per[, B])
            xg = _to_global(x_all)

        x_loc_in = x_local
        if not pipeline:
            # Serial order: tie the local pass's input to the completed
            # exchange so no kernel work precedes the collective.  The
            # values are untouched — identical operands, identical
            # combine — so serial and pipelined runs are bitwise-equal;
            # only the scheduling freedom differs.
            x_loc_in, _ = jax.lax.optimization_barrier((x_local, xg))

        y_loc = kernel_pass(kid, led, lec, lorow, locol, loval, lsv, lsc,
                            lsr, lsp, ltd, ltxc, ltbr, NS_loc, x_loc_in)
        y_rem = kernel_pass(kid, red, rec, rorow, rocol, roval, rsv, rsc,
                            rsr, rsp, rtd, rtxc, rtbr, NS_rem, xg)
        m = row_rem[0]
        if y_rem.ndim == 2:                                # batched (R, B)
            m = m[:, None]
        y = jnp.where(m, y_rem, y_loc)
        return y[None]

    n_ops = len(_OPERAND_KEYS)
    fn = _shard_map_norep(
        shard_fn, mesh=mesh,
        in_specs=(P(axis),) * (n_ops + 1),
        out_specs=P(axis))
    jfn = jax.jit(fn)
    operands = tuple(jnp.asarray(ops[k]) for k in _OPERAND_KEYS)

    def run(x_shards):
        return jfn(*operands, jnp.asarray(x_shards))

    run.rows_out = R
    return run


def gather_b(program: SpmvProgram, y_shards) -> np.ndarray:
    """(S, rows_pad[, B]) device output -> global b in the caller's order."""
    y = np.asarray(y_shards)
    out = np.zeros((program.matrix.nrows,) + y.shape[2:], dtype=y.dtype)
    for p, st in enumerate(program.stages):
        out[st.row_offset: st.row_offset + st.rows] = y[p, : st.rows]
    return out if program.perm is None else out[program.perm]


# --------------------------------------------------------------------------
# Emu probe backend + the one executor entry point
# --------------------------------------------------------------------------

def probe_program(program: SpmvProgram, *, emu: EmuConfig | None = None,
                  engine: str = "vectorized") -> EmuResult:
    """Run the Emu timeline simulator on the program's (matrix, partition,
    layout) walk — the migratory-thread cost of the same plan the other
    backends execute.  This is the probe the autotuner's re-ranking and
    the rebalancer's drift oracle consume."""
    emu = emu or EmuConfig(nodelets=program.plan.num_shards)
    return run_spmv(program.matrix, program.partition, program.x_layout,
                    emu, engine=engine)


def execute(program: SpmvProgram, x: np.ndarray | None = None, *,
            backend: str = "numpy", mesh=None, axis: str = "model",
            use_kernel: bool = False, interpret: bool = True,
            pipeline: bool = True,
            emu: EmuConfig | None = None, engine: str = "vectorized"):
    """Execute a lowered program — the single entry point for every backend.

    * ``backend="numpy"``: exact float64 host oracle; returns y in the
      caller's index order ((M,) or (M, B) for batched x).
    * ``backend="shard_map"``: the device executor (requires ``mesh`` with
      ``plan.num_shards`` devices along ``axis``); builds the one-shot
      :func:`make_program_spmv_fn`, runs it, and assembles the caller-order
      result — use ``make_program_spmv_fn`` directly for a reusable
      compiled function.  ``pipeline=False`` forces the pre-pipeline
      serial schedule (exchange completes before any kernel work) —
      bitwise-equal to the default pipelined schedule.
    * ``backend="emu"``: ignores ``x`` and returns the
      :class:`~repro.core.emu.EmuResult` timeline probe.
    """
    if backend == "emu":
        return probe_program(program, emu=emu, engine=engine)
    if x is None:
        raise ValueError(f"backend {backend!r} needs an input vector x")
    if backend == "numpy":
        return _execute_numpy(program, x)
    if backend == "shard_map":
        if mesh is None:
            raise ValueError("backend='shard_map' needs a mesh with "
                             "plan.num_shards devices")
        fn = make_program_spmv_fn(program, mesh, axis=axis,
                                  use_kernel=use_kernel, interpret=interpret,
                                  pipeline=pipeline)
        xs = program.x_to_device(np.asarray(x, dtype=np.float32))
        with mesh:
            y = fn(xs)
        return gather_b(program, y)
    raise ValueError(f"unknown executor backend {backend!r}; expected "
                     f"'numpy', 'shard_map', or 'emu'")
