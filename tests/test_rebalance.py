"""Online rebalancer tests: a drifting request stream trips the detector
and swaps in a validated plan; serving stays consistent with the kernel
oracle through the swap; a stable stream never re-plans (hysteresis).
"""
import numpy as np
import pytest

from repro.core.layout import make_layout
from repro.core.migration import count_migrations, migration_arrivals, \
    remote_access_matrix, shard_load_map
from repro.core.partition import make_partition, partition_nonzeros
from repro.core.sparse_matrix import csr_matvec, csr_row_nnz, csr_to_dense
from repro.data.matrices import make_matrix
from repro.kernels import ops as kops
from repro.serve.engine import SparseMatrixEngine
from repro.serve.rebalance import LoadMonitor, RebalanceConfig

CFG = RebalanceConfig(window=32, patience=2, cooldown=2, probe=2)


def _engine(A, cfg=CFG):
    eng = SparseMatrixEngine(num_shards=4, rebalance=cfg)
    eng.ingest("a", A)
    return eng


def _hot_cols(eng, name="a"):
    """Columns (caller order) the active program placed on shard 0."""
    d = eng._matrices[name].dist
    order = np.arange(d.matrix.ncols) if d.perm is None else d.perm
    return np.flatnonzero(d.x_layout.owner_of(order) == 0)


def _request(rng, N, k, cols=None):
    x = np.zeros(N)
    idx = rng.integers(0, N, k) if cols is None else rng.choice(cols, size=k)
    x[idx] = rng.standard_normal(k)
    return x


def _seg_oracle(A, x):
    """Full-matrix seg_spmv_ref oracle in the caller's index order."""
    seg = kops.seg_from_csr(A)
    return np.asarray(kops.seg_spmv_ref(seg.vals, seg.cols, seg.rows,
                                        np.asarray(x, np.float32),
                                        num_rows=A.nrows))


def test_drifting_stream_trips_and_swaps_consistently():
    """(a) hot stream trips the detector; (b) y = A @ x stays consistent
    with the seg_spmv_ref oracle through the swap."""
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    eng = _engine(A)
    m = eng._matrices["a"]
    hot = _hot_cols(eng)
    rng = np.random.default_rng(0)
    k = max(N // 20, 8)

    for _ in range(2 * CFG.window):                      # warm-up, uniform
        eng.spmv("a", _request(rng, N, k))
    assert not m.rebalance_log                           # no false trip

    swapped_at = None
    for i in range(10 * CFG.window):
        x = _request(rng, N, k, cols=hot)
        y = eng.spmv("a", x)
        # consistency with the kernel-path oracle before/through/after swap
        np.testing.assert_allclose(y, _seg_oracle(A, x), atol=1e-3,
                                   rtol=1e-4)
        np.testing.assert_allclose(y, csr_matvec(A, x), atol=1e-4,
                                   rtol=1e-5)
        if swapped_at is None and any(e.swapped for e in m.rebalance_log):
            swapped_at = i
    assert m.monitor.trips >= 1, "hot-spot stream never tripped the detector"
    assert swapped_at is not None, "detector tripped but nothing swapped"
    swap = next(e for e in m.rebalance_log if e.swapped)
    # the swap was load-motivated and helped: weighted CV dropped a lot
    assert swap.load_cv_before > 2 * swap.load_cv_after
    # oracle gate held: the modeled seconds improved
    assert swap.probe_new_seconds < swap.probe_old_seconds
    # the served plan is the swapped-in one
    assert eng.plan("a") == swap.new_plan
    # repeated identical requests are bitwise stable on the new program
    x = _request(rng, N, k, cols=hot)
    assert np.array_equal(eng.spmv("a", x), eng.spmv("a", x))


def test_stable_stream_never_replans():
    """(c) hysteresis: a uniform stream closes many windows, zero trips."""
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    eng = _engine(A)
    m = eng._matrices["a"]
    rng = np.random.default_rng(1)
    k = max(N // 20, 8)
    for _ in range(8 * CFG.window):
        eng.spmv("a", _request(rng, N, k))
    assert m.monitor.windows_closed >= 8
    assert m.monitor.trips == 0
    assert not m.rebalance_log
    assert eng.stats()["a"]["rebalance"]["replans"] == 0


def test_single_burst_does_not_trip():
    """patience=2 means one hot window alone never triggers a re-plan."""
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    eng = _engine(A)
    m = eng._matrices["a"]
    hot = _hot_cols(eng)
    rng = np.random.default_rng(2)
    k = max(N // 20, 8)
    for _ in range(CFG.window):                 # exactly one hot window
        eng.spmv("a", _request(rng, N, k, cols=hot))
    for _ in range(4 * CFG.window):             # back to uniform
        eng.spmv("a", _request(rng, N, k))
    assert m.monitor.trips == 0
    assert not m.rebalance_log


def test_monitor_baseline_matches_static_counts():
    """Uniform activity through the load map == count_migrations' counts."""
    A = make_matrix("ford1", scale=0.05)
    part = make_partition(A, 4, "nonzero")
    xl = make_layout("block", A.ncols, 4)
    bl = make_layout("block", A.nrows, 4)
    lm, base = shard_load_map(A, part, xl, bl)
    static = count_migrations(A, part, xl, bl).mem_instr_per_nodelet
    np.testing.assert_allclose(lm @ np.ones(A.ncols) + base,
                               static.astype(np.float64))


def test_weighted_accounting_reduces_to_unweighted():
    """col_weight=1 reproduces the exact integer counts."""
    A = make_matrix("cop20k_A", scale=0.005)
    part = make_partition(A, 4, "row")
    xl = make_layout("block", A.ncols, 4)
    ones = np.ones(A.ncols)
    np.testing.assert_allclose(
        migration_arrivals(A, part, xl, col_weight=ones),
        migration_arrivals(A, part, xl).astype(np.float64))
    np.testing.assert_allclose(
        remote_access_matrix(A, part, xl, col_weight=ones),
        remote_access_matrix(A, part, xl).astype(np.float64))


def test_weighted_nonzero_partition_balances_weighted_work():
    """Traffic-weighted nnz split equalizes weighted (not raw) nnz."""
    A = make_matrix("webbase-1M", scale=0.001)
    w_col = np.ones(A.ncols)
    w_col[: A.ncols // 8] = 50.0            # hot leading columns
    nnz_w = w_col[A.col_index]
    part = partition_nonzeros(A, 4, nnz_weight=nnz_w)
    rows = np.repeat(np.arange(A.nrows), csr_row_nnz(A))
    per_shard = np.zeros(4)
    np.add.at(per_shard, part.owner_of_rows(A.nrows)[rows], nnz_w)
    cv_weighted = per_shard.std() / per_shard.mean()
    # the unweighted split leaves the weighted work skewed
    part0 = partition_nonzeros(A, 4)
    per0 = np.zeros(4)
    np.add.at(per0, part0.owner_of_rows(A.nrows)[rows], nnz_w)
    cv_unweighted = per0.std() / per0.mean()
    assert cv_weighted < 0.5 * cv_unweighted
    # and it still covers every row exactly once
    assert part.starts[0] == 0 and part.starts[-1] == A.nrows
    assert (np.diff(part.starts) >= 0).all()


def test_rejected_replan_keeps_serving_old_plan():
    """min_gain=1.0 rejects every candidate; serving must not degrade."""
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    cfg = RebalanceConfig(window=32, patience=2, cooldown=2, probe=2,
                          min_gain=1.0)
    eng = _engine(A, cfg)
    m = eng._matrices["a"]
    plan0 = eng.plan("a")
    hot = _hot_cols(eng)
    rng = np.random.default_rng(3)
    k = max(N // 20, 8)
    for _ in range(6 * cfg.window):
        x = _request(rng, N, k, cols=hot)
        np.testing.assert_allclose(eng.spmv("a", x), csr_matvec(A, x),
                                   atol=1e-4, rtol=1e-5)
    assert eng.plan("a") == plan0
    assert m.rebalance_log and all(not e.swapped for e in m.rebalance_log)


def test_async_replan_swaps_off_the_request_path():
    """async_replan=True: the triggering request returns immediately, the
    worker swaps in the validated plan, and serving stays correct while
    (and after) the re-plan runs on the old program."""
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    cfg = RebalanceConfig(window=32, patience=2, cooldown=2, probe=2,
                          async_replan=True)
    eng = _engine(A, cfg)
    m = eng._matrices["a"]
    hot = _hot_cols(eng)
    rng = np.random.default_rng(4)
    k = max(N // 20, 8)
    for _ in range(2 * cfg.window):
        eng.spmv("a", _request(rng, N, k))
    for _ in range(6 * cfg.window):
        x = _request(rng, N, k, cols=hot)
        np.testing.assert_allclose(eng.spmv("a", x), csr_matvec(A, x),
                                   atol=1e-4, rtol=1e-5)
        if m.replan_thread is not None:
            break
    assert m.replan_thread is not None, "detector never handed off a re-plan"
    m.replan_thread.join(timeout=120)
    assert not m.replan_thread.is_alive()
    assert any(e.swapped for e in m.rebalance_log)
    x = _request(rng, N, k, cols=hot)
    np.testing.assert_allclose(eng.spmv("a", x), csr_matvec(A, x),
                               atol=1e-4, rtol=1e-5)


def test_partial_replan_swaps_only_hot_shards():
    """A shard-0-concentrated workload re-kernels *only* the hot shard:
    the partial tier relowers that stage, shares every other stage object
    with the incumbent program, and the result still matches the oracle."""
    from repro.core.plan import PlanChoice, RankedPlan, estimate_cost, \
        extract_features
    from repro.core.program import execute, lower
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import mixed_structure
    from repro.serve.rebalance import hot_shards, replan

    A = mixed_structure(1024, 33 * 1024, seed=0)
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="halo", kernel="seg", num_shards=4)
    prog = lower(A, plan)
    cfg = RebalanceConfig(window=16, probe=0)
    mon = LoadMonitor(prog, cfg)
    w = np.ones(A.ncols)
    w[:256] = 50.0                      # traffic on shard 0's x columns
    mon._act_ema = w / w.mean()
    assert list(hot_shards(mon.shard_load(), cfg.hot_factor)) == [0]

    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    dist, new_choice, ev = replan(A, mon, choice, num_shards=4, seed=0,
                                  cfg=cfg, request_index=0, program=prog)
    assert ev.swapped and ev.mode == "partial"
    assert ev.swapped_shards == (0,)
    assert dist.shard_kernels()[0] != "seg"       # hot shard re-kerneled
    assert dist.shard_kernels()[1:] == ("seg",) * 3
    # per-shard double-buffered swap: untouched stages are shared objects
    assert all(dist.stages[p] is prog.stages[p] for p in (1, 2, 3))
    assert dist.stages[0] is not prog.stages[0]
    assert new_choice.plan == dist.plan
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(dist, x), csr_matvec(A, x),
                               atol=1e-5, rtol=1e-6)
    # no partial tier when disabled: same trip goes the full route
    cfg_full = RebalanceConfig(window=16, probe=0, partial_first=False)
    _, _, ev_full = replan(A, mon, choice, num_shards=4, seed=0,
                           cfg=cfg_full, request_index=0, program=prog)
    assert ev_full.mode == "full"


def test_partial_replan_reaches_split_on_monster_row_shard():
    """When the hot shard holds monster rows, the partial tier's
    per-shard re-kernel lands on the split family (its per-shard cost
    beats seg there), with the split count derived by the policy at
    relower time — and the swapped program still matches the oracle."""
    from repro.core.plan import PlanChoice, RankedPlan, estimate_cost, \
        extract_features
    from repro.core.program import execute, lower
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import powerlaw_tail
    from repro.serve.rebalance import hot_shards, replan

    A = powerlaw_tail(2048, 2 * 4 * 2048, n_monster=4, seed=0)
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="halo", kernel="seg", num_shards=4)
    prog = lower(A, plan)
    cfg = RebalanceConfig(window=16, probe=0)
    mon = LoadMonitor(prog, cfg)
    # skewed toward shard 0's x columns, but mild enough that the
    # traffic-thinned probe structure keeps the monster rows spanning
    # many chunks (heavy thinning would shorten them below the split
    # policy's span floor)
    w = np.ones(A.ncols)
    w[:512] = 3.0
    mon._act_ema = w / w.mean()
    assert list(hot_shards(mon.shard_load(), cfg.hot_factor)) == [0]

    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    dist, new_choice, ev = replan(A, mon, choice, num_shards=4, seed=0,
                                  cfg=cfg, request_index=0, program=prog)
    assert ev.swapped and ev.mode == "partial"
    assert ev.swapped_shards == (0,)
    assert dist.shard_kernels()[0] == "split"
    assert dist.shard_kernels()[1:] == ("seg",) * 3
    assert dist.stages[0].split is not None
    assert dist.stages[0].split.num_splits > 1     # policy-derived count
    assert all(dist.stages[p] is prog.stages[p] for p in (1, 2, 3))
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(dist, x), csr_matvec(A, x),
                               atol=1e-4, rtol=1e-5)


def test_partial_replan_reaches_tile_on_blocked_shard():
    """When the hot shard is block-structured (dense (8, 128) tiles), the
    partial tier's per-shard re-kernel lands on the bitmask-tiled family
    — its occupied-tile cost beats every flat format there — while the
    scattered shards keep their kernels, and the swapped program still
    matches the oracle."""
    from repro.core.plan import PlanChoice, RankedPlan, estimate_cost, \
        extract_features
    from repro.core.program import execute, lower
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import blocked_band
    from repro.serve.rebalance import hot_shards, replan

    A = blocked_band(2048, 215 * 2048, seed=0)
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="halo", kernel="seg", num_shards=4)
    prog = lower(A, plan)
    cfg = RebalanceConfig(window=16, probe=0)
    mon = LoadMonitor(prog, cfg)
    w = np.ones(A.ncols)
    w[:512] = 3.0                 # skew toward the band shard's columns
    mon._act_ema = w / w.mean()
    assert list(hot_shards(mon.shard_load(), cfg.hot_factor)) == [0]

    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    dist, new_choice, ev = replan(A, mon, choice, num_shards=4, seed=0,
                                  cfg=cfg, request_index=0, program=prog)
    assert ev.swapped and ev.mode == "partial"
    assert ev.swapped_shards == (0,)
    assert dist.shard_kernels()[0] == "tile"
    assert dist.shard_kernels()[1:] == ("seg",) * 3
    assert dist.stages[0].tile is not None
    assert dist.stages[0].tile.num_tiles > 0
    assert all(dist.stages[p] is prog.stages[p] for p in (1, 2, 3))
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(dist, x), csr_matvec(A, x),
                               atol=1e-3, rtol=1e-4)


def test_partial_replan_flips_only_hot_shard_exchange():
    """When the hot shard's traffic-thinned halo beats streaming the full
    padded vector, the partial tier flips *only* that shard's exchange
    policy: no stage is rebuilt (exchange is not a lowering-base field,
    every stage object is shared), the flip is logged in
    ``RebalanceEvent.exchange_flips``, and the swapped program still
    matches the oracle."""
    from repro.core.plan import (KERNELS, PlanChoice, RankedPlan,
                                 _active_submatrix, estimate_cost,
                                 extract_features, kernel_shard_costs)
    from repro.core.program import execute, lower
    from repro.core.spmv import SpmvPlan
    from repro.data.matrices import mixed_structure
    from repro.serve.rebalance import hot_shards, replan

    A = mixed_structure(1024, 33 * 1024, seed=0)
    cfg = RebalanceConfig(window=16, probe=0)
    w = np.ones(A.ncols)
    w[:256] = 50.0                      # traffic on shard 0's x columns

    # pin shard 0's kernel to the thinned-structure argmin up front, so
    # the kernel axis is a no-op and the exchange axis acts alone
    part = make_partition(A, 4, "row")
    sub = _active_submatrix(A, w / w.mean(), seed=cfg.seed)
    kc = kernel_shard_costs(sub, part)
    k0 = min(KERNELS, key=lambda k: (kc[k][0], KERNELS.index(k)))
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="allgather", kernel="seg", num_shards=4,
                    shard_kernels=(k0, "seg", "seg", "seg"))
    prog = lower(A, plan)
    mon = LoadMonitor(prog, cfg)
    mon._act_ema = w / w.mean()
    assert list(hot_shards(mon.shard_load(), cfg.hot_factor)) == [0]

    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    dist, new_choice, ev = replan(A, mon, choice, num_shards=4, seed=0,
                                  cfg=cfg, request_index=0, program=prog)
    assert ev.swapped and ev.mode == "partial"
    assert ev.exchange_flips == (0,)
    assert ev.swapped_shards == ()                 # exchange axis only
    assert "flipped exchange" in ev.reason
    assert dist.plan.resolved_shard_exchanges() == \
        ("halo", "allgather", "allgather", "allgather")
    # a flip rebuilds nothing: every stage object is shared
    assert all(dist.stages[p] is prog.stages[p] for p in range(4))
    assert new_choice.plan == dist.plan
    x = np.random.default_rng(0).standard_normal(A.ncols)
    np.testing.assert_allclose(execute(dist, x), csr_matvec(A, x),
                               atol=1e-5, rtol=1e-6)


def test_partial_replan_needs_skewed_traffic():
    """Uniform traffic never takes the partial tier (nothing local to
    re-derive) — the full tier answers the trip instead."""
    from repro.core.plan import PlanChoice, RankedPlan, estimate_cost, \
        extract_features
    from repro.core.program import lower
    from repro.core.spmv import SpmvPlan
    from repro.serve.rebalance import replan

    A = make_matrix("cop20k_A", scale=0.005)
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="halo", kernel="ell", num_shards=4)
    prog = lower(A, plan)
    cfg = RebalanceConfig(window=16, probe=2)
    mon = LoadMonitor(prog, cfg)
    mon._act_ema = np.ones(A.ncols)
    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    _, _, ev = replan(A, mon, choice, num_shards=4, seed=0, cfg=cfg,
                      request_index=0, program=prog)
    assert ev.mode == "full"


def test_monitor_batched_requests_count_columns():
    A = make_matrix("ford1", scale=0.05)
    eng = _engine(A)
    mon = eng._matrices["a"].monitor
    X = np.random.default_rng(0).standard_normal((A.ncols, 5))
    eng.spmv("a", X)
    assert mon.requests_seen == 5
