"""Persistent program artifacts: versioned on-disk bundles of lowered programs.

The paper's optimizations (reordering, distribution, kernel choice) only pay
off once their cost is amortized over enough SpMVs; a process restart that
re-probes the simulator and re-lowers every stage resets that clock to zero.
This module makes a lowered :class:`~repro.core.program.SpmvProgram` durable:

* :func:`save_program` writes a *bundle* directory —

  - ``arrays.npz``: every numpy payload (the reordered matrix, partition
    starts, traffic vectors, the permutation, and each shard stage's
    ell/seg/split/tile slabs),
  - ``plan_choice.json``: the autotuner's full ranked
    :class:`~repro.core.plan.PlanChoice` (optional; same JSON the plan
    layer has always round-tripped),
  - ``manifest.json``: schema version, the structure digest of the
    *source* (caller-order) matrix, the plan, and per-stage scalar
    metadata.  The manifest is written **last** via temp-file +
    ``os.replace``, and removed **first** on rewrite — a bundle without a
    valid manifest is simply not a bundle, so a crash mid-write can never
    yield a loadable-but-wrong artifact, and a serving-layer swap
    invalidates disk atomically before rewriting it.

* :func:`load_program` validates schema version and digest (raising
  :class:`ArtifactMismatch` so callers fall back to a fresh ``lower()``)
  and reconstructs the exact ``SpmvProgram``: every array round-trips
  bitwise through ``.npz``, and the executor outputs are bitwise equal to
  the freshly lowered program's.

* :func:`structure_digest` hashes shape, nnz, ``row_ptr``, ``col_index``
  *and* ``values``: a re-ingested matrix with identical structure but
  updated values must miss, otherwise a warm start would serve stale
  numerics bitwise-confidently.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

from .layout import make_layout
from .migration import TrafficReport
from .partition import Partition
from .plan import PlanChoice
from .program import ShardStage, SpmvProgram
from .sparse_matrix import CSRMatrix, EllMatrix, SegMatrix, SplitMatrix, \
    TileMatrix
from .spmv import SpmvPlan

__all__ = ["SCHEMA_VERSION", "ArtifactError", "ArtifactMissing",
           "ArtifactMismatch", "structure_digest", "save_program",
           "load_program", "invalidate_bundle"]

#: Bump when the bundle layout changes incompatibly.  Loaders reject any
#: other version (:class:`ArtifactMismatch`) so a fleet that skews across
#: releases falls back to a fresh ``lower()`` instead of misreading bytes.
SCHEMA_VERSION = 1

_FORMAT = "spmv-program-bundle"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_CHOICE = "plan_choice.json"


class ArtifactError(Exception):
    """Base: this bundle cannot be used; fall back to a fresh lower()."""


class ArtifactMissing(ArtifactError):
    """No bundle (or no valid manifest — e.g. an interrupted write)."""


class ArtifactMismatch(ArtifactError):
    """Bundle exists but its schema version or structure digest disagrees."""


def structure_digest(csr: CSRMatrix) -> str:
    """Content hash of a CSR matrix in the caller's index order.

    Covers shape/nnz/``row_ptr``/``col_index``/``values`` — the full
    identity an artifact's bitwise-equality guarantee rests on.
    """
    h = hashlib.sha256(b"spmv-structure-v1")
    h.update(np.asarray([csr.nrows, csr.ncols, csr.nnz],
                        dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(csr.row_ptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(csr.col_index, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(csr.values, dtype=np.float64).tobytes())
    return h.hexdigest()


def _write_atomic(path: str, text: str) -> None:
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


def _plan_to_dict(plan: SpmvPlan) -> dict:
    d = dataclasses.asdict(plan)
    for k in ("shard_kernels", "split_counts", "shard_exchanges"):
        if d[k] is not None:
            d[k] = list(d[k])
    return d


# Stage payload array fields, keyed ``s{p}_{field}`` in arrays.npz.  The
# scalar fields (nnz / chunk / num_splits) and the payload shape live in
# the manifest's per-stage entry.
_ELL_ARRAYS = ("data", "cols", "overflow_rows", "overflow_cols",
               "overflow_vals")
_SEG_ARRAYS = ("vals", "cols", "rows", "piece_chunk", "piece_lo",
               "piece_hi", "piece_row")
_SPLIT_ARRAYS = ("vals", "cols", "rows", "piece_split", "piece_chunk",
                 "piece_lo", "piece_hi", "piece_row")
_TILE_ARRAYS = ("tile_ptr", "tile_rows", "tile_cols", "data", "mask")


def _stage_entry(st: ShardStage, arrays: dict, p: int) -> dict:
    entry = {"kernel": st.kernel, "rows": int(st.rows),
             "row_offset": int(st.row_offset), "nnz": int(st.nnz)}
    if st.kernel in ("ell", "hyb"):
        entry["payload"] = {"shape": list(st.ell.shape),
                            "nnz": int(st.ell.nnz)}
        for f in _ELL_ARRAYS:
            arrays[f"s{p}_{f}"] = getattr(st.ell, f)
    elif st.kernel == "seg":
        entry["payload"] = {"shape": list(st.seg.shape),
                            "chunk": int(st.seg.chunk),
                            "nnz": int(st.seg.nnz)}
        for f in _SEG_ARRAYS:
            arrays[f"s{p}_{f}"] = getattr(st.seg, f)
    elif st.kernel == "split":
        entry["payload"] = {"shape": list(st.split.shape),
                            "chunk": int(st.split.chunk),
                            "num_splits": int(st.split.num_splits),
                            "nnz": int(st.split.nnz)}
        for f in _SPLIT_ARRAYS:
            arrays[f"s{p}_{f}"] = getattr(st.split, f)
    elif st.kernel == "tile":
        entry["payload"] = {"shape": list(st.tile.shape),
                            "bm": int(st.tile.bm), "bn": int(st.tile.bn),
                            "nnz": int(st.tile.nnz)}
        for f in _TILE_ARRAYS:
            arrays[f"s{p}_{f}"] = getattr(st.tile, f)
    else:  # pragma: no cover - lower() already validated the kernel
        raise ValueError(f"unknown stage kernel {st.kernel!r}")
    return entry


def _stage_from_entry(entry: dict, arrays, p: int) -> ShardStage:
    kernel = entry["kernel"]
    pay = entry["payload"]
    shape = tuple(pay["shape"])
    ell = seg = split = tile = None
    get = lambda f: arrays[f"s{p}_{f}"]  # noqa: E731
    if kernel in ("ell", "hyb"):
        ell = EllMatrix(shape=shape, data=get("data"), cols=get("cols"),
                        overflow_rows=get("overflow_rows"),
                        overflow_cols=get("overflow_cols"),
                        overflow_vals=get("overflow_vals"),
                        nnz=int(pay["nnz"]))
    elif kernel == "seg":
        seg = SegMatrix(shape=shape, chunk=int(pay["chunk"]),
                        vals=get("vals"), cols=get("cols"), rows=get("rows"),
                        piece_chunk=get("piece_chunk"),
                        piece_lo=get("piece_lo"), piece_hi=get("piece_hi"),
                        piece_row=get("piece_row"), nnz=int(pay["nnz"]))
    elif kernel == "split":
        split = SplitMatrix(shape=shape, chunk=int(pay["chunk"]),
                            num_splits=int(pay["num_splits"]),
                            vals=get("vals"), cols=get("cols"),
                            rows=get("rows"),
                            piece_split=get("piece_split"),
                            piece_chunk=get("piece_chunk"),
                            piece_lo=get("piece_lo"),
                            piece_hi=get("piece_hi"),
                            piece_row=get("piece_row"), nnz=int(pay["nnz"]))
    elif kernel == "tile":
        tile = TileMatrix(shape=shape, bm=int(pay["bm"]), bn=int(pay["bn"]),
                          tile_ptr=get("tile_ptr"),
                          tile_rows=get("tile_rows"),
                          tile_cols=get("tile_cols"), data=get("data"),
                          mask=get("mask"), nnz=int(pay["nnz"]))
    else:
        raise ArtifactMismatch(f"unknown stage kernel {kernel!r} in bundle")
    return ShardStage(shard=p, kernel=kernel, rows=int(entry["rows"]),
                      row_offset=int(entry["row_offset"]),
                      nnz=int(entry["nnz"]), ell=ell, seg=seg, split=split,
                      tile=tile)


def invalidate_bundle(bundle_dir: str) -> None:
    """Atomically mark a bundle unusable (manifest removal is the commit
    point for both invalidation and rewrite)."""
    try:
        os.remove(os.path.join(bundle_dir, _MANIFEST))
    except FileNotFoundError:
        pass


def save_program(program: SpmvProgram, bundle_dir: str, *,
                 source: CSRMatrix | None = None,
                 choice: PlanChoice | None = None) -> str:
    """Write ``program`` as a versioned bundle directory; returns the path.

    ``source`` is the matrix in the *caller's* index order (what the
    serving layer was handed at ingest) — the digest future loads are
    validated against.  It may be omitted only for unreordered programs,
    where ``program.matrix`` is already in caller order.

    Write protocol: remove the old manifest first, arrays and choice next,
    manifest last (each file via temp + ``os.replace``).  Readers treat a
    manifest-less directory as :class:`ArtifactMissing`, so every
    intermediate state of this sequence — including a crash — reads as
    "no artifact", never as a stale or torn one.
    """
    if source is None:
        if program.perm is not None:
            raise ValueError("reordered programs need source= (the matrix "
                             "in caller index order) to digest against")
        source = program.matrix
    os.makedirs(bundle_dir, exist_ok=True)
    invalidate_bundle(bundle_dir)

    arrays: dict = {
        "mat_values": program.matrix.values,
        "mat_col_index": program.matrix.col_index,
        "mat_row_ptr": program.matrix.row_ptr,
        "part_starts": program.partition.starts,
        "traffic_mem_instr": program.traffic.mem_instr_per_nodelet,
        "traffic_inbound_x": program.traffic.inbound_x_loads,
        "traffic_nnz": program.traffic.nnz_per_nodelet,
        "shard_traffic": program.shard_traffic,
    }
    if program.perm is not None:
        arrays["perm"] = program.perm
    stages = [_stage_entry(st, arrays, p)
              for p, st in enumerate(program.stages)]

    npz_path = os.path.join(bundle_dir, _ARRAYS)
    npz_tmp = f"{npz_path}.tmp{os.getpid()}.npz"
    np.savez(npz_tmp, **arrays)
    os.replace(npz_tmp, npz_path)

    choice_path = os.path.join(bundle_dir, _CHOICE)
    if choice is not None:
        _write_atomic(choice_path, choice.to_json(indent=1))
    elif os.path.exists(choice_path):
        os.remove(choice_path)

    manifest = {
        "format": _FORMAT,
        "schema_version": SCHEMA_VERSION,
        "digest": structure_digest(source),
        "plan": _plan_to_dict(program.plan),
        "shape": [program.matrix.nrows, program.matrix.ncols],
        "partition_strategy": program.partition.strategy,
        "traffic": {
            "migrations": int(program.traffic.migrations),
            "remote_x_loads": int(program.traffic.remote_x_loads),
            "remote_b_updates": int(program.traffic.remote_b_updates),
        },
        "stages": stages,
        "has_choice": choice is not None,
    }
    _write_atomic(os.path.join(bundle_dir, _MANIFEST),
                  json.dumps(manifest, indent=1))
    return bundle_dir


def load_program(bundle_dir: str, *, expect: CSRMatrix | None = None
                 ) -> tuple[SpmvProgram, PlanChoice | None]:
    """Load a bundle back into an exact :class:`SpmvProgram`.

    ``expect`` (the matrix being ingested, caller index order) arms the
    digest check; schema-version skew or a digest miss raises
    :class:`ArtifactMismatch`, an absent/torn bundle raises
    :class:`ArtifactMissing` — both signals to fall back to ``lower()``.
    """
    manifest_path = os.path.join(bundle_dir, _MANIFEST)
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        raise ArtifactMissing(f"no readable manifest in {bundle_dir}") from e
    if manifest.get("format") != _FORMAT:
        raise ArtifactMismatch(f"not a {_FORMAT}: {bundle_dir}")
    version = manifest.get("schema_version")
    if version != SCHEMA_VERSION:
        raise ArtifactMismatch(
            f"bundle schema {version!r} != supported {SCHEMA_VERSION}")
    if expect is not None and manifest["digest"] != structure_digest(expect):
        raise ArtifactMismatch("structure digest mismatch: bundle was built "
                               "from a different matrix")

    try:
        arrays = np.load(os.path.join(bundle_dir, _ARRAYS))
    except (FileNotFoundError, ValueError, OSError) as e:
        raise ArtifactMissing(f"unreadable {_ARRAYS} in {bundle_dir}") from e

    plan = SpmvPlan(**manifest["plan"])
    M, N = (int(v) for v in manifest["shape"])
    matrix = CSRMatrix(shape=(M, N), values=arrays["mat_values"],
                       col_index=arrays["mat_col_index"],
                       row_ptr=arrays["mat_row_ptr"])
    part = Partition(strategy=manifest["partition_strategy"],
                     num_shards=plan.num_shards,
                     starts=arrays["part_starts"])
    traffic = TrafficReport(
        migrations=int(manifest["traffic"]["migrations"]),
        remote_x_loads=int(manifest["traffic"]["remote_x_loads"]),
        remote_b_updates=int(manifest["traffic"]["remote_b_updates"]),
        mem_instr_per_nodelet=arrays["traffic_mem_instr"],
        inbound_x_loads=arrays["traffic_inbound_x"],
        nnz_per_nodelet=arrays["traffic_nnz"])
    stages = tuple(_stage_from_entry(entry, arrays, p)
                   for p, entry in enumerate(manifest["stages"]))
    perm = arrays["perm"] if "perm" in arrays.files else None

    program = SpmvProgram(
        plan=plan, matrix=matrix, partition=part,
        x_layout=make_layout(plan.layout, N, plan.num_shards),
        b_layout=make_layout(plan.layout, M, plan.num_shards),
        rows_per_shard=part.rows_per_shard().astype(np.int64),
        row_offset=part.starts[:-1].astype(np.int64),
        traffic=traffic, shard_traffic=arrays["shard_traffic"],
        stages=stages, perm=perm)

    choice = None
    choice_path = os.path.join(bundle_dir, _CHOICE)
    if manifest.get("has_choice") and os.path.exists(choice_path):
        with open(choice_path) as f:
            choice = PlanChoice.from_json(f.read())
    return program, choice
