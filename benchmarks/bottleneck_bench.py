"""Bottleneck-oracle serving benchmark: does the amortization gate pay?

Two scenarios, one entry, both exercising the
:class:`~repro.core.oracle.CostOracle` re-plan gate (the Asudeh
volume-aware swap criterion, arXiv 2506.10356) against the legacy
volume-blind behaviour:

* **Gating.**  One drifting tenant is served by two engines with an
  identical hair-trigger detector (``patience=1``, ``cooldown=0``, tiny
  ``min_gain``): *eager* re-plans whenever any modeled gain exists (the
  volume-blind legacy gate), *gated* additionally requires the projected
  request volume to amortize the swap's one-time cost
  (``amortization_lookahead``).  The trace ramps through a mild skew
  into a short burst of the paper's strong shard-concentrated skew
  (§IV-D) and then ends — exactly the volume regime where chasing the
  drift is a loss: the eager engine swaps as soon as a few percent of
  modeled gain appears, while the gated engine refuses because the
  remaining volume cannot pay back a full re-plan.  Headline: on the
  **amortized trace cost** (Emu-modeled seconds for every served
  request, plus each swap charged its one-time cost in SpMV equivalents
  — :data:`~repro.core.oracle.REPLAN_SPMV_EQUIV`), the gated engine
  matches or beats the eager engine while performing strictly fewer
  swaps.
* **Low traffic.**  The same strong-drift trace is served to a tenant
  taking ~1/10th of an engine's traffic (a busy ballast tenant absorbs
  the rest).  Volume-blind, the drifted tenant swaps; with the
  amortization gate armed, its projected horizon (lookahead x traffic
  share) cannot cover the full re-plan's SpMV-equivalent cost and the
  identical candidate is refused — the accepted-vs-refused pair the
  oracle's ``replan_pays`` decision is for.

Usage::

    PYTHONPATH=src python -m benchmarks.bottleneck_bench           # full
    PYTHONPATH=src python -m benchmarks.bottleneck_bench --fast    # CI smoke
    PYTHONPATH=src python -m benchmarks.perf_probe --bottleneck    # + record
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.oracle import REPLAN_SPMV_EQUIV
from repro.data.matrices import make_matrix
from repro.serve.engine import SparseMatrixEngine
from repro.serve.rebalance import RebalanceConfig, probe_plan_seconds

AMORTIZATION_REASON = "amortization gate"


def make_drift_stream(N: int, hot_cols: np.ndarray, *, k: int,
                      phases, zipf_a: float = 1.6, seed: int = 0):
    """Request vectors whose hot-column fraction steps through ``phases``.

    ``phases`` is a list of ``(n_requests, hot_frac)``: each request's
    support draws ``round(k * hot_frac)`` columns zipf-ranked over
    ``hot_cols`` (heaviest first — the power-law mix of
    ``drift_bench.make_request_stream``) and the rest uniformly, so
    ``hot_frac=0`` is uniform traffic and ``hot_frac=1`` the paper's
    shard-concentrated convergence.
    """
    rng = np.random.default_rng(seed)
    for n_req, hot_frac in phases:
        k_hot = int(round(k * hot_frac))
        for _ in range(n_req):
            x = np.zeros(N)
            if k_hot:
                ranks = np.minimum(rng.zipf(zipf_a, k_hot) - 1,
                                   hot_cols.size - 1)
                x[hot_cols[ranks]] = rng.standard_normal(k_hot)
            if k - k_hot:
                x[rng.integers(0, N, k - k_hot)] = \
                    rng.standard_normal(k - k_hot)
            yield x


def _hot_cols(engine: SparseMatrixEngine, name: str) -> np.ndarray:
    """Columns the active program placed on shard 0 (the drift target)."""
    d = engine._matrices[name].dist
    N = d.matrix.ncols
    order = np.arange(N) if d.perm is None else d.perm
    return np.flatnonzero(d.x_layout.owner_of(order) == 0)


def _replan_counts(engine: SparseMatrixEngine, name: str) -> dict:
    log = engine.rebalance_log(name)
    return {
        "trips": len(log),
        "swaps": sum(e.swapped for e in log),
        "amortization_refusals": sum(
            not e.swapped and e.reason.startswith(AMORTIZATION_REASON)
            for e in log),
    }


def _amortized_trace_cost(A, engine: SparseMatrixEngine, name: str,
                          n_requests: int, w_final: np.ndarray,
                          _cache: dict) -> float:
    """Emu-modeled cost of the whole served trace, swaps charged.

    Every request is priced at the modeled seconds of the plan that was
    serving it (segments reconstructed from the rebalance log), under
    the end-of-trace traffic weights — the same weights for both engines
    being compared, so the comparison is apples-to-apples even though
    early uniform-phase requests are priced under drifted weights.  Each
    swap additionally pays its one-time cost in steady-state SpMV
    equivalents (:data:`~repro.core.oracle.REPLAN_SPMV_EQUIV`) — the
    Asudeh accounting the gate itself uses, here applied to what each
    engine *actually did*.
    """
    def sec(plan) -> float:
        key = repr(plan)
        if key not in _cache:
            _cache[key] = probe_plan_seconds(A, plan, w_final)
        return _cache[key]

    swaps = [e for e in engine.rebalance_log(name) if e.swapped]
    plan0 = swaps[0].old_plan if swaps else engine.plan(name)
    segments = [(0, plan0)] + [(e.request_index, e.new_plan) for e in swaps]
    total = 0.0
    for i, (start, p) in enumerate(segments):
        end = segments[i + 1][0] if i + 1 < len(segments) else n_requests
        total += max(end - start, 0) * sec(p)
    for e in swaps:
        total += REPLAN_SPMV_EQUIV[e.mode] * sec(e.new_plan)
    return total


def run_bottleneck_bench(*, matrix: str = "cop20k_A", scale: float = 0.005,
                         shards: int = 4, window: int = 32,
                         k_frac: float = 0.05, mild_windows: int = 4,
                         strong_windows: int = 3, mild_frac: float = 0.45,
                         lookahead: int = 50, ballast_ratio: int = 9,
                         probe: int = 2, seed: int = 0) -> dict:
    """Run both scenarios; returns the headline dict (printed by main)."""
    A = make_matrix(matrix, scale=scale)
    N = A.ncols
    k = max(int(N * k_frac), 8)

    # Hair-trigger detector shared by both engines: every skewed window
    # trips, so the *only* difference between the two runs is the
    # oracle's amortization gate.
    det = dict(window=window, patience=1, cooldown=0, cv_trigger=0.05,
               cv_ratio=1.01, min_gain=0.01, probe=probe, seed=seed)
    cfg_eager = RebalanceConfig(**det)
    cfg_gated = RebalanceConfig(**det, amortization_lookahead=lookahead)

    # -- scenario 1: eager vs gated on the stepped-drift trace --------------
    eager = SparseMatrixEngine(num_shards=shards, rebalance=cfg_eager)
    gated = SparseMatrixEngine(num_shards=shards, rebalance=cfg_gated)
    eager.ingest("A", A)
    gated.ingest("A", A)

    hot = _hot_cols(eager, "A")
    phases = [(2 * window, 0.0),
              (mild_windows * window, mild_frac),
              (strong_windows * window, 1.0)]
    stream = list(make_drift_stream(N, hot, k=k, phases=phases, seed=seed))
    for x in stream:
        eager.spmv("A", x)
        gated.spmv("A", x)

    w_final = eager._matrices["A"].monitor.activity()
    sec_cache: dict = {}
    cost_eager = _amortized_trace_cost(A, eager, "A", len(stream), w_final,
                                       sec_cache)
    cost_gated = _amortized_trace_cost(A, gated, "A", len(stream), w_final,
                                       sec_cache)
    gating = {
        "requests": len(stream),
        "phases": [{"requests": n, "hot_frac": f} for n, f in phases],
        "eager": {**_replan_counts(eager, "A"),
                  "final_plan": _plan_str(eager.plan("A"))},
        "gated": {**_replan_counts(gated, "A"),
                  "final_plan": _plan_str(gated.plan("A"))},
        "steady_state_spmv_seconds": {
            "eager": probe_plan_seconds(A, eager.plan("A"), w_final),
            "gated": probe_plan_seconds(A, gated.plan("A"), w_final)},
        "amortized_trace_cost": {
            "eager": cost_eager, "gated": cost_gated,
            "ratio_eager_vs_gated": round(cost_eager /
                                          max(cost_gated, 1e-12), 3)},
    }

    # -- scenario 2: low-traffic tenant, volume-blind vs gated --------------
    # The drifted tenant sees one request per ``ballast_ratio`` ballast
    # requests, so its traffic share — and with it the projected
    # amortization horizon the oracle gates on — is ~1/(ballast_ratio+1).
    lt = {}
    for label, cfg in (("volume_blind", cfg_eager), ("gated", cfg_gated)):
        eng = SparseMatrixEngine(num_shards=shards, rebalance=None)
        eng.ingest("lo", A, rebalance=cfg)
        eng.ingest("ballast", A, rebalance=False)
        hot_lo = _hot_cols(eng, "lo")
        lo_stream = make_drift_stream(
            N, hot_lo, k=k,
            phases=[(2 * window, 0.0),
                    ((mild_windows + strong_windows) * window, 1.0)],
            seed=seed)
        x_ballast = np.ones(N)
        for x in lo_stream:
            for _ in range(ballast_ratio):
                eng.spmv("ballast", x_ballast)
            eng.spmv("lo", x)
        counts = _replan_counts(eng, "lo")
        counts["traffic_share"] = round(
            eng._matrices["lo"].spmv_count / max(eng.total_requests, 1), 3)
        lt[label] = counts
    lt["lookahead"] = lookahead

    entry = {
        "workload": f"bottleneck/{matrix}", "scale": scale,
        "shards": shards, "window": window, "lookahead": lookahead,
        "bottleneck": {
            "ingest": eager._matrices["A"].choice.bottleneck,
            "eager_final": eager._matrices["A"].choice.bottleneck,
            "gated_final": gated._matrices["A"].choice.bottleneck},
        "gating": gating,
        "low_traffic": lt,
    }
    return entry


def _plan_str(p) -> str:
    return f"{p.reordering}/{p.layout}/{p.distribution}/{p.kernel}"


def check(entry: dict) -> bool:
    """Acceptance gates CI smoke-tests.

    Gating: on the amortized trace cost (served requests + swap
    one-time costs, Emu-modeled) the oracle-gated engine matches or
    beats always-re-plan (2% grace) with strictly fewer swaps, and at
    least one refusal explicitly from the amortization gate.  Low
    traffic: the volume-blind run swaps on the drifted low-share tenant
    while the gated run refuses the same drift at the amortization gate
    and never swaps.
    """
    g = entry["gating"]
    lt = entry["low_traffic"]
    return (g["gated"]["swaps"] < g["eager"]["swaps"] and
            g["gated"]["amortization_refusals"] >= 1 and
            g["amortized_trace_cost"]["ratio_eager_vs_gated"] >= 0.98 and
            lt["volume_blind"]["swaps"] >= 1 and
            lt["gated"]["swaps"] == 0 and
            lt["gated"]["amortization_refusals"] >= 1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="cop20k_A")
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--lookahead", type=int, default=50)
    ap.add_argument("--probe", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller matrix/stream, same gates")
    ap.add_argument("--json", action="store_true",
                    help="print the entry as JSON only")
    args = ap.parse_args()

    kw = dict(matrix=args.matrix, scale=args.scale, shards=args.shards,
              window=args.window, lookahead=args.lookahead,
              probe=args.probe, seed=args.seed)
    if args.fast:
        kw.update(scale=min(args.scale, 0.003), window=16)
    entry = run_bottleneck_bench(**kw)
    ok = check(entry)

    if args.json:
        print(json.dumps(entry, indent=2))
    else:
        g = entry["gating"]
        print(f"bottleneck bench: {entry['workload']} "
              f"scale={entry['scale']} shards={entry['shards']} "
              f"lookahead={entry['lookahead']}")
        print(f"  gating    : eager {g['eager']['swaps']} swap(s) / "
              f"{g['eager']['trips']} trips -> {g['eager']['final_plan']}")
        print(f"              gated {g['gated']['swaps']} swap(s) / "
              f"{g['gated']['trips']} trips "
              f"({g['gated']['amortization_refusals']} amortization "
              f"refusal(s)) -> {g['gated']['final_plan']}")
        c = g["amortized_trace_cost"]
        print(f"  amortized : eager {c['eager']:.3e}s vs gated "
              f"{c['gated']:.3e}s trace cost "
              f"(ratio {c['ratio_eager_vs_gated']:.3f}, bar >= 0.98)")
        lt = entry["low_traffic"]
        print(f"  low-traf  : share {lt['gated']['traffic_share']:.0%} | "
              f"volume-blind {lt['volume_blind']['swaps']} swap(s) vs "
              f"gated {lt['gated']['swaps']} swap(s), "
              f"{lt['gated']['amortization_refusals']} amortization "
              f"refusal(s)")
        print(f"  -> {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
