"""Reproduce the paper's headline table: reordering gains on the Emu model
vs a real cache-hierarchy CPU (Figs. 10 & 12 side by side).

    PYTHONPATH=src python examples/reorder_study.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.cache_model import measure_cpu_spmv
from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix


def main():
    A_emu = make_matrix("cop20k_A", scale=0.02)
    A_cpu = make_matrix("cop20k_A", scale=0.3)
    print(f"{'reordering':10s} {'Emu model MB/s':>14s} {'gain':>6s}"
          f" {'this CPU MB/s':>14s} {'gain':>6s}")
    base_e = base_c = None
    for r in ("none", "random", "bfs", "metis"):
        Be, Bc = reorder(A_emu, r), reorder(A_cpu, r)
        e = run_spmv(Be, make_partition(Be, 8, "nonzero"),
                     make_layout("block", Be.ncols, 8), EmuConfig())
        c = measure_cpu_spmv(Bc, trials=5)
        base_e = base_e or e.bandwidth_mbs
        base_c = base_c or c.bandwidth_mbs
        print(f"{r:10s} {e.bandwidth_mbs:14.1f} {e.bandwidth_mbs/base_e:6.2f}"
              f" {c.bandwidth_mbs:14.1f} {c.bandwidth_mbs/base_c:6.2f}")
    print("\npaper: reordering is worth far more on the migratory machine")
    print("(<=1.7x) than on the cache machine (<=1.16x), and random only")
    print("helps on the migratory machine.")


if __name__ == "__main__":
    main()
