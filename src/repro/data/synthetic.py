"""Synthetic token pipeline: deterministic, shardable, restart-exact.

A real deployment swaps ``TokenStream`` for a file-backed loader; everything
downstream (sharding, restart bookkeeping) is identical.  The stream is a
counter-based PRNG (threefry) keyed by (seed, step, host) so a restarted or
re-sharded job regenerates byte-identical batches — the property the
fault-tolerance path relies on (no data-loader state in checkpoints beyond
the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128


class TokenStream:
    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for a given step — pure function of (seed, step)."""
        cfg, d = self.cfg, self.data
        rng = np.random.default_rng(np.uint64(d.seed * 1_000_003 + step))
        B, S = d.batch, d.seq_len
        if cfg.frontend == "encodec_stub":
            return {
                "frames": rng.standard_normal((B, S, cfg.d_model)).astype(np.float32),
                "labels": rng.integers(0, cfg.vocab_size,
                                       (B, S, cfg.num_codebooks)).astype(np.int32),
            }
        if cfg.frontend == "siglip_stub":
            P = cfg.prefix_len
            return {
                "image_embeds": rng.standard_normal((B, P, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(0, cfg.vocab_size, (B, S - P)).astype(np.int32),
                "labels": rng.integers(0, cfg.vocab_size, (B, S - P)).astype(np.int32),
            }
        # LM: structured-ish stream (Zipf tokens + shifted labels) so loss
        # actually decreases during the e2e example runs.
        toks = (rng.zipf(1.3, (B, S + 1)) % cfg.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
