"""The deprecated pre-IR ``make_*_spmv_fn`` shims warn exactly once each
(DeprecationWarning) and keep their historical behavior bit-for-bit.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spmv as S
from repro.core.program import execute, lower
from repro.core.spmv import SpmvPlan
from repro.data.matrices import make_matrix


@pytest.fixture()
def mesh1():
    return jax.make_mesh((1,), ("model",))


def _reset(name):
    """Isolate the warn-once latch from other tests in this process."""
    S._DEPRECATION_WARNED.discard(name)


def _first_shard(prog, y):
    r = int(prog.rows_per_shard[0])
    return np.asarray(y[0])[:r]


def test_make_spmv_fn_warns_once_and_behaves(mesh1):
    A = make_matrix("ford1", scale=0.05)
    prog = lower(A, SpmvPlan(num_shards=1, kernel="ell",
                             exchange="allgather"))
    x = np.random.default_rng(0).standard_normal(A.ncols).astype(np.float32)
    _reset("make_spmv_fn")
    with pytest.warns(DeprecationWarning, match="make_spmv_fn"):
        fn = S.make_spmv_fn(prog, mesh1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        S.make_spmv_fn(prog, mesh1)          # second call: silent
    with mesh1:
        y = fn(jnp.array(prog.data), jnp.array(prog.cols),
               jnp.array(prog.x_to_device(x)))
    np.testing.assert_allclose(_first_shard(prog, y), execute(prog, x),
                               atol=1e-3, rtol=1e-4)


def test_make_seg_spmv_fn_warns_once_and_behaves(mesh1):
    A = make_matrix("cop20k_A", scale=0.005)
    prog = lower(A, SpmvPlan(num_shards=1, kernel="seg",
                             exchange="allgather"))
    x = np.random.default_rng(1).standard_normal(A.ncols).astype(np.float32)
    _reset("make_seg_spmv_fn")
    with pytest.warns(DeprecationWarning, match="make_seg_spmv_fn"):
        fn = S.make_seg_spmv_fn(prog, mesh1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        S.make_seg_spmv_fn(prog, mesh1)
    with mesh1:
        y = fn(jnp.array(prog.seg_vals), jnp.array(prog.seg_cols),
               jnp.array(prog.seg_rows), jnp.array(prog.seg_pieces),
               jnp.array(prog.x_to_device(x)))
    np.testing.assert_allclose(_first_shard(prog, y), execute(prog, x),
                               atol=1e-3, rtol=1e-4)


def test_make_halo_spmv_fn_warns_once_and_behaves(mesh1):
    A = make_matrix("ford1", scale=0.05)
    prog = lower(A, SpmvPlan(num_shards=1, kernel="ell", exchange="halo"))
    halo = S.build_halo(prog)
    x = np.random.default_rng(2).standard_normal(A.ncols).astype(np.float32)
    _reset("make_halo_spmv_fn")
    with pytest.warns(DeprecationWarning, match="make_halo_spmv_fn"):
        fn = S.make_halo_spmv_fn(prog, halo, mesh1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        S.make_halo_spmv_fn(prog, halo, mesh1)
    with mesh1:
        y = fn(jnp.array(prog.data), jnp.array(halo.cols_remap),
               jnp.array(halo.send_idx), jnp.array(prog.x_to_device(x)))
    np.testing.assert_allclose(_first_shard(prog, y), execute(prog, x),
                               atol=1e-3, rtol=1e-4)
