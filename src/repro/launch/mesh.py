"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
carries only DP traffic (gradient all-reduce, optionally int8-compressed)
since it maps to the slower inter-pod links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int | None = None):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    mp = model_parallel or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh(
        (n // mp, mp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
