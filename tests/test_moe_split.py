"""Expert splitting (§Perf H2): exact SwiGLU decomposition + counting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MoEConfig
from repro.models.moe import moe_ffn


def _weights(E, d, f, key):
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.1,
    }


def _split_weights(p, E, d, f, sp):
    fs = f // sp
    wg = p["w_gate"].reshape(E, d, sp, fs).transpose(0, 2, 1, 3).reshape(E * sp, d, fs)
    wu = p["w_up"].reshape(E, d, sp, fs).transpose(0, 2, 1, 3).reshape(E * sp, d, fs)
    wd = p["w_down"].reshape(E, sp, fs, d).reshape(E * sp, fs, d)
    return {"router": p["router"], "w_gate": wg, "w_up": wu, "w_down": wd}


def test_split_is_exact():
    E, d, f = 4, 32, 64
    key = jax.random.PRNGKey(0)
    p = _weights(E, d, f, key)
    x = jax.random.normal(key, (2, 16, d), jnp.float32)
    cfg = MoEConfig(num_experts=E, top_k=2, d_expert=f, capacity_factor=8.0)
    y1, _ = moe_ffn(p, x, cfg, "swiglu")
    for sp in (2, 4):
        cfg_s = dataclasses.replace(cfg, expert_split=sp)
        y2, _ = moe_ffn(_split_weights(p, E, d, f, sp), x, cfg_s, "swiglu")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)


def test_grok_config_split_divides_model_axis():
    from repro.configs.registry import get_config
    cfg = get_config("grok_1_314b")
    assert cfg.moe.expert_split == 2
    assert (cfg.moe.num_experts * cfg.moe.expert_split) % 16 == 0
    # param count unchanged by splitting (same physical weights)
    assert 300e9 < cfg.param_count() < 330e9


def test_combine_modes_agree():
    E, d, f = 8, 32, 64
    key = jax.random.PRNGKey(1)
    p = _weights(E, d, f, key)
    x = jax.random.normal(key, (2, 16, d), jnp.float32)
    cfg = MoEConfig(num_experts=E, top_k=2, d_expert=f, capacity_factor=4.0)
    y_g, _ = moe_ffn(p, x, cfg, "swiglu", combine="gather")
    y_s, _ = moe_ffn(p, x, cfg, "swiglu", combine="scatter_psum")
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_s),
                               rtol=1e-5, atol=1e-5)
