"""Fig. 6 — SpMV bandwidth: row vs non-zero work distribution (Emu model).
Paper: nonzero up to 3.34x better despite ~1.69x more migrations.

Runs the **full synthetic matrix sizes** (``common.FULL_SIM_SCALES``) on
the vectorized Emu engine by default.  Run standalone to sweep a chosen
distribution against the ``row`` baseline:

    python -m benchmarks.fig6_distribution --distribution nnz \
        --matrices webbase-1M rmat
    python -m benchmarks.fig6_distribution --fast     # legacy small sizes

Each CSV row reports bandwidth, the migration ratio, and the per-nodelet
instruction-count CV from the tick simulator (``row_cv`` vs ``<dist>_cv``)
— the paper's Fig. 7 balance metric (``EmuResult.instr_cv``).  On the
power-law generators the nonzero split must come out with the lower CV.
"""
import argparse

from repro.core.layout import make_layout
from repro.core.migration import count_migrations
from repro.core.partition import make_partition
from repro.data.matrices import make_matrix
from .common import COUNT_SCALES, FULL_SIM_SCALES, SIM_SCALES, emit, \
    sim_bandwidth


def run(distribution: str = "nonzero", matrices=None, fast: bool = False):
    names = matrices or list(FULL_SIM_SCALES)
    scales = SIM_SCALES if fast else FULL_SIM_SCALES
    rows = []
    for name in names:
        bws, cvs, migs = {}, {}, {}
        for strat in ("row", distribution):
            _, res = sim_bandwidth(name, strategy=strat, scale=scales[name])
            bws[strat] = res.bandwidth_mbs
            cvs[strat] = res.instr_cv
        A = make_matrix(name, scale=COUNT_SCALES[name])
        for strat in ("row", distribution):
            p = make_partition(A, 8, strat)
            migs[strat] = count_migrations(
                A, p, make_layout("block", A.ncols, 8),
                make_layout("block", A.nrows, 8)).migrations
        rows.append((f"fig6/{name}@{scales[name]}", round(bws["row"], 1),
                     round(bws[distribution], 1),
                     round(bws[distribution] / max(bws["row"], 1e-9), 2),
                     round(migs[distribution] / max(migs["row"], 1), 2),
                     round(cvs["row"], 3), round(cvs[distribution], 3)))
    d = distribution
    emit(rows, ("name", "row_mbs", f"{d}_mbs", f"{d}_speedup",
                f"mig_ratio_{d}_over_row", "row_cv", f"{d}_cv"))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--distribution", default="nonzero",
                    choices=("nonzero", "nnz"),
                    help="strategy to compare against the row baseline")
    ap.add_argument("--matrices", nargs="*", default=None,
                    choices=list(SIM_SCALES),
                    help="subset of the paper suite (default: all)")
    ap.add_argument("--fast", action="store_true",
                    help="legacy scaled-down workloads (SIM_SCALES)")
    args = ap.parse_args()
    run(distribution=args.distribution, matrices=args.matrices,
        fast=args.fast)
