"""Distributed train/serve step factories + the training loop.

``make_train_step`` builds the jitted SPMD step for a (config, mesh) pair:
batch over ("pod","data"), TP over "model", optional ZeRO-3 FSDP of params
and Adam state over "data", per-unit remat inside the layer scan, donated
buffers, optional int8+error-feedback gradient compression across the "pod"
axis.  ``make_decode_step``/``make_prefill_step`` are the serving versions.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as mm
from repro.models import params as pp
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw

Tree = Any


@dataclasses.dataclass(frozen=True)
class RunConfig:
    fsdp: bool = True
    remat: bool = True
    donate: bool = True
    compress_pod_grads: bool = False
    step_deadline_s: float = 0.0     # 0 = no straggler deadline
    model_axis: str = "model"
    # Analysis-grade lowering: True fully unrolls the layer scan; an int
    # partially unrolls it (XLA counts a while-loop body once, so the
    # dry-run extrapolates from a partial unroll).
    scan_unroll: object = False
    # Gradient accumulation: the global batch is split into this many
    # microbatches scanned per step (f32 grad accumulators stay sharded).
    # Keeps per-device activation memory ~ microbatch-sized.
    grad_accum: int = 1


def batch_axes_of(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_specs(cfg: ModelConfig, mesh: Mesh, shape_batch: int) -> Tree:
    """PartitionSpec tree for the input batch dict."""
    baxes = batch_axes_of(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    lead = P(baxes) if shape_batch % nb == 0 and shape_batch >= nb else P()

    def spec_like(name):
        return lead
    return spec_like


def _named(mesh: Mesh, spec_tree: Tree) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(cfg: ModelConfig, mesh: Mesh, run: RunConfig) -> Tree:
    data_axis = "data" if "data" in mesh.axis_names else None
    specs = pp.param_specs(cfg, fsdp=run.fsdp and data_axis is not None,
                           data_axis=data_axis, model_axis=run.model_axis)
    return _named(mesh, specs)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, mesh: Mesh,
                    run: RunConfig = RunConfig()):
    """Returns (jitted step, in_shardings tuple) — lowerable with abstract
    params/state/batch for the dry-run."""
    p_shard = param_shardings(cfg, mesh, run)
    o_shard = adamw.AdamWState(step=NamedSharding(mesh, P()),
                               m=p_shard, v=p_shard)
    baxes = batch_axes_of(mesh)

    def batch_shard(batch_tree: Tree) -> Tree:
        return jax.tree.map(lambda _: NamedSharding(mesh, P(baxes)), batch_tree)

    def step_fn(params, opt_state, batch, rng):
        unroll = run.scan_unroll or 1
        n_micro = run.grad_accum

        def lg(p, mb):
            return jax.value_and_grad(
                lambda q: mm.loss_fn(q, cfg, mb, rng=rng, remat=run.remat,
                                     scan_unroll=unroll), has_aux=True)(p)

        if n_micro == 1:
            (loss, metrics), grads = lg(params, batch)
        else:
            micro_batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                    NamedSharding(mesh, P(None, baxes))),
                batch)

            def micro_step(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = lg(params, mb)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / n_micro,
                    g_acc, g)
                return (g_acc, l_acc + l / n_micro), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(
                micro_step, (g0, jnp.zeros((), jnp.float32)), micro_batch)
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        new_params, new_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    def jit_for(batch_tree: Tree):
        donate = (0, 1) if run.donate else ()
        return jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, batch_shard(batch_tree),
                          NamedSharding(mesh, P())),
            out_shardings=(p_shard, o_shard,
                           jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                        {"loss": 0, "ce": 0, "aux": 0,
                                         "gnorm": 0, "lr": 0})),
            donate_argnums=donate)
    return step_fn, jit_for, (p_shard, o_shard)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> Tree:
    """PartitionSpec tree matching abstract_cache's structure."""
    baxes = batch_axes_of(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    b = baxes if batch % nb == 0 and batch >= nb else None
    ma = "model"

    def kv_spec(kind):
        if kind == "local_attn":
            return (P(b, None, None, None), P(b, None, None, None))
        return (P(b, ma, None, None), P(b, ma, None, None))

    def block_spec(cfg, kind):
        if kind in ("attn", "moe", "local_attn"):
            return kv_spec(kind)
        if kind == "mlstm":
            dk_ok = (int(cfg.d_model * cfg.lstm_proj_factor) //
                     cfg.num_heads) % mesh.shape[ma] == 0
            m = ma if dk_ok else None
            return (P(b, None, m, None), P(b, None, m))
        if kind == "slstm":
            return (P(b), P(b), P(b), P(b))
        if kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            m = ma if w % mesh.shape[ma] == 0 else None
            return (P(b, m), P(b, None, m))
        raise ValueError(kind)

    unit = cfg.pattern()
    n_scan = cfg.num_layers - cfg.dense_first_layers
    tail_kinds = unit[: n_scan % len(unit)]

    def stack_spec(kind):
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))),
                            block_spec(cfg, kind),
                            is_leaf=lambda x: isinstance(x, P))

    return {
        "stack": {f"u{j}_{k}": stack_spec(k) for j, k in enumerate(unit)},
        "tail": {f"t{j}_{k}": block_spec(cfg, k)
                 for j, k in enumerate(tail_kinds)},
        "prefix": {f"p{j}_{unit[0]}": block_spec(cfg, unit[0])
                   for j in range(cfg.dense_first_layers)},
    }


def make_decode_step(cfg: ModelConfig, mesh: Mesh, batch: int,
                     run: RunConfig = RunConfig()):
    p_shard = param_shardings(cfg, mesh, run)
    c_shard = _named(mesh, cache_specs(cfg, mesh, batch))
    baxes = batch_axes_of(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    tok_spec = P(baxes) if batch % nb == 0 and batch >= nb else P()

    def serve_step(params, tokens, caches, pos):
        return mm.decode_step(params, cfg, tokens, caches, pos,
                              scan_unroll=run.scan_unroll or 1)

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_shard, NamedSharding(mesh, tok_spec), c_shard,
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec), c_shard),
        donate_argnums=(2,))
    return serve_step, jitted, (p_shard, c_shard)


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, batch: int,
                      run: RunConfig = RunConfig()):
    p_shard = param_shardings(cfg, mesh, run)
    baxes = batch_axes_of(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes]))
    tok_spec = P(baxes) if batch % nb == 0 and batch >= nb else P()

    def prefill_step(params, batch_inputs):
        return mm.prefill(params, cfg, batch_inputs,
                          scan_unroll=run.scan_unroll or 1)

    def jit_for(batch_tree: Tree):
        return jax.jit(
            prefill_step,
            in_shardings=(p_shard,
                          jax.tree.map(lambda _: NamedSharding(mesh, tok_spec),
                                       batch_tree)),
            out_shardings=NamedSharding(mesh, tok_spec))
    return prefill_step, jit_for, p_shard


def train_loop(cfg: ModelConfig, opt_cfg, mesh, stream, steps: int,
               run: RunConfig = RunConfig(), *, checkpoint_dir=None,
               checkpoint_every: int = 0, start_step: int = 0,
               params=None, opt_state=None, on_metrics=None):
    """Host training loop with checkpoint/restart + straggler deadline."""
    from repro.train import checkpoint as ckpt
    key = jax.random.PRNGKey(0)
    if params is None:
        params = pp.init_params(cfg, key)
        opt_state = adamw.init_state(params)
    _, jit_for, _ = make_train_step(cfg, opt_cfg, mesh, run)
    step_jit = None
    metrics = {}
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        if step_jit is None:
            step_jit = jit_for(batch)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_jit(params, opt_state, batch,
                                              jax.random.fold_in(key, step))
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        if run.step_deadline_s and dt > run.step_deadline_s:
            metrics["straggler"] = dt       # deadline breach -> logged + hook
        if on_metrics:
            on_metrics(step, metrics)
        if checkpoint_dir and checkpoint_every and \
                (step + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, params, opt_state, step + 1)
    return params, opt_state, metrics
