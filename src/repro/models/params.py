"""Parameter construction: shapes, init, counting, and sharding specs.

The parameter tree mirrors the stacked-scan layout::

    params = {
      "embed": (V, d),
      "stack": { pos_j: {block params with leading n_units axis} },
      "tail":  [ per-layer block params (pattern remainder, unscanned) ],
      "prefix":[ dense-first layers for MoE archs ],
      "final_norm": (d,), "lm_head": (d, V or K*V),
    }

Shapes are produced *abstractly* (``abstract_params``) so the dry-run can
lower against ShapeDtypeStructs without allocating 314 B parameters, and
concretely (``init_params``) for smoke tests / real training.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

Tree = Any
PDTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# per-block shape tables: dict name -> (shape, spec)
# spec axes use logical names: "fsdp" -> data axis, "tp" -> model axis
# --------------------------------------------------------------------------

def _attn_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = {
        "norm1": ((d,), P()),
        "wq": ((d, qd), P("fsdp", "tp")),
        "wk": ((d, kvd), P("fsdp", "tp")),
        "wv": ((d, kvd), P("fsdp", "tp")),
        "wo": ((qd, d), P("tp", "fsdp")),
    }
    if cfg.qkv_bias:
        s |= {"bq": ((qd,), P("tp")), "bk": ((kvd,), P("tp")),
              "bv": ((kvd,), P("tp"))}
    if cfg.qk_norm:
        s |= {"q_norm": ((cfg.head_dim,), P()), "k_norm": ((cfg.head_dim,), P())}
    return s


def _ffn_shapes(cfg: ModelConfig, d_ff: int) -> Dict[str, tuple]:
    d = cfg.d_model
    return {
        "norm2": ((d,), P()),
        "w_gate": ((d, d_ff), P("fsdp", "tp")),
        "w_up": ((d, d_ff), P("fsdp", "tp")),
        "w_down": ((d_ff, d), P("tp", "fsdp")),
    }


def _moe_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    m = cfg.moe
    E, f = m.num_experts * m.expert_split, m.d_expert // m.expert_split
    # Expert-parallel over tp when E divides the axis (deepseek 64e, grok
    # 8e x split 2), with the d-dim FSDP-sharded over data (ZeRO-3 — the
    # optimizer state of a 314B MoE cannot live TP-sharded only, §Perf H2).
    # Otherwise TP inside each expert (E replicated, f sharded).
    if E % 16 == 0:
        w_specs = (P("tp", "fsdp", None), P("tp", "fsdp", None),
                   P("tp", None, "fsdp"))
    else:
        w_specs = (P(None, "fsdp", "tp"), P(None, "fsdp", "tp"),
                   P(None, "tp", "fsdp"))
    s = {
        "norm2": ((d,), P()),
        "router": ((d, E), P()),
        "w_gate": ((E, d, f), w_specs[0]),
        "w_up": ((E, d, f), w_specs[1]),
        "w_down": ((E, f, d), w_specs[2]),
    }
    if m.num_shared:
        fs = f * m.num_shared
        s |= {"s_gate": ((d, fs), P("fsdp", "tp")),
              "s_up": ((d, fs), P("fsdp", "tp")),
              "s_down": ((fs, d), P("tp", "fsdp"))}
    return s


def _mlstm_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    inner = int(d * cfg.lstm_proj_factor)
    H = cfg.num_heads
    return {
        "norm1": ((d,), P()),
        "w_qkv": ((d, 4 * inner), P("fsdp", "tp")),
        "w_gates": ((d, 2 * H), P()),
        "w_out": ((inner, d), P("tp", "fsdp")),
    }


def slstm_inner(cfg: ModelConfig) -> int:
    """sLSTM up-projection width: ~4/3 d, rounded so heads AND a 16-wide
    model axis divide it (mesh divisibility is a hard pjit requirement)."""
    unit = cfg.num_heads * 16
    return ((int(cfg.d_model * 4 / 3) + unit - 1) // unit) * unit


def _slstm_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    inner = slstm_inner(cfg)
    Dh = inner // cfg.num_heads
    return {
        "norm1": ((d,), P()),
        "w_in": ((d, 4 * inner), P("fsdp", "tp")),
        "r_kernel": ((cfg.num_heads, Dh, 4 * Dh), P()),
        "w_out": ((inner, d), P("tp", "fsdp")),
    }


def _rglru_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    w = cfg.lru_width or d
    return {
        "norm1": ((d,), P()),
        "w_gelu_gate": ((d, w), P("fsdp", "tp")),
        "w_in": ((d, w), P("fsdp", "tp")),
        "conv_kernel": ((cfg.conv_width, w), P(None, "tp")),
        "w_rgate": ((w, w), P("fsdp", "tp")),
        "w_igate": ((w, w), P("fsdp", "tp")),
        "lam": ((w,), P("tp")),
        "w_out": ((w, d), P("tp", "fsdp")),
    }


def block_shapes(cfg: ModelConfig, kind: str, *, dense_ffn: bool = False
                 ) -> Dict[str, tuple]:
    if kind in ("attn", "local_attn"):
        s = _attn_shapes(cfg)
        if cfg.d_ff:
            s |= _ffn_shapes(cfg, cfg.d_ff)
        return s
    if kind == "moe":
        s = _attn_shapes(cfg)
        s |= _ffn_shapes(cfg, cfg.d_ff) if dense_ffn else _moe_shapes(cfg)
        return s
    if kind == "mlstm":
        return _mlstm_shapes(cfg)
    if kind == "slstm":
        return _slstm_shapes(cfg)
    if kind == "rglru":
        s = _rglru_shapes(cfg)
        if cfg.d_ff:
            s |= _ffn_shapes(cfg, cfg.d_ff)
        return s
    raise ValueError(f"unknown block kind {kind!r}")


def model_shape_tree(cfg: ModelConfig) -> Dict[str, Any]:
    """Full (shape, spec) tree for the model."""
    d, V = cfg.d_model, cfg.vocab_size
    unit = cfg.pattern()
    n_scan_layers = cfg.num_layers - cfg.dense_first_layers
    n_units = n_scan_layers // len(unit)
    tail_kinds = unit[: n_scan_layers % len(unit)]

    def stacked(shapes: Dict[str, tuple], n: int):
        return {k: ((n, *shp), P(*((None,) + tuple(sp))) if n else sp)
                for k, (shp, sp) in shapes.items()}

    tree: Dict[str, Any] = {
        "embed": ((V, d), P("tp", None)),
        "final_norm": ((d,), P()),
    }
    head_out = V * cfg.num_codebooks
    if not cfg.tie_embeddings:
        tree["lm_head"] = ((d, head_out), P(None, "tp"))
    tree["stack"] = {
        f"u{j}_{kind}": stacked(block_shapes(cfg, kind), n_units)
        for j, kind in enumerate(unit)
    }
    tree["tail"] = {
        f"t{j}_{kind}": block_shapes(cfg, kind)
        for j, kind in enumerate(tail_kinds)
    }
    tree["prefix"] = {
        f"p{j}_{unit[0]}": block_shapes(cfg, unit[0], dense_ffn=True)
        for j in range(cfg.dense_first_layers)
    }
    return tree


def _leaf_dtype(name: str) -> jnp.dtype:
    return jnp.float32 if name in ("lam",) else PDTYPE


def abstract_params(cfg: ModelConfig) -> Tree:
    return jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t[0], PDTYPE),
        model_shape_tree(cfg), is_leaf=lambda t: isinstance(t, tuple) and
        isinstance(t[0], tuple))


def param_specs(cfg: ModelConfig, *, fsdp: bool, data_axis="data",
                model_axis="model") -> Tree:
    """PartitionSpec tree with logical axes resolved to mesh axes."""
    def resolve(t):
        spec = t[1]
        out = []
        for ax in spec:
            if ax == "tp":
                out.append(model_axis)
            elif ax == "fsdp":
                out.append(data_axis if fsdp else None)
            else:
                out.append(ax)
        return P(*out)
    return jax.tree.map(resolve, model_shape_tree(cfg),
                        is_leaf=lambda t: isinstance(t, tuple) and
                        isinstance(t[0], tuple))


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    shapes = model_shape_tree(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda t: isinstance(t, tuple) and isinstance(t[0], tuple))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, (shp, _) in zip(keys, leaves):
        fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        out.append((jax.random.normal(k, shp, jnp.float32) * scale).astype(PDTYPE))
    return jax.tree.unflatten(treedef, out)


def count_params_config(cfg: ModelConfig, *, active_only: bool = False) -> int:
    """Analytic parameter count; ``active_only`` counts top-k experts only."""
    total = 0
    tree = model_shape_tree(cfg)

    def visit(path, t):
        nonlocal total
        n = int(np.prod(t[0]))
        E_eff = (cfg.moe.num_experts * cfg.moe.expert_split
                 if cfg.moe is not None else 0)
        if active_only and cfg.moe is not None and path and \
                path[-1] in ("w_gate", "w_up", "w_down") and len(t[0]) >= 3 \
                and t[0][-3] == E_eff:
            n = n * (cfg.moe.top_k + cfg.moe.num_shared) // cfg.moe.num_experts
        total += n

    def walk(prefix, node):
        if isinstance(node, tuple) and isinstance(node[0], tuple):
            visit(prefix, node)
            return
        for k, v in node.items():
            walk(prefix + (k,), v)

    walk((), tree)
    return total
