"""Serving-path regression tests: Engine.generate edge semantics, the
SparseMatrixEngine error/stats contract, batched multi-RHS SpMV exactness,
the feature-keyed plan cache (in-memory and disk-backed), warm-start
ingest from persistent program artifacts, per-tenant rebalance state, and
cross-request micro-batching.
"""
import numpy as np
import pytest

from repro.core.sparse_matrix import csr_to_dense
from repro.core.spmv import SpmvPlan, build_distributed, local_spmv
from repro.data.matrices import make_matrix
from repro.serve.engine import Engine, ServeConfig, SparseMatrixEngine


# --------------------------------------------------------------------------
# Engine.generate edges (prefill/decode semantics)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_engine():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models import params as pp
    cfg = get_smoke_config("qwen3_4b")
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_steps_zero_returns_prompts(lm_engine):
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = np.array([[1, 2, 3], [4, 5, 6]], dtype=np.int32)
    out = eng.generate(prompts, steps=0)
    np.testing.assert_array_equal(out, prompts)
    # and a (B, 0) prompt with steps=0 is a harmless no-op
    empty = np.zeros((2, 0), dtype=np.int32)
    assert eng.generate(empty, steps=0).shape == (2, 0)
    # steps=0 never samples, so it must not demand a key either
    sampling = Engine(cfg, params, ServeConfig(max_len=32, temperature=0.9))
    np.testing.assert_array_equal(sampling.generate(prompts, steps=0),
                                  prompts)


def test_generate_empty_prefill_raises(lm_engine):
    """S0 == 0 with steps > 0 used to crash with NameError on `logits`;
    the chosen semantics are an explicit error telling callers to seed
    the prompt (e.g. BOS)."""
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    empty = np.zeros((2, 0), dtype=np.int32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate(empty, steps=4)


def test_generate_temperature_requires_key(lm_engine):
    """temperature > 0 without a key used to silently decode greedily."""
    import jax
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32, temperature=0.8))
    prompts = np.array([[1, 2]], dtype=np.int32)
    with pytest.raises(ValueError, match="PRNG key"):
        eng.generate(prompts, steps=2)
    out = eng.generate(prompts, steps=2, key=jax.random.PRNGKey(0))
    assert out.shape == (1, 4)


def test_generate_greedy_still_works(lm_engine):
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = np.array([[1, 2]], dtype=np.int32)
    out = eng.generate(prompts, steps=3)
    assert out.shape == (1, 5)
    np.testing.assert_array_equal(out[:, :2], prompts)


# --------------------------------------------------------------------------
# SparseMatrixEngine contract
# --------------------------------------------------------------------------

def test_spmv_unknown_name_is_actionable_and_uncounted():
    eng = SparseMatrixEngine(num_shards=4)
    A = make_matrix("ford1", scale=0.05)
    eng.ingest("ford", A)
    x = np.zeros(A.ncols)
    with pytest.raises(KeyError, match="ford"):
        eng.spmv("typo", x)
    # the failed call neither counted nor created anything
    assert eng.stats()["ford"]["spmv_count"] == 0
    assert set(eng.stats()) == {"ford"}
    eng.spmv("ford", x)
    assert eng.stats()["ford"]["spmv_count"] == 1
    with pytest.raises(KeyError):
        eng.plan("typo")


def test_batched_spmv_bitwise_matches_per_vector():
    """(M, B) blocks equal per-vector calls bitwise, both kernels."""
    A = make_matrix("cop20k_A", scale=0.005)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((A.ncols, 4))
    for kernel in ("ell", "seg"):
        dist = build_distributed(A, SpmvPlan(kernel=kernel, num_shards=4,
                                             reordering="bfs"))
        Y = local_spmv(dist, X)
        assert Y.shape == (A.nrows, 4)
        for b in range(X.shape[1]):
            assert np.array_equal(Y[:, b], local_spmv(dist, X[:, b])), \
                (kernel, b)
        np.testing.assert_allclose(Y, csr_to_dense(A) @ X, atol=1e-6)
    with pytest.raises(ValueError, match="elements"):
        local_spmv(dist, X[: A.ncols // 2])
    with pytest.raises(ValueError, match=r"\(N,\) or \(N, B\)"):
        local_spmv(dist, X[..., None])


def test_engine_serves_batched_requests():
    eng = SparseMatrixEngine(num_shards=4)
    A = make_matrix("rmat", scale=0.002)
    eng.ingest("r", A)
    X = np.random.default_rng(1).standard_normal((A.ncols, 3))
    Y = eng.spmv("r", X)
    np.testing.assert_allclose(Y, csr_to_dense(A) @ X, atol=1e-6)
    for b in range(3):
        assert np.array_equal(eng.spmv("r", X[:, b]), Y[:, b])


def test_plan_cache_reuses_structural_twins():
    eng = SparseMatrixEngine(num_shards=4)
    c1 = eng.ingest("m1", make_matrix("rmat", scale=0.002, seed=0))
    assert eng.plan_cache_hits == 0
    c2 = eng.ingest("m2", make_matrix("rmat", scale=0.002, seed=7))
    assert eng.plan_cache_hits == 1
    assert eng.stats()["m2"]["plan_cache_hit"]
    assert not eng.stats()["m1"]["plan_cache_hit"]
    assert c2.plan == c1.plan
    assert len(c2.ranking) == 1 and c2.probed == 0   # no grid re-run
    # a different archetype misses
    eng.ingest("banded", make_matrix("ford1", scale=0.05))
    assert eng.plan_cache_hits == 1
    # cached plans still serve correctly
    A2 = make_matrix("rmat", scale=0.002, seed=7)
    x = np.random.default_rng(2).standard_normal(A2.ncols)
    np.testing.assert_allclose(eng.spmv("m2", x), csr_to_dense(A2) @ x,
                               atol=1e-6)


def test_plan_cache_can_be_disabled():
    eng = SparseMatrixEngine(num_shards=4, plan_cache=False)
    eng.ingest("m1", make_matrix("rmat", scale=0.002, seed=0))
    c2 = eng.ingest("m2", make_matrix("rmat", scale=0.002, seed=7))
    assert eng.plan_cache_hits == 0
    assert len(c2.ranking) > 1                       # full grid ran


# --------------------------------------------------------------------------
# Multi-tenant router: warm-start artifacts, shared plan cache, batching
# --------------------------------------------------------------------------

def test_warm_start_ingest_skips_autotune_and_lower(tmp_path, monkeypatch):
    """A restarted engine pointed at the artifact store loads every tenant
    digest-hit: no autotune, no lower, bitwise-identical serving."""
    A = make_matrix("cop20k_A", scale=0.005)
    B = make_matrix("ford1", scale=0.05)
    store = str(tmp_path / "artifacts")
    e1 = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    c1a = e1.ingest("a", A)
    e1.ingest("b", B)
    rng = np.random.default_rng(0)
    xa = rng.standard_normal(A.ncols)
    xb = rng.standard_normal(B.ncols)
    ya, yb = e1.spmv("a", xa), e1.spmv("b", xb)

    # the warm path must touch neither the autotuner nor the lowerer
    import repro.serve.router as router
    monkeypatch.setattr(router, "autotune", _boom)
    monkeypatch.setattr(router, "lower", _boom)
    e2 = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    c2a = e2.ingest("a", A)
    e2.ingest("b", B)
    assert e2.warm_starts == 2
    assert e2.stats()["a"]["warm_start"] and e2.stats()["b"]["warm_start"]
    assert c2a == c1a                       # full PlanChoice round-trips
    assert np.array_equal(e2.spmv("a", xa), ya)
    assert np.array_equal(e2.spmv("b", xb), yb)


def _boom(*a, **k):
    raise AssertionError("warm-start ingest must not reach this path")


def test_warm_start_digest_mismatch_falls_back_cold(tmp_path):
    """Re-ingesting a same-name tenant with different values must miss the
    artifact (stale numerics) and re-tune cold — correctly."""
    from repro.core.sparse_matrix import CSRMatrix
    A = make_matrix("rmat", scale=0.002)
    store = str(tmp_path / "artifacts")
    e1 = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    e1.ingest("a", A)
    A2 = CSRMatrix(shape=A.shape, values=A.values * 2.0,
                   col_index=A.col_index, row_ptr=A.row_ptr)
    e2 = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    e2.ingest("a", A2)
    assert not e2.stats()["a"]["warm_start"]
    x = np.random.default_rng(1).standard_normal(A.ncols)
    np.testing.assert_allclose(e2.spmv("a", x), csr_to_dense(A2) @ x,
                               atol=1e-6)
    # the fallback also rewrote the bundle: a third engine warm-starts A2
    e3 = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    e3.ingest("a", A2)
    assert e3.stats()["a"]["warm_start"]
    assert np.array_equal(e3.spmv("a", x), e2.spmv("a", x))


def test_disk_plan_cache_shared_across_engine_instances(tmp_path):
    """plan_cache_dir makes the feature-keyed cache an L2 shared by
    engine instances: the second instance skips the grid entirely."""
    cache = str(tmp_path / "plans")
    e1 = SparseMatrixEngine(num_shards=4, plan_cache_dir=cache)
    c1 = e1.ingest("m1", make_matrix("rmat", scale=0.002, seed=0))
    assert e1.plan_cache_hits == 0
    e2 = SparseMatrixEngine(num_shards=4, plan_cache_dir=cache)
    c2 = e2.ingest("m2", make_matrix("rmat", scale=0.002, seed=7))
    assert e2.plan_cache_hits == 1
    assert c2.plan == c1.plan
    assert len(c2.ranking) == 1 and c2.probed == 0   # no grid re-run


def test_per_tenant_rebalance_config_override():
    from repro.serve.rebalance import RebalanceConfig
    eng = SparseMatrixEngine(num_shards=4)           # no engine default
    A = make_matrix("rmat", scale=0.002)
    eng.ingest("watched", A, rebalance=RebalanceConfig(window=16))
    eng.ingest("plain", A)
    assert "rebalance" in eng.stats()["watched"]
    assert "rebalance" not in eng.stats()["plain"]
    # and an engine-wide default can be switched off per tenant
    eng2 = SparseMatrixEngine(num_shards=4, rebalance=True)
    eng2.ingest("off", A, rebalance=False)
    eng2.ingest("on", A)
    assert "rebalance" not in eng2.stats()["off"]
    assert "rebalance" in eng2.stats()["on"]


def test_micro_batching_gathers_concurrent_requests():
    """Concurrent single-vector requests for one tenant share a batched
    (N, B) execute and still return bitwise-solo results."""
    import threading
    from repro.serve.router import MicroBatchConfig
    A = make_matrix("cop20k_A", scale=0.005)
    solo = SparseMatrixEngine(num_shards=4)
    solo.ingest("a", A)
    eng = SparseMatrixEngine(
        num_shards=4,
        micro_batch=MicroBatchConfig(max_batch=4, max_wait_ms=100.0))
    eng.ingest("a", A)
    rng = np.random.default_rng(2)
    xs = [rng.standard_normal(A.ncols) for _ in range(4)]
    want = [solo.spmv("a", x) for x in xs]
    got = [None] * 4
    barrier = threading.Barrier(4)

    def hit(i):
        barrier.wait()
        got[i] = eng.spmv("a", xs[i])

    threads = [threading.Thread(target=hit, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(4):
        assert np.array_equal(got[i], want[i]), i
    mb = eng.stats()["a"]["micro_batch"]
    assert mb["requests"] == 4
    assert mb["widest"] >= 2                 # at least one real gather
    assert eng.stats()["a"]["spmv_count"] == 4
    # multi-RHS blocks bypass the batcher unchanged
    X = np.stack(xs, axis=1)
    assert np.array_equal(eng.spmv("a", X), np.stack(want, axis=1))


def test_rebalance_swap_rewrites_artifact(tmp_path):
    """After a drift-triggered swap the tenant's bundle holds the *new*
    program: a restart warm-starts straight into the post-drift plan."""
    from repro.serve.rebalance import RebalanceConfig
    cfg = RebalanceConfig(window=32, patience=2, cooldown=2, probe=2)
    A = make_matrix("cop20k_A", scale=0.005)
    N = A.ncols
    store = str(tmp_path / "artifacts")
    eng = SparseMatrixEngine(num_shards=4, rebalance=cfg,
                             artifact_dir=store)
    eng.ingest("a", A)
    m = eng._matrices["a"]
    d = m.dist
    order = np.arange(N) if d.perm is None else d.perm
    hot = np.flatnonzero(d.x_layout.owner_of(order) == 0)
    rng = np.random.default_rng(0)
    k = max(N // 20, 8)
    for _ in range(2 * cfg.window):                  # uniform warm-up
        x = np.zeros(N)
        x[rng.integers(0, N, k)] = rng.standard_normal(k)
        eng.spmv("a", x)
    for i in range(10 * cfg.window):                 # sustained hot-spot
        x = np.zeros(N)
        x[rng.choice(hot, size=k)] = rng.standard_normal(k)
        eng.spmv("a", x)
        if any(e.swapped for e in m.rebalance_log):
            break
    assert any(e.swapped for e in m.rebalance_log), "drift never swapped"
    # restart: the bundle must hand back the swapped-in plan, warm
    fresh = SparseMatrixEngine(num_shards=4, artifact_dir=store)
    fresh.ingest("a", A)
    assert fresh.stats()["a"]["warm_start"]
    assert fresh.plan("a") == eng.plan("a")
    x = np.zeros(N)
    x[rng.choice(hot, size=k)] = rng.standard_normal(k)
    assert np.array_equal(fresh.spmv("a", x), eng.spmv("a", x))
