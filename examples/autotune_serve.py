"""Autotuned SpMV serving in ~40 lines.

Ingest structurally different matrices (including a mixed-structure one)
into the sparse serving engine; each gets its own cost-model-tuned plan at
load time (no hand-picked layouts/kernels — and since the SpmvProgram
refactor, a kernel *per shard*), then serve y = A @ x requests and print
which plan each matrix ended up with, shard by shard, and why it differs.

    PYTHONPATH=src python examples/autotune_serve.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.sparse_matrix import csr_to_dense
from repro.data.matrices import make_matrix, mixed_structure
from repro.serve.engine import SparseMatrixEngine


def _shards_str(kernels) -> str:
    """Compress ('ell','ell','seg',...) to 'ell x2 + seg x6' style."""
    runs = []
    for k in kernels:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    return " + ".join(f"{k}x{n}" if n > 1 else k for k, n in runs)


def main():
    # probe=20 measures every (reordering, layout, distribution) base at
    # ingest — the mixed matrix's locality-rich bases rank poorly on the
    # analytic issue term, so the default small probe budget would never
    # simulate them (the vectorized Emu engine keeps this milliseconds).
    eng = SparseMatrixEngine(num_shards=8, probe=20)
    rng = np.random.default_rng(0)
    suite = {name: make_matrix(name, scale=scale)
             for name, scale in (("cop20k_A", 0.02), ("webbase-1M", 0.002),
                                 ("audikw_1", 0.001))}
    suite["mixed"] = mixed_structure(2048, 33 * 2048)

    print(f"{'matrix':12s} {'chosen plan':26s} {'per-shard kernels':24s} "
          f"{'migrations':>10s} {'hot-share':>9s} {'served-ok':>9s}")
    for name, A in suite.items():
        eng.ingest(name, A)                       # autotunes here
        x = rng.standard_normal(A.ncols)
        y = eng.spmv(name, x)
        ok = np.allclose(y, csr_to_dense(A) @ x, atol=1e-6)
        s = eng.stats()[name]
        p = s["plan"]
        plan = f"{p['reordering']}/{p['layout']}/{p['distribution']}"
        print(f"{name:12s} {plan:26s} {_shards_str(s['shard_kernels']):24s} "
              f"{s['migrations']:10d} {s['hotspot_share']:9.3f} "
              f"{str(ok):>9s}")

    print("\nhot-spot FEM -> reordered; power-law -> nonzero split; "
          "wide-band -> plain block; mixed structure -> a different kernel "
          "per shard. The study, applied as policy — per nodelet.")


if __name__ == "__main__":
    main()
