"""Model configuration schema + the 10 assigned architectures.

Every architecture is expressed in one dataclass; ``block_pattern`` encodes
heterogeneous stacks (hybrid/ssm archs) as a repeating unit, scanned as a
super-block.  Exact figures follow the assignment table (sources noted in
each config module under repro/configs/).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0          # shared (always-on) experts
    d_expert: int = 0            # expert FFN width
    capacity_factor: float = 1.25
    valiant_shuffle: bool = False  # paper's random-reorder analogue (§4 DESIGN)
    router_zloss: float = 1e-3
    # Exact SwiGLU decomposition of each expert into `expert_split` thinner
    # experts (split f columns; duplicate routing weights).  Lets an expert
    # count that does not divide the model axis become expert-parallel
    # (grok: 8 experts x split 2 = 16 — §Perf H2).
    expert_split: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"   # swiglu | geglu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    # Heterogeneous stacks: repeating unit of block kinds; None = ["attn"].
    # kinds: attn, local_attn, mlstm, slstm, rglru, moe (ffn follows attn
    # blocks implicitly; moe blocks use MoEConfig for their ffn)
    block_pattern: Optional[Tuple[str, ...]] = None
    attn_window: Optional[int] = None       # local attention window
    moe: Optional[MoEConfig] = None
    dense_first_layers: int = 0             # MoE archs with dense first N
    # Modality frontends are stubs: input_specs() supplies embeddings.
    frontend: Optional[str] = None          # encodec_stub | siglip_stub
    num_codebooks: int = 1                  # audio heads (musicgen)
    prefix_len: int = 0                     # vlm image-prefix tokens
    # ssm internals
    lstm_proj_factor: float = 2.0
    lru_width: Optional[int] = None
    conv_width: int = 4
    # serving
    max_seq_len: int = 8192

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("attn",)

    def param_count(self) -> int:
        """Total parameters (embedding + blocks), for 6ND roofline math."""
        from repro.models.params import count_params_config
        return count_params_config(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_config
        return count_params_config(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

# Architectures whose attention is fully quadratic skip long_500k (the skip
# is recorded in docs/ARCHITECTURE.md#design-5); SSM/hybrid archs run it.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True
