"""Sharded, async, atomic checkpoints (npz + JSON manifest).

Layout::

    <dir>/step_000123/          # atomic: written as .tmp then renamed
        manifest.json           # step, tree structure, leaf shapes/dtypes
        host_000.npz            # this host's leaves (full arrays here; on a
                                # real pod each host saves its addressable
                                # shards and restore re-assembles)

Writes happen on a background thread against host copies so the training
loop never blocks on disk (compute/IO overlap); ``wait()`` drains the queue.
Restore takes a target sharding tree so a *differently-shaped mesh* (elastic
restart) can re-shard the same checkpoint — see train/elastic.py.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any
_WRITER: Optional["_AsyncWriter"] = None


def _flatten_with_names(tree: Tree):
    # jax.tree.flatten_with_path only exists on jax >= 0.5; the tree_util
    # spelling works on every version this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves, treedef


class _AsyncWriter:
    def __init__(self):
        self.q: "queue.Queue" = queue.Queue()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            path, names, arrays, manifest = item
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{jax.process_index():03d}.npz"),
                     **dict(zip(names, arrays)))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self.q.task_done()

    def submit(self, *item):
        self.q.put(item)

    def wait(self):
        self.q.join()


def _writer() -> _AsyncWriter:
    global _WRITER
    if _WRITER is None:
        _WRITER = _AsyncWriter()
    return _WRITER


def save(ckpt_dir: str, params: Tree, opt_state: Tree, step: int,
         *, blocking: bool = False) -> str:
    state = {"params": params, "opt": opt_state}
    names, leaves, _ = _flatten_with_names(state)
    # Device->host copy happens synchronously (cheap vs the disk write);
    # serialization + fsync happen on the writer thread.  npz cannot store
    # ml_dtypes (bf16) natively — widen to f32 on disk; restore re-casts.
    def savable(a):
        a = np.asarray(jax.device_get(a))
        return a.astype(np.float32) if a.dtype.name == "bfloat16" else a

    arrays = [savable(l) for l in leaves]
    manifest = {"step": step, "names": names,
                "shapes": [list(a.shape) for a in arrays],
                "dtypes": [str(a.dtype) for a in arrays]}
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    w = _writer()
    w.submit(path, names, arrays, manifest)
    if blocking:
        w.wait()
    return path


def wait_for_writes():
    if _WRITER is not None:
        _WRITER.wait()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Tree,
            shardings: Optional[Tree] = None) -> tuple[Tree, int]:
    """Restore into the structure of ``like`` ({"params":…, "opt":…}).

    ``shardings`` (same structure) places each leaf on the target mesh —
    pass the *new* mesh's shardings to re-shard on elastic restart.
    """
    path = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"host_{jax.process_index():03d}.npz"))
    names, leaves, treedef = _flatten_with_names(like)
    assert names == manifest["names"], "checkpoint/model structure mismatch"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for name, ref, sh in zip(names, leaves, shard_leaves):
        arr = data[name]
        tgt_dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
        if arr.dtype != tgt_dtype:
            # numpy lacks cast kernels for ml_dtypes (bf16) — cast via jnp.
            arr = np.asarray(jnp.asarray(arr).astype(tgt_dtype))
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]
