"""command-r-plus-104b [dense] — hf:CohereForAI/c4ai-command-r-v01
(unverified).  GQA kv=8, no biases."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense", num_layers=64,
    d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256_000, activation="swiglu",
    rope_theta=75_000.0)

def smoke_config():
    return ModelConfig(
        name="command-r-plus-smoke", family="dense", num_layers=2,
        d_model=64, num_heads=8, num_kv_heads=2, head_dim=8, d_ff=128,
        vocab_size=512, activation="swiglu")
