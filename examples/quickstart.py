"""Quickstart: the paper's whole optimization study in ~40 lines.

Builds a cop20k_A-like matrix, runs distributed SpMV plans across the
paper's grid (layout x distribution x reordering), and prints the Emu-model
bandwidth + the exact migration counts for each — Figs. 3/6/10 in
miniature.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.migration import count_migrations
from repro.core.partition import make_partition
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix


def main():
    A = make_matrix("cop20k_A", scale=0.02)
    print(f"matrix: cop20k_A-like {A.shape}, nnz={A.nnz}\n")
    print(f"{'plan':38s} {'MB/s':>8s} {'migrations':>11s} {'hot-share':>9s}")
    cfg = EmuConfig()
    for reordering in ("none", "random", "bfs", "metis"):
        B = reorder(A, reordering)
        for layout in ("cyclic", "block"):
            for dist in ("row", "nonzero"):
                part = make_partition(B, 8, dist)
                xl = make_layout(layout, B.ncols, 8)
                bl = make_layout(layout, B.nrows, 8)
                traffic = count_migrations(B, part, xl, bl)
                res = run_spmv(B, part, xl, cfg)
                name = f"{reordering:7s} {layout:7s} {dist:8s}"
                print(f"{name:38s} {res.bandwidth_mbs:8.1f} "
                      f"{traffic.migrations:11d} "
                      f"{traffic.hotspot_share:9.3f}")
    print("\npaper's findings, reproduced: block > cyclic; nonzero >= row;")
    print("BFS/METIS/random reorderings beat the original on the hot-spot")
    print("matrix; random trades migrations for hot-spot dispersal.")


if __name__ == "__main__":
    main()
