"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the host mesh, with checkpoints and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --d-model 256

The config is the qwen3 family (GQA + qk_norm) scaled to ~100M params; the
loop is the same `train_loop` the production launcher uses (remat, donation,
grad accumulation, checkpoint/restart).
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.data.synthetic import DataConfig, TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.loop import RunConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="qwen3-100m", family="dense", num_layers=args.layers,
        d_model=args.d_model, num_heads=8, num_kv_heads=2,
        head_dim=args.d_model // 8, d_ff=args.d_model * 4,
        vocab_size=32_000, activation="swiglu", qk_norm=True)
    from repro.models.params import count_params_config
    print(f"model: {count_params_config(cfg)/1e6:.1f}M params")

    mesh = make_host_mesh()
    stream = TokenStream(cfg, DataConfig(seed=0, batch=args.batch,
                                         seq_len=args.seq))
    run = RunConfig(fsdp=False, remat=True, donate=True, grad_accum=2)
    opt = adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)

    def report(step, metrics):
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={metrics['loss']:.4f} "
                  f"gnorm={metrics['gnorm']:.2f} lr={metrics['lr']:.2e}")

    train_loop(cfg, opt, mesh, stream, args.steps, run,
               checkpoint_dir=args.ckpt, checkpoint_every=50,
               on_metrics=report)
    print("done; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
