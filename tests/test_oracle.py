"""CostOracle: classification, scoring, amortization gate, split guard.

The oracle is the single cost model behind autotune, the rebalancer's
two tiers and the router's re-plan gate (``core/oracle.py``).  This
suite pins:

* bottleneck classification is a deterministic function of the exact
  structural features (same matrix -> same class, every call) and the
  class JSON-round-trips through ``PlanChoice`` — including legacy JSON
  written before the field existed;
* the delegated cost tables are bit-identical to the plan-layer
  primitives they wrap (routing a consumer through the oracle never
  changes a selection);
* the Asudeh amortization gate (``replan_pays``): volume-blind with no
  horizon, break-even accounting with one;
* the ``SPLIT_MIN_SPAN`` structural guard: a traffic-thinned monster
  row that drops below the span floor must not be offered the split
  family by the rebalancer's partial tier;
* ``probe="auto"`` adaptive probing through ``autotune`` and
  ``SpmvPlan.auto``.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.oracle import (BOTTLENECK_CLASSES, DEFAULT_ORACLE,
                               IMBALANCE_HOT_COL, IMBALANCE_ROW_CV,
                               IMBALANCE_TAIL_SHARE, LATENCY_REMOTE_FRAC,
                               REPLAN_SPMV_EQUIV, CostOracle)
from repro.core.partition import make_partition
from repro.core.plan import (AUTO_PROBE_MIN, KERNELS, MatrixFeatures,
                             PlanChoice, ShardFeatures, autotune,
                             exchange_shard_costs, extract_features,
                             kernel_shard_costs)
from repro.core.spmv import SpmvPlan
from repro.data.matrices import powerlaw, powerlaw_tail


def features(**kw) -> MatrixFeatures:
    """A bandwidth-bound baseline; override fields per test."""
    base = dict(nrows=100, ncols=100, nnz=1000, density=0.1,
                row_nnz_mean=10.0, row_nnz_cv=0.2, row_nnz_max=20.0,
                tail_share=0.05, bandwidth_mean=0.1, bandwidth_p95=0.3,
                hot_col_share=0.1, remote_frac=0.2)
    base.update(kw)
    return MatrixFeatures(**base)


# -- classification (Elafrou) ----------------------------------------------

def test_classify_thresholds():
    o = DEFAULT_ORACLE
    assert o.classify(features()) == "bandwidth"
    assert o.classify(features(remote_frac=LATENCY_REMOTE_FRAC + 0.01)) \
        == "latency"
    # any imbalance trigger wins over the latency test
    assert o.classify(features(row_nnz_cv=IMBALANCE_ROW_CV + 0.1,
                               remote_frac=0.9)) == "imbalance"
    assert o.classify(features(tail_share=IMBALANCE_TAIL_SHARE + 0.01)) \
        == "imbalance"
    assert o.classify(features(hot_col_share=IMBALANCE_HOT_COL + 0.01)) \
        == "imbalance"
    # thresholds are strict: at the boundary the lower class holds
    assert o.classify(features(remote_frac=LATENCY_REMOTE_FRAC)) \
        == "bandwidth"
    assert o.classify(features(row_nnz_cv=IMBALANCE_ROW_CV)) == "bandwidth"


def test_classify_shard_uses_matrix_remote_frac():
    o = DEFAULT_ORACLE
    sf = ShardFeatures(shard=0, rows=10, nnz=100, row_nnz_mean=10.0,
                       row_nnz_cv=0.1, row_nnz_max=15.0, tail_share=0.05)
    assert o.classify_shard(sf) == "bandwidth"
    assert o.classify_shard(sf, remote_frac=0.9) == "latency"
    skew = dataclasses.replace(sf, row_nnz_cv=2.0)
    assert o.classify_shard(skew, remote_frac=0.9) == "imbalance"
    assert o.classify_shards((sf, skew), remote_frac=0.9) \
        == ("latency", "imbalance")


def test_classification_is_deterministic_and_serialized():
    """Same matrix -> same class every call, carried in the PlanChoice
    and surviving an exact JSON round-trip (shard classes included)."""
    A = powerlaw(192, 1800, seed=1)
    a = autotune(A, num_shards=4, probe=0)
    b = autotune(A, num_shards=4, probe=0)
    assert a.bottleneck in BOTTLENECK_CLASSES
    assert a.bottleneck == b.bottleneck
    assert a.shard_bottlenecks == b.shard_bottlenecks
    assert len(a.shard_bottlenecks) == 4
    assert a.bottleneck == DEFAULT_ORACLE.classify(a.features)

    rt = PlanChoice.from_json(a.to_json())
    assert rt.bottleneck == a.bottleneck
    assert rt.shard_bottlenecks == a.shard_bottlenecks
    assert rt.plan == a.plan


def test_legacy_choice_json_has_no_bottleneck():
    """PlanChoice JSON written before the oracle loads with class None."""
    A = powerlaw(192, 1800, seed=1)
    d = __import__("json").loads(autotune(A, num_shards=4, probe=0).to_json())
    del d["bottleneck"], d["shard_bottlenecks"]
    legacy = PlanChoice.from_json(__import__("json").dumps(d))
    assert legacy.bottleneck is None
    assert legacy.shard_bottlenecks is None


def test_score_reweights_the_matched_term():
    A = powerlaw(192, 1800, seed=1)
    o = DEFAULT_ORACLE
    cost = o.plan_cost(A, SpmvPlan(num_shards=4))
    scores = {b: o.score(cost, b) for b in BOTTLENECK_CLASSES}
    for b, s in scores.items():
        assert s >= cost.total       # total plus a non-negative term
    assert scores["bandwidth"] == cost.total + cost.issue_cycles
    assert scores["imbalance"] == cost.total + cost.ingress_cycles
    with pytest.raises(ValueError, match="unknown bottleneck"):
        o.score(cost, "thermal")


def test_kernel_affinity_orders_by_bottleneck_class():
    """Bandwidth-bound shards prefer the index-free streaming formats
    (tile, ell), imbalance-bound shards the load-balanced ones; every
    class returns a permutation of the full kernel grid; latency keeps
    the canonical order (pure tie-break, no reweighting)."""
    o = DEFAULT_ORACLE
    for b in BOTTLENECK_CLASSES:
        order = o.kernel_affinity(b)
        assert sorted(order) == sorted(KERNELS)
    assert o.kernel_affinity("bandwidth")[:2] == ("tile", "ell")
    assert o.kernel_affinity("imbalance")[:3] == ("split", "seg", "hyb")
    assert o.kernel_affinity("latency") == tuple(KERNELS)
    with pytest.raises(ValueError, match="unknown bottleneck"):
        o.kernel_affinity("thermal")


# -- delegation ------------------------------------------------------------

def test_oracle_tables_match_plan_primitives():
    """The oracle is a facade: identical numbers to the plan-layer cost
    primitives, so no consumer's selection moved in the refactor."""
    A = powerlaw(192, 1800, seed=1)
    part = make_partition(A, 4, "nonzero")
    o = CostOracle()
    kc, kc_ref = o.kernel_costs(A, part), kernel_shard_costs(A, part)
    assert kc.keys() == kc_ref.keys()
    for k in kc:
        np.testing.assert_array_equal(kc[k], kc_ref[k])
    ec = o.exchange_costs(A, part, layout="cyclic")
    ec_ref = exchange_shard_costs(A, part, "cyclic")
    assert ec.keys() == ec_ref.keys()
    for e in ec:
        np.testing.assert_array_equal(ec[e], ec_ref[e])
    assert o.select_kernels(A, part) == \
        tuple(min(KERNELS, key=lambda k: (kc[k][p], KERNELS.index(k)))
              for p in range(4))


# -- amortization gate (Asudeh) --------------------------------------------

def test_replan_pays_volume_blind_without_horizon():
    o = DEFAULT_ORACLE
    assert o.replan_pays(0.01, None).pays
    assert not o.replan_pays(0.0, None).pays
    assert not o.replan_pays(-0.1, None).pays
    assert o.replan_pays(-0.1, None).break_even_spmvs == float("inf")


def test_replan_pays_break_even_accounting():
    o = DEFAULT_ORACLE
    full = REPLAN_SPMV_EQUIV["full"]
    d = o.replan_pays(0.10, horizon=full / 0.10)       # exactly break-even
    assert d.pays and d.break_even_spmvs == pytest.approx(full / 0.10)
    assert not o.replan_pays(0.10, horizon=full / 0.10 - 1).pays
    # the partial tier's one-time cost is much smaller
    partial = REPLAN_SPMV_EQUIV["partial"]
    assert partial < full
    assert o.replan_pays(0.10, horizon=partial / 0.10, mode="partial").pays
    # a positive-gain swap a volume-blind model takes is refused at low
    # projected volume — the accepted/refused pair the gate exists for
    assert o.replan_pays(0.10, None).pays
    assert not o.replan_pays(0.10, horizon=5.0).pays
    with pytest.raises(ValueError, match="unknown re-plan mode"):
        o.replan_pays(0.1, None, mode="hourly")


# -- SPLIT_MIN_SPAN guard --------------------------------------------------

def monster_matrix():
    # 4 fully dense rows over 2048 columns: exactly SPLIT_MIN_SPAN seg
    # chunks of span, so any thinning at all drops below the floor.
    return powerlaw_tail(2048, 2 * 4 * 2048, n_monster=4, seed=0)


def test_split_span_ok_thresholds():
    from repro.core.plan import _active_submatrix
    o = DEFAULT_ORACLE
    A = monster_matrix()
    part = make_partition(A, 4, "row")
    assert o.split_span_ok(A, part, 0)            # monster rows: span 4
    assert not o.split_span_ok(A, part, 1)        # short-row background
    # heavy thinning shortens the monster rows below the span floor
    w = np.ones(A.ncols)
    w[:128] = 64.0
    sub = _active_submatrix(A, w)
    assert sub is not A
    assert not o.split_span_ok(sub, part, 0)


def test_split_span_ok_false_on_empty_shard():
    from repro.core.sparse_matrix import csr_from_coo
    A = csr_from_coo(np.arange(2), np.arange(2), np.ones(2), (2, 8))
    part = make_partition(A, 4, "row")
    assert any(part.starts[p] == part.starts[p + 1] for p in range(4))
    for p in range(4):
        if part.starts[p] == part.starts[p + 1]:
            assert not DEFAULT_ORACLE.split_span_ok(A, part, p)


def test_partial_replan_split_guard_under_heavy_thinning():
    """Regression for the split-swap span guard: traffic so concentrated
    that thinning shortens the monster rows below ``SPLIT_MIN_SPAN``
    chunks must not let the partial tier deploy split against the real
    matrix (the companion to ``test_partial_replan_reaches_split_on_
    monster_row_shard``, whose *mild* skew keeps the span and does
    reach split)."""
    from repro.core.plan import RankedPlan, estimate_cost
    from repro.core.program import lower
    from repro.serve.rebalance import (LoadMonitor, RebalanceConfig,
                                       _try_partial_replan, hot_shards)

    A = monster_matrix()
    plan = SpmvPlan(layout="block", distribution="row", reordering="none",
                    exchange="halo", kernel="seg", num_shards=4)
    prog = lower(A, plan)
    cfg = RebalanceConfig(window=16, probe=0)
    mon = LoadMonitor(prog, cfg)
    w = np.ones(A.ncols)
    w[:128] = 64.0                    # heavy skew: thinned span < floor
    mon._act_ema = w / w.mean()
    assert list(hot_shards(mon.shard_load(), cfg.hot_factor)) == [0]

    choice = PlanChoice(
        features=extract_features(A, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(A, plan)),),
        probed=0)
    out = _try_partial_replan(A, mon, choice, prog, mon.activity(), cfg,
                              request_index=0)
    if out is not None:               # any surviving swap must avoid split
        dist, _, ev = out
        assert "split" not in dist.shard_kernels()
        assert ev.mode == "partial"


# -- adaptive probing ------------------------------------------------------

def test_autotune_probe_auto_stabilizes():
    A = powerlaw(192, 1800, seed=1)
    choice = autotune(A, num_shards=4, probe="auto")
    assert choice.probed >= AUTO_PROBE_MIN
    bases = {(r.plan.reordering, r.plan.layout, r.plan.distribution)
             for r in choice.ranking if r.probe_seconds is not None}
    assert len(bases) == choice.probed


def test_autotune_rejects_unknown_probe_string():
    A = powerlaw(192, 1800, seed=1)
    with pytest.raises(ValueError, match="auto"):
        autotune(A, num_shards=4, probe="adaptive")


def test_spmv_plan_auto_accepts_probe_auto():
    A = powerlaw(192, 1800, seed=1)
    plan = SpmvPlan.auto(A, num_shards=4, probe="auto")
    assert plan.num_shards == 4
