"""Figs. 8 & 11 — per-nodelet thread residency over time on cop20k_A,
original vs random reordering (the hot-spot collapse and its mitigation)."""
import numpy as np
from .common import emit, sim_bandwidth


def run():
    rows = []
    for reord in ("none", "random"):
        _, res = sim_bandwidth("cop20k_A", reordering=reord)
        r = res.residency
        # sample 8 time points across the run
        idx = np.linspace(0, len(r) - 1, 8).astype(int)
        for i in idx:
            rows.append((f"fig8/cop20k_A/{reord}", i,
                         *[int(v) for v in r[i]]))
        # summary: mean residency of nodelet 0 vs others mid-run
        mid = r[len(r) // 4: max(len(r) // 2, len(r) // 4 + 1)]
        rows.append((f"fig8/cop20k_A/{reord}/summary", -1,
                     round(float(mid.mean(axis=0)[0]), 1),
                     round(float(np.delete(mid.mean(axis=0), 0).mean()), 1),
                     res.ticks, round(res.bandwidth_mbs, 1), 0, 0, 0))
    emit(rows, ("name", "tick", "n0", "n1", "n2", "n3", "n4", "n5", "n6/x", "n7/x"))


if __name__ == "__main__":
    run()
