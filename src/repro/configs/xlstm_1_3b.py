"""xlstm-1.3b [ssm] — arXiv:2405.04517 (unverified).  sLSTM + mLSTM blocks,
xLSTM[7:1] ratio, d_ff=0 (blocks carry their own projections)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=4, num_kv_heads=4, head_dim=512, d_ff=0,
    vocab_size=50_304, lstm_proj_factor=1.0, tie_embeddings=True,
    block_pattern=("mlstm",) * 7 + ("slstm",))

def smoke_config():
    return ModelConfig(
        name="xlstm-smoke", family="ssm", num_layers=4, d_model=64,
        num_heads=2, num_kv_heads=2, head_dim=32, d_ff=0, vocab_size=512,
        lstm_proj_factor=2.0, block_pattern=("mlstm", "slstm"))
