"""Fig. 10 — SpMV bandwidth under NONE / RANDOM / BFS / METIS reordering
(Emu model).  Paper: BFS/METIS up to +70%, RANDOM up to +50% on hot-spot
matrices; random hurts banded matrices."""
from .common import SIM_SCALES, emit, sim_bandwidth


def run():
    rows = []
    for name in SIM_SCALES:
        bws = {}
        for reord in ("none", "random", "bfs", "metis"):
            _, res = sim_bandwidth(name, reordering=reord)
            bws[reord] = res.bandwidth_mbs
        base = max(bws["none"], 1e-9)
        rows.append((f"fig10/{name}",
                     *[round(bws[r], 1) for r in
                       ("none", "random", "bfs", "metis")],
                     *[round(bws[r] / base, 2) for r in
                       ("random", "bfs", "metis")]))
    emit(rows, ("name", "none_mbs", "random_mbs", "bfs_mbs", "metis_mbs",
                "random_x", "bfs_x", "metis_x"))


if __name__ == "__main__":
    run()
