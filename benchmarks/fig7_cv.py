"""Fig. 7 — coefficient of variation of per-nodelet memory instructions,
row vs non-zero distribution (exact counting, larger scales)."""
from repro.core.layout import make_layout
from repro.core.migration import count_migrations
from repro.core.partition import make_partition
from repro.data.matrices import make_matrix
from .common import COUNT_SCALES, emit


def run():
    rows = []
    for name, scale in COUNT_SCALES.items():
        A = make_matrix(name, scale=scale)
        cvs = {}
        for strat in ("row", "nonzero"):
            p = make_partition(A, 8, strat)
            cvs[strat] = count_migrations(
                A, p, make_layout("block", A.ncols, 8),
                make_layout("block", A.nrows, 8)).mem_instr_cv
        rows.append((f"fig7/{name}", round(cvs["row"], 4),
                     round(cvs["nonzero"], 4)))
    emit(rows, ("name", "cv_row", "cv_nonzero"))


if __name__ == "__main__":
    run()
