"""Pallas SpMV kernels + pure-jnp oracles (``ref.py``) + jit'd wrappers
(``ops.py``).

Five kernel families, one per sparse format/work-distribution choice:

* **ELL** (``spmv_ell.py``) — row-tiled padded-ELL SpMV (+ COO overflow
  tail = HYB via :func:`hyb_spmv`).  Grid is shape-aware: (rows, width)
  tiles, so one power-law row widens every tile's reduction.
* **Segmented** (``spmv_seg.py``) — nonzero-balanced merge-path-style
  SpMV: the nnz stream is cut into equal-size chunks, the kernel emits
  within-chunk prefix sums, and a jit'd cross-chunk carry fix-up
  assembles rows.  Grid is load-balance-aware: every step owns the same
  number of non-zeros regardless of row skew (the TPU analogue of the
  paper's nonzero work distribution, §III-C).
* **Split** (``spmv_split.py``) — split-nnz *two-stage* SpMV (split-K):
  the seg chunk grid is further cut into NS splits, stage 1 fills a 2-D
  (split, chunk) grid of partial accumulators, stage 2 is a tiny
  split-axis combine.  Cures the paper's §IV-D monster-row hot-spot at
  *shard* granularity — a one-row shard still fills the whole grid.
* **Tile** (``spmv_tile.py``) — bitmask-tiled SpMV: a coarse pointer
  grid over dense (8, 128) tiles plus per-tile occupancy bitmasks.  The
  scalar-prefetch walk streams whole tiles with dense FMAs and **no
  per-element column indices**, skipping empty tiles via the pointer
  level — the blocked format for banded / block-structured matrices,
  where ELL pads and seg wastes scan work.  The old MXU Block-ELL
  (``bell_*``) is absorbed as a special case of this walk; its ops
  survive as warn-once deprecated shims.

Every kernel has the same contract: pure-jnp oracle as the default
execution path, ``use_kernel=True`` for the Pallas path (TPU), and
``interpret=True`` to run the Pallas path on CPU.  The public API is
re-exported here (from ``ops.py``), so callers write
``from repro.kernels import ell_spmv`` without caring which file owns the
kernel.

Examples
--------
The ELL oracle against a dense product:

>>> import numpy as np
>>> from repro.kernels import ell_spmv_ref
>>> data = np.array([[2.0, 0.0], [1.0, 3.0]], np.float32)
>>> cols = np.array([[1, 0], [0, 1]], np.int32)
>>> x = np.array([1.0, 10.0], np.float32)
>>> np.asarray(ell_spmv_ref(data, cols, x)).tolist()   # [2*10, 1*1+3*10]
[20.0, 31.0]

The segmented path built straight from a CSR matrix:

>>> from repro.core.sparse_matrix import csr_from_coo, csr_to_dense
>>> from repro.kernels import seg_from_csr, seg_spmv
>>> A = csr_from_coo(np.array([0, 1, 1]), np.array([1, 0, 1]),
...                  np.array([5.0, 2.0, 4.0]), (2, 2))
>>> seg = seg_from_csr(A, chunk=128)
>>> y = np.asarray(seg_spmv(seg, np.array([1.0, 2.0], np.float32)))
>>> np.allclose(y, csr_to_dense(A) @ np.array([1.0, 2.0]))
True

The split-K path from the same matrix (two splits over the chunk grid):

>>> from repro.kernels import split_from_csr, split_spmv
>>> spl = split_from_csr(A, 2, chunk=128)
>>> y2 = np.asarray(split_spmv(spl, np.array([1.0, 2.0], np.float32)))
>>> np.allclose(y2, y)
True

The bitmask-tiled path from the same matrix (one occupied (8, 128) tile):

>>> from repro.kernels import tile_from_csr, tile_spmv
>>> tl = tile_from_csr(A)
>>> tl.num_tiles
1
>>> y3 = np.asarray(tile_spmv(tl, np.array([1.0, 2.0], np.float32)))
>>> np.allclose(y3, y)
True
"""
from .ops import (bell_from_bcsr, bell_spmm, bell_spmv, ell_spmv,
                  ell_spmv_ref, hyb_spmv, seg_from_csr, seg_spmv,
                  seg_spmv_ref, split_flat_spmv, split_from_csr, split_spmv,
                  split_spmv_ref, tile_flat_spmv, tile_from_csr, tile_spmv,
                  tile_spmv_ref)

__all__ = ["ell_spmv", "ell_spmv_ref", "hyb_spmv", "bell_spmv", "bell_spmm",
           "bell_from_bcsr", "seg_spmv", "seg_spmv_ref", "seg_from_csr",
           "split_spmv", "split_spmv_ref", "split_from_csr",
           "split_flat_spmv", "tile_spmv", "tile_spmv_ref", "tile_from_csr",
           "tile_flat_spmv"]
