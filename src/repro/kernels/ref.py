"""Pure-jnp oracles for every kernel in this package.

These are the correctness references the per-kernel tests sweep against
(shapes x dtypes, assert_allclose).  They are also the fallback execution
path on backends without Pallas support.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ell_spmv_ref", "bell_spmv_ref", "coo_spmv_ref", "bell_spmm_ref",
           "seg_spmv_ref", "seg_psum_ref", "split_psum_ref",
           "split_partial_ref", "split_combine_ref", "split_spmv_ref",
           "tile_spmv_ref", "tile_flat_spmv_ref"]


def ell_spmv_ref(data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_w data[i, w] * x[cols[i, w]]  — padded slots hold 0.

    ``x`` may be (N,) or a multi-RHS block (N, B); the result matches
    ((M,) or (M, B)).  The batched path reuses the same gather and the
    same axis-1 reduction, so per-column results equal the per-vector
    ones exactly.
    """
    gathered = jnp.take(x, cols, axis=0)     # (M, W) or (M, W, B)
    if x.ndim == 2:
        return jnp.sum(data[..., None] * gathered, axis=1)
    return jnp.sum(data * gathered, axis=1)


def coo_spmv_ref(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                 x: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """Scatter-add oracle for the HYB overflow tail."""
    contrib = vals * jnp.take(x, cols, axis=0)
    return jnp.zeros((num_rows,), dtype=contrib.dtype).at[rows].add(contrib)


def seg_spmv_ref(vals: jnp.ndarray, cols: jnp.ndarray, rows: jnp.ndarray,
                 x: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """Segmented SpMV oracle over the chunked nnz stream.

    vals/cols/rows: (C, L) slab (padded slots: val 0 / col 0 / row 0).
    Scatter-adds every product into its destination row — the order-free
    definition the chunked prefix-sum kernel must reproduce.  ``x`` may be
    (N,) or a multi-RHS block (N, B); the (C, L) row ids then scatter
    whole (B,) slices, so batched columns match per-vector runs exactly.
    """
    gathered = jnp.take(x, cols, axis=0)     # (C, L) or (C, L, B)
    if x.ndim == 2:
        contrib = vals[..., None] * gathered
        out = jnp.zeros((num_rows, x.shape[1]), dtype=contrib.dtype)
    else:
        contrib = vals * gathered
        out = jnp.zeros((num_rows,), dtype=contrib.dtype)
    return out.at[rows].add(contrib)


def seg_psum_ref(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Within-chunk inclusive prefix sums — oracle for kernels.spmv_seg."""
    return jnp.cumsum(vals * jnp.take(x, cols, axis=0), axis=1)


def split_psum_ref(vals: jnp.ndarray, cols: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    """Stage-1 oracle: within-chunk scans over the (NS, Cs, L) slab."""
    return jnp.cumsum(vals * jnp.take(x, cols, axis=0), axis=-1)


def split_partial_ref(psum: jnp.ndarray, piece_split: jnp.ndarray,
                      piece_chunk: jnp.ndarray, piece_lo: jnp.ndarray,
                      piece_hi: jnp.ndarray, piece_row: jnp.ndarray,
                      num_splits: int, num_rows: int) -> jnp.ndarray:
    """Carry fix-up into per-split partial row sums.

    psum: (NS, Cs, L) stage-1 scans (trailing batch dims allowed).  Each
    piece contributes ``psum[s, c, hi] - psum[s, c, lo-1]`` to partial
    row ``(s, row)``; ``lo == 0`` contributes the plain prefix.  Returns
    (NS, num_rows) partials (plus any batch dims).
    """
    hi = psum[piece_split, piece_chunk, piece_hi]
    lo = jnp.where((piece_lo > 0)[(...,) + (None,) * (hi.ndim - 1)],
                   psum[piece_split, piece_chunk,
                        jnp.maximum(piece_lo - 1, 0)], 0)
    contrib = hi - lo
    out = jnp.zeros((num_splits, num_rows) + psum.shape[3:],
                    dtype=psum.dtype)
    return out.at[piece_split, piece_row].add(contrib)


def split_combine_ref(partial: jnp.ndarray) -> jnp.ndarray:
    """Stage-2 oracle: reduce the split axis, (NS, R, ...) -> (R, ...)."""
    return jnp.sum(partial, axis=0)


def split_spmv_ref(vals: jnp.ndarray, cols: jnp.ndarray, rows: jnp.ndarray,
                   x: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """End-to-end split oracle — identical contract to seg_spmv_ref.

    The split axis only partitions the nnz stream; flattening it back to
    a (NS*Cs, L) slab and scatter-adding gives the order-free answer.
    """
    NS, Cs, L = vals.shape
    return seg_spmv_ref(vals.reshape(NS * Cs, L), cols.reshape(NS * Cs, L),
                        rows.reshape(NS * Cs, L), x, num_rows)


def tile_spmv_ref(data: jnp.ndarray, tile_rows: jnp.ndarray,
                  tile_cols: jnp.ndarray, x: jnp.ndarray,
                  num_rows: int) -> jnp.ndarray:
    """Bitmask-tiled SpMV oracle over the occupied-tile list.

    data:      (T, bm, bn) dense zero-filled tiles
    tile_rows: (T,) int32 block-row id per tile
    tile_cols: (T,) int32 block-col id per tile
    x:         (N,) or (N, B) — padded internally to a ``bn`` multiple

    Each tile gathers its lane-aligned x slice whole, does one dense
    (bm, bn) @ (bn,) product, and scatter-adds into its block row — the
    order-free definition the scalar-prefetch walk kernel reproduces.
    """
    T, bm, bn = data.shape
    n = x.shape[0]
    Nb = max(-(-n // bn), 1)
    pad = [(0, Nb * bn - n)] + [(0, 0)] * (x.ndim - 1)
    xb = jnp.pad(x, pad).reshape((Nb, bn) + x.shape[1:])
    gathered = jnp.take(xb, tile_cols, axis=0)          # (T, bn[, B])
    contrib = jnp.einsum("tij,tj...->ti...", data, gathered)
    Mb = max(-(-num_rows // bm), 1)
    out = jnp.zeros((Mb, bm) + x.shape[1:], dtype=contrib.dtype)
    out = out.at[tile_rows].add(contrib)
    return out.reshape((Mb * bm,) + x.shape[1:])[:num_rows]


def tile_flat_spmv_ref(data: jnp.ndarray, xcols: jnp.ndarray,
                       trows: jnp.ndarray, x: jnp.ndarray,
                       num_rows: int) -> jnp.ndarray:
    """Flat-gather variant for the device path.

    ``xcols`` (T, bn) carries each tile's *remapped* per-lane x positions
    (the executor's augmented local+halo buffer has no block structure to
    index by block column), and padding tiles carry ``trows >= Mb`` so
    their scatter drops.  Unoccupied lanes point at position 0 and hold
    zero data, contributing exact zeros.
    """
    T, bm, bn = data.shape
    gathered = jnp.take(x, xcols, axis=0)               # (T, bn[, B])
    contrib = jnp.einsum("tij,tj...->ti...", data, gathered)
    Mb = max(-(-num_rows // bm), 1)
    out = jnp.zeros((Mb, bm) + x.shape[1:], dtype=contrib.dtype)
    out = out.at[trows].add(contrib, mode="drop")
    return out.reshape((Mb * bm,) + x.shape[1:])[:num_rows]


def bell_spmv_ref(blocks: jnp.ndarray, bcols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Block-ELL SpMV oracle.

    blocks: (Mb, K, bm, bn) dense blocks, zero-padded where inactive
    bcols:  (Mb, K) block-column index per slot (0 for padded slots)
    x:      (Nb * bn,)
    returns y: (Mb * bm,)
    """
    Mb, K, bm, bn = blocks.shape
    xb = x.reshape(-1, bn)                       # (Nb, bn)
    gathered = jnp.take(xb, bcols, axis=0)       # (Mb, K, bn)
    y = jnp.einsum("mkij,mkj->mi", blocks, gathered)
    return y.reshape(Mb * bm)


def bell_spmm_ref(blocks: jnp.ndarray, bcols: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Block-ELL SpMM oracle (sparse A @ dense X).

    X: (Nb * bn, B) -> returns (Mb * bm, B).
    """
    Mb, K, bm, bn = blocks.shape
    B = X.shape[1]
    Xb = X.reshape(-1, bn, B)                    # (Nb, bn, B)
    gathered = jnp.take(Xb, bcols, axis=0)       # (Mb, K, bn, B)
    Y = jnp.einsum("mkij,mkjb->mib", blocks, gathered)
    return Y.reshape(Mb * bm, B)
