"""Pallas TPU kernels: split-nnz two-stage SpMV (split-K).

``spmv_seg`` cures row skew at chunk granularity, but its grid is the
chunk count: a shard that is one monster row lowers to a handful of
chunks and leaves the machine idle — the paper's §IV-D hot-spot
reappears one level up.  This is the split-K decode idiom (aiter MLA,
SNIPPETS.md §2) ported to SpMV:

* stage 1 (``split_psum``): the (C, L) nnz slab is reshaped to
  (NS, Cs, L) and a 2-D grid ``(NS, Cs // tc)`` computes within-chunk
  inclusive prefix sums per split — NS independent partial accumulators,
  so even a one-row shard fills ``NS * Cs/tc`` grid steps;
* the carry fix-up scatters each split's pieces into a *partial* row-sum
  buffer (NS, R) (cheap jit'd gather/scatter in ops, same shape as the
  seg fix-up but indexed by split);
* stage 2 (``split_combine``): a tiny reduction over the split axis,
  (NS, R) -> (R,) — the aiter ``_fwd_kernel_stage2`` analogue.

The split count NS is a planning decision (``plan.split_meta``), driven
by the row span (chunks of the longest row) and the device core count —
the ``get_meta_param`` analogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["split_psum", "split_combine"]


def _split_psum_kernel(vals_ref, cols_ref, x_ref, psum_ref):
    vals = vals_ref[0]                         # (TC, L) tile of one split
    cols = cols_ref[0]                         # (TC, L)
    x = x_ref[...]                             # (N,) resident in VMEM
    prod = vals * jnp.take(x, cols, axis=0)    # VMEM dynamic gather
    psum_ref[0] = jnp.cumsum(prod, axis=1)     # within-chunk inclusive scan


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def split_psum(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray,
               *, tile_c: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Stage 1: per-chunk inclusive prefix sums over a split slab.

    vals/cols: (NS, Cs, L) nnz-stream slab with L % 128 == 0.  The grid
    is 2-D, (NS, Cs // tc): the split axis keeps every core busy even
    when Cs is tiny (one monster row => C chunks cut into NS splits).
    x: (N,) gathered vector, fits VMEM alongside the tiles.
    Returns psum: (NS, Cs, L) in x.dtype.
    """
    NS, Cs, L = vals.shape
    tc = min(tile_c, Cs)
    while Cs % tc:                 # largest divisor of Cs not above tile_c
        tc -= 1
    grid = (NS, Cs // tc)
    return pl.pallas_call(
        _split_psum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, L), lambda s, c: (s, c, 0)),   # vals tile
            pl.BlockSpec((1, tc, L), lambda s, c: (s, c, 0)),   # cols tile
            pl.BlockSpec((x.shape[0],), lambda s, c: (0,)),     # full x
        ],
        out_specs=pl.BlockSpec((1, tc, L), lambda s, c: (s, c, 0)),
        out_shape=jax.ShapeDtypeStruct((NS, Cs, L), x.dtype),
        interpret=interpret,
    )(vals, cols, x)


def _split_combine_kernel(part_ref, y_ref):
    y_ref[...] = jnp.sum(part_ref[...], axis=0)   # (NS, TR) -> (TR,)


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def split_combine(partial: jnp.ndarray, *, tile_r: int = 128,
                  interpret: bool = False) -> jnp.ndarray:
    """Stage 2: reduce the per-split partial row sums, (NS, R) -> (R,)."""
    NS, R = partial.shape
    tr = min(tile_r, R)
    while R % tr:                  # largest divisor of R not above tile_r
        tr -= 1
    return pl.pallas_call(
        _split_combine_kernel,
        grid=(R // tr,),
        in_specs=[pl.BlockSpec((NS, tr), lambda r: (0, r))],
        out_specs=pl.BlockSpec((tr,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((R,), partial.dtype),
        interpret=interpret,
    )(partial)
