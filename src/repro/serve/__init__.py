"""Serving layer: the batched LM engine and the multi-tenant sparse-matrix
serving router (autotuned ingest, warm-start program artifacts, batched
multi-RHS SpMV, feature-keyed plan cache, cross-request micro-batching)
plus the online rebalancing subsystem that keeps serving plans matched to
the live request mix (``rebalance.py``)."""
from .engine import Engine, ServeConfig
from .router import IngestedMatrix, MicroBatchConfig, SparseMatrixEngine
from .rebalance import LoadMonitor, RebalanceConfig, RebalanceEvent

__all__ = ["Engine", "ServeConfig", "SparseMatrixEngine", "IngestedMatrix",
           "MicroBatchConfig", "LoadMonitor", "RebalanceConfig",
           "RebalanceEvent"]
