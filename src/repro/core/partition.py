"""Work-distribution strategies (paper §III-C).

Two strategies, exactly as studied:

* ``row``      — each shard ("nodelet") gets an equal count of contiguous
                 rows; a block-layout ``b`` then lines up with the shard.
* ``nonzero``  — contiguous rows are packed until ~NNZ/shards non-zeros per
                 shard, so every shard does the same amount of *work* even
                 when row lengths are wildly skewed (cop20k_A, webbase).
                 ``nnz`` is an accepted alias so :class:`SpmvPlan` and the
                 segmented kernel share one spelling.

Both return a :class:`Partition` describing row ranges per shard plus the
per-thread sub-split used by the Emu machine model.

:func:`nnz_chunk_starts` is the *element-level* analogue used by the
segmented SpMV kernel (``kernels/spmv_seg.py``): the nnz stream is cut into
equal-size chunks regardless of row boundaries, which is the merge-path /
nonzero-split work distribution at grid-step granularity.  Keeping both
definitions in this module means the Emu simulator traces and the TPU
kernel path agree on what "nonzero-balanced" means.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .sparse_matrix import CSRMatrix, csr_row_nnz

__all__ = ["Partition", "partition_rows", "partition_nonzeros",
           "make_partition", "nnz_chunk_starts", "DISTRIBUTIONS"]

#: Accepted ``make_partition`` / ``SpmvPlan.distribution`` spellings.
DISTRIBUTIONS = ("row", "nonzero", "nnz")


@dataclasses.dataclass(frozen=True)
class Partition:
    """Row ranges per shard: shard p owns rows [starts[p], starts[p+1])."""

    strategy: str
    num_shards: int
    starts: np.ndarray  # (P+1,) int64, starts[0] == 0, starts[-1] == M

    def rows_of(self, p: int) -> range:
        return range(int(self.starts[p]), int(self.starts[p + 1]))

    def shard_csr(self, csr: CSRMatrix, p: int) -> CSRMatrix:
        """Shard p's mini-CSR (relative row offsets, Fig. 2).

        The single definition of "shard p's slice of A" shared by the
        program lowering (``core/program.py``), the per-shard kernel cost
        table and shard features (``core/plan.py``) — so every per-shard
        consumer reads exactly the same row range.
        """
        return csr.row_slice(int(self.starts[p]), int(self.starts[p + 1]))

    def rows_per_shard(self) -> np.ndarray:
        return np.diff(self.starts)

    def nnz_per_shard(self, csr: CSRMatrix) -> np.ndarray:
        return self.starts_nnz(csr)

    def starts_nnz(self, csr: CSRMatrix) -> np.ndarray:
        rp = csr.row_ptr
        return np.diff(rp[self.starts])

    def owner_of_rows(self, M: int) -> np.ndarray:
        """(M,) shard id owning each row."""
        return np.searchsorted(self.starts, np.arange(M), side="right") - 1

    def thread_splits(self, csr: CSRMatrix, threads_per_shard: int) -> list[np.ndarray]:
        """Sub-split each shard's rows among worker threads.

        Row strategy: equal rows per thread.  Non-zero strategy: rows packed
        to ~NNZ/threads non-zeros per thread across *all* threads (the paper
        accumulates until the global NNZ/threads threshold is met).
        """
        out = []
        for p in range(self.num_shards):
            r0, r1 = int(self.starts[p]), int(self.starts[p + 1])
            sub = csr.row_slice(r0, r1)
            if self.strategy == "row":
                t_starts = _even_row_starts(r1 - r0, threads_per_shard) + r0
            else:
                t = partition_nonzeros(sub, threads_per_shard)
                t_starts = t.starts + r0
            out.append(t_starts.astype(np.int64))
        return out


def _even_row_starts(M: int, P: int) -> np.ndarray:
    base, rem = divmod(M, P)
    sizes = np.full(P, base, dtype=np.int64)
    sizes[:rem] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


def partition_rows(csr: CSRMatrix, num_shards: int) -> Partition:
    """Equal-row contiguous blocks (paper's *row* distribution)."""
    return Partition("row", num_shards, _even_row_starts(csr.nrows, num_shards))


def partition_nonzeros(csr: CSRMatrix, num_shards: int,
                       nnz_weight: np.ndarray | None = None) -> Partition:
    """Contiguous row blocks with ~equal non-zeros (paper's *non-zero*).

    Walk ``row_ptr`` accumulating rows until the NNZ/shards threshold is
    met — vectorized as a searchsorted over the cumulative nnz curve.

    ``nnz_weight`` (optional, (nnz,) float, aligned with the stored-entry
    order) switches the split from equal stored non-zeros to equal
    *expected work*: the cumulative curve is the weighted one, so under a
    skewed serving workload (each entry's weight = its column's observed
    activity) every shard gets the same share of traffic-visible work —
    the paper's nonzero split re-derived against what the request stream
    actually touches.  Note the serving re-plan path does **not** pass
    weights here: :class:`~repro.core.spmv.SpmvPlan` stays a weight-free,
    JSON-round-trippable config (so ``build_distributed`` can always
    rebuild the exact program from the persisted plan), and the
    rebalancer instead re-ranks weight-free plans under traffic-weighted
    *costs*.  The weighted split is the primitive for callers that manage
    their own partitions (pinned by ``tests/test_rebalance.py``).
    """
    M = csr.nrows
    if nnz_weight is None:
        curve = csr.row_ptr[1:].astype(np.float64)
        total = float(csr.nnz)
    else:
        w = np.asarray(nnz_weight, dtype=np.float64)
        if w.shape[0] != csr.nnz:
            raise ValueError(f"nnz_weight has {w.shape[0]} entries, "
                             f"matrix stores {csr.nnz}")
        per_row = np.zeros(M, dtype=np.float64)
        np.add.at(per_row, np.repeat(np.arange(M), csr_row_nnz(csr)), w)
        curve = np.cumsum(per_row)
        total = float(curve[-1]) if M else 0.0
    targets = (np.arange(1, num_shards, dtype=np.float64) * total / num_shards)
    cut = np.searchsorted(curve, targets, side="left") + 1
    starts = np.concatenate([[0], cut, [M]]).astype(np.int64)
    # Monotonicity guard for degenerate matrices (empty rows at the ends).
    np.maximum.accumulate(starts, out=starts)
    starts = np.minimum(starts, M)
    return Partition("nonzero", num_shards, starts)


def nnz_chunk_starts(nnz: int, chunk: int) -> np.ndarray:
    """Element-space chunk boundaries for the segmented SpMV kernel.

    The nnz stream [0, nnz) is cut into ceil(nnz/chunk) chunks of exactly
    ``chunk`` elements (the last one short).  Every kernel grid step then
    owns the same number of non-zeros — the nonzero-split distribution at
    chunk granularity, independent of how skewed the row lengths are.
    """
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    n_chunks = max((nnz + chunk - 1) // chunk, 1)
    starts = np.minimum(np.arange(n_chunks + 1, dtype=np.int64) * chunk, nnz)
    return starts


def make_partition(csr: CSRMatrix, num_shards: int, strategy: str,
                   nnz_weight: np.ndarray | None = None) -> Partition:
    if strategy == "row":
        return partition_rows(csr, num_shards)
    if strategy in ("nonzero", "nnz"):
        return partition_nonzeros(csr, num_shards, nnz_weight=nnz_weight)
    raise ValueError(f"unknown work-distribution strategy: {strategy!r}; "
                     f"expected one of {DISTRIBUTIONS}")
