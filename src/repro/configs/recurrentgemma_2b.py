"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (hf).  RG-LRU + local
attention, pattern (rec, rec, attn); MQA kv=1, window 2048, GeGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
    num_heads=10, num_kv_heads=1, head_dim=256, d_ff=7680,
    vocab_size=256_000, activation="geglu", attn_window=2048,
    lru_width=2560, block_pattern=("rglru", "rglru", "local_attn"),
    tie_embeddings=True)

def smoke_config():
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", num_layers=3,
        d_model=64, num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
        vocab_size=512, activation="geglu", attn_window=16, lru_width=64,
        block_pattern=("rglru", "rglru", "local_attn"))
