"""Jit'd public wrappers around the Pallas kernels (+ oracle fallbacks).

On TPU the Pallas path is used; on CPU (this container) the kernels run
under ``interpret=True`` in tests and the pure-jnp oracle is the default
execution path, so every higher layer works identically on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .spmv_ell import ell_spmv as _ell_spmv_pallas
from .spmv_bell import bell_spmv as _bell_spmv_pallas, bell_spmm as _bell_spmm_pallas

__all__ = ["ell_spmv_ref", "ell_spmv", "hyb_spmv", "bell_spmv", "bell_spmm",
           "bell_from_bcsr"]

ell_spmv_ref = jax.jit(ref.ell_spmv_ref)
bell_spmv_ref = jax.jit(ref.bell_spmv_ref)
bell_spmm_ref = jax.jit(ref.bell_spmm_ref)


def ell_spmv(data, cols, x, *, interpret: bool = False, **tiles):
    """Pallas ELL SpMV (TPU); set interpret=True on CPU."""
    return _ell_spmv_pallas(data, cols, x, interpret=interpret, **tiles)


@functools.partial(jax.jit, static_argnames=("num_rows",))
def _overflow_add(y, rows, cols, vals, x, num_rows: int):
    return y.at[rows].add(vals * jnp.take(x, cols, axis=0))


def hyb_spmv(ell_data, ell_cols, ovf_rows, ovf_cols, ovf_vals, x,
             *, use_kernel: bool = False, interpret: bool = False):
    """HYB = padded-ELL kernel + COO overflow scatter-add tail."""
    if use_kernel:
        y = ell_spmv(ell_data, ell_cols, x, interpret=interpret)
    else:
        y = ell_spmv_ref(ell_data, ell_cols, x)
    if ovf_vals.shape[0]:
        y = _overflow_add(y, ovf_rows, ovf_cols, ovf_vals, x, num_rows=y.shape[0])
    return y


def bell_spmv(blocks, bcols, x, *, use_kernel: bool = False,
              interpret: bool = False):
    if use_kernel:
        return _bell_spmv_pallas(blocks, bcols, x, interpret=interpret)
    return bell_spmv_ref(blocks, bcols, x)


def bell_spmm(blocks, bcols, X, *, use_kernel: bool = False,
              interpret: bool = False, tile_b: int = 128):
    if use_kernel:
        return _bell_spmm_pallas(blocks, bcols, X, tile_b=tile_b,
                                 interpret=interpret)
    return bell_spmm_ref(blocks, bcols, X)


def bell_from_bcsr(bcsr) -> tuple[np.ndarray, np.ndarray]:
    """Convert host BcsrMatrix -> padded Block-ELL arrays (blocks, bcols).

    K = max blocks per block-row; padded slots hold zero blocks and bcol 0,
    which the kernels treat as a no-op contribution.
    """
    Mb = bcsr.block_row_ptr.shape[0] - 1
    bm, bn = bcsr.block_shape
    per_row = np.diff(bcsr.block_row_ptr)
    K = max(int(per_row.max()) if Mb else 1, 1)
    blocks = np.zeros((Mb, K, bm, bn), dtype=bcsr.blocks.dtype)
    bcols = np.zeros((Mb, K), dtype=np.int32)
    for r in range(Mb):
        lo, hi = int(bcsr.block_row_ptr[r]), int(bcsr.block_row_ptr[r + 1])
        blocks[r, : hi - lo] = bcsr.blocks[lo:hi]
        bcols[r, : hi - lo] = bcsr.block_cols[lo:hi]
    return blocks, bcols
