"""Serving launcher: build an engine for an arch and run batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs.registry import get_config, get_smoke_config
    from repro.models import params as pp
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params,
                    ServeConfig(max_len=args.prompt_len + args.gen + 8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, steps=args.gen)
    for i, row in enumerate(out):
        print(f"req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
