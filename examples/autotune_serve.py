"""Autotuned SpMV serving in ~60 lines.

Ingest structurally different matrices (including a mixed-structure one)
into the sparse serving engine; each gets its own cost-model-tuned plan at
load time (no hand-picked layouts/kernels — and since the SpmvProgram
refactor, a kernel *per shard*), then serve y = A @ x requests and print
which plan each matrix ended up with, shard by shard (plus the cost
oracle's bottleneck class), and why it differs.  Ends with the oracle's
amortization gate deciding the *same* drift re-plan both ways: the busy
tenant's projected volume pays it back, the idle tenant's never does.

    PYTHONPATH=src python examples/autotune_serve.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.oracle import DEFAULT_ORACLE
from repro.core.sparse_matrix import csr_to_dense
from repro.data.matrices import make_matrix, mixed_structure
from repro.serve.engine import SparseMatrixEngine


def _shards_str(kernels) -> str:
    """Compress ('ell','ell','seg',...) to 'ell x2 + seg x6' style."""
    runs = []
    for k in kernels:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    return " + ".join(f"{k}x{n}" if n > 1 else k for k, n in runs)


def main():
    # probe="auto" spends Emu probes adaptively at ingest: bases are
    # measured in analytic-rank order until the measured-vs-analytic
    # inversion rate stabilizes, so locality-rich bases the analytic
    # issue term under-ranks still get simulated — without hard-coding a
    # full-sweep budget (the vectorized Emu engine keeps this
    # milliseconds either way).
    eng = SparseMatrixEngine(num_shards=8, probe="auto")
    rng = np.random.default_rng(0)
    suite = {name: make_matrix(name, scale=scale)
             for name, scale in (("cop20k_A", 0.02), ("webbase-1M", 0.002),
                                 ("audikw_1", 0.001))}
    # Same mixed-structure workload as benchmarks/hetero_bench.py: at this
    # size the locality-rich bases keep the analytic-vs-measured inversion
    # rate unstable, so probe="auto" keeps spending until it measures
    # them — and lands on a per-shard heterogeneous program.
    suite["mixed"] = mixed_structure(4096, 33 * 4096)

    print(f"{'matrix':12s} {'chosen plan':26s} {'per-shard kernels':24s} "
          f"{'bottleneck':>10s} {'migrations':>10s} {'hot-share':>9s} "
          f"{'served-ok':>9s}")
    for name, A in suite.items():
        eng.ingest(name, A)                       # autotunes here
        x = rng.standard_normal(A.ncols)
        y = eng.spmv(name, x)
        ok = np.allclose(y, csr_to_dense(A) @ x, atol=1e-6)
        s = eng.stats()[name]
        p = s["plan"]
        plan = f"{p['reordering']}/{p['layout']}/{p['distribution']}"
        print(f"{name:12s} {plan:26s} {_shards_str(s['shard_kernels']):24s} "
              f"{s['bottleneck']:>10s} {s['migrations']:10d} "
              f"{s['hotspot_share']:9.3f} {str(ok):>9s}")

    print("\nhot-spot FEM -> reordered; power-law -> nonzero split; "
          "wide-band -> plain block; mixed structure -> a different kernel "
          "per shard. The study, applied as policy — per nodelet.")

    # -- the amortization gate, on a busy vs an idle tenant ----------------
    # Skew the traffic: cop20k_A absorbs nearly all requests, audikw_1
    # almost none.  Then put the *same* drift re-plan (a modeled 8%
    # per-SpMV win, full-tier swap) in front of the oracle's Asudeh-style
    # gate, with each tenant's horizon = its observed traffic share
    # projected over the next `lookahead` engine requests — exactly what
    # `RebalanceConfig(amortization_lookahead=...)` feeds the live
    # rebalancer.
    x = rng.standard_normal(suite["cop20k_A"].ncols)
    for _ in range(58):
        eng.spmv("cop20k_A", x)
    gain, lookahead = 0.08, 500
    print(f"\nsame drift re-plan (modeled gain {gain:.0%}, full swap "
          f"~{DEFAULT_ORACLE.replan_pays(gain, None).break_even_spmvs:.0f} "
          f"SpMVs to break even), lookahead {lookahead} engine requests:")
    for name in ("cop20k_A", "audikw_1"):
        share = eng.stats()[name]["spmv_count"] / eng.total_requests
        d = DEFAULT_ORACLE.replan_pays(gain, horizon=lookahead * share)
        verdict = "re-plan PAYS" if d.pays else "re-plan REFUSED"
        print(f"  {name:12s} share {share:5.1%} -> horizon "
              f"{d.horizon:5.1f} SpMVs: {verdict}")
    print("volume-blind gating would have taken both; the oracle spends "
          "the one-time swap only where the traffic pays it back.")


if __name__ == "__main__":
    main()
