"""paligemma-3b [vlm] — arXiv:2407.07726 (hf).  SigLIP patch embeddings
(stubbed) + gemma-2b backbone, MQA kv=1, prefix-LM over 256 image tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
    vocab_size=257_216, activation="geglu", frontend="siglip_stub",
    prefix_len=256, tie_embeddings=True)

def smoke_config():
    return ModelConfig(
        name="paligemma-smoke", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
        activation="geglu", frontend="siglip_stub", prefix_len=8)
