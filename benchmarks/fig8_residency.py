"""Figs. 8 & 11 — per-nodelet thread residency over time on cop20k_A,
original vs random reordering (the hot-spot collapse and its mitigation).

Runs the **full synthetic matrix size** (120k rows / 2.6M nnz) on the
vectorized Emu engine by default; pass ``fast=True`` (or run via
``python -m benchmarks.run``) for the legacy scaled-down workload.

    PYTHONPATH=src python -m benchmarks.fig8_residency
"""
import argparse

import numpy as np

from .common import FULL_SIM_SCALES, SIM_SCALES, emit, sim_bandwidth


def run(fast: bool = False):
    scale = (SIM_SCALES if fast else FULL_SIM_SCALES)["cop20k_A"]
    rows = []
    for reord in ("none", "random"):
        _, res = sim_bandwidth("cop20k_A", reordering=reord, scale=scale)
        r = res.residency
        # sample 8 time points across the run
        idx = np.linspace(0, len(r) - 1, 8).astype(int)
        for i in idx:
            rows.append((f"fig8/cop20k_A@{scale}/{reord}",
                         i * res.sample_every, *[int(v) for v in r[i]]))
        # summary: mean residency of nodelet 0 vs others mid-run, plus the
        # residency CV (time-averaged per-nodelet skew) and tick count
        mid = r[len(r) // 4: max(len(r) // 2, len(r) // 4 + 1)]
        rows.append((f"fig8/cop20k_A@{scale}/{reord}/summary", -1,
                     round(float(mid.mean(axis=0)[0]), 1),
                     round(float(np.delete(mid.mean(axis=0), 0).mean()), 1),
                     res.ticks, round(res.bandwidth_mbs, 1),
                     round(res.residency_cv, 3), round(res.instr_cv, 3), 0))
    emit(rows, ("name", "tick", "n0", "n1", "n2", "n3", "n4", "n5",
                "n6/x", "n7/x"))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="legacy scaled-down workload (SIM_SCALES)")
    args = ap.parse_args()
    run(fast=args.fast)
