"""Matrix reordering techniques (paper §IV-E).

* ``none``   — identity.
* ``random`` — Fisher-Yates permutation of rows and columns (the paper's
               Valiant-style hot-spot spreader).
* ``bfs``    — breadth-first traversal order of the symmetrized adjacency
               graph (Al-Furaih & Ranka style); pulls non-zeros toward the
               diagonal.
* ``metis``  — METIS-like multilevel behaviour approximated with recursive
               greedy graph growing (GGGP): BFS-grow one half, recurse, then
               concatenate parts.  Produces balanced, diagonal-clustered
               partitions like METIS does in the paper's Fig. 9 without the
               external library.
* ``degree`` — descending-degree order (extra, beyond paper, useful for the
               power-law suite).

Symmetric permutations P A P^T are used throughout (the paper permutes rows
and columns together).
"""
from __future__ import annotations

import numpy as np

from .sparse_matrix import CSRMatrix, csr_from_coo, csr_row_nnz

__all__ = ["reorder", "reordering_permutation", "REORDERINGS"]

REORDERINGS = ("none", "random", "bfs", "metis", "degree")


def _symmetrized_adjacency(csr: CSRMatrix) -> CSRMatrix:
    """Pattern of A + A^T (no self loops) as CSR with unit values."""
    M = csr.nrows
    rows = np.repeat(np.arange(M), csr_row_nnz(csr))
    cols = csr.col_index.astype(np.int64)
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    keep = r != c
    r, c = r[keep], c[keep]
    return csr_from_coo(r, c, np.ones(r.shape[0]), (M, M), sum_duplicates=True)


def _bfs_order(adj: CSRMatrix, seeds: np.ndarray | None = None) -> np.ndarray:
    """Vectorized frontier BFS; returns vertices in discovery order."""
    M = adj.nrows
    visited = np.zeros(M, dtype=bool)
    order = np.empty(M, dtype=np.int64)
    filled = 0
    rp, ci = adj.row_ptr, adj.col_index.astype(np.int64)
    seed_iter = iter(seeds if seeds is not None else np.arange(M))
    while filled < M:
        seed = -1
        for s in seed_iter:
            if not visited[s]:
                seed = int(s)
                break
        if seed < 0:  # seeds exhausted; fall back to first unvisited
            seed = int(np.flatnonzero(~visited)[0])
        frontier = np.array([seed], dtype=np.int64)
        visited[seed] = True
        while frontier.size:
            order[filled : filled + frontier.size] = frontier
            filled += frontier.size
            counts = rp[frontier + 1] - rp[frontier]
            total = int(counts.sum())
            if total == 0:
                break
            # Gather all neighbours of the frontier in one shot.
            offsets = np.repeat(rp[frontier], counts) + (
                np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
            )
            nbrs = ci[offsets]
            nbrs = np.unique(nbrs[~visited[nbrs]])
            visited[nbrs] = True
            frontier = nbrs
    return order


def _gggp_bisect(adj: CSRMatrix, verts: np.ndarray, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Greedy graph growing: BFS-grow half of ``verts`` from a seed."""
    inset = np.zeros(adj.nrows, dtype=bool)
    inset[verts] = True
    target = verts.size // 2
    grown = np.zeros(adj.nrows, dtype=bool)
    seed = int(verts[rng.integers(verts.size)])
    frontier = np.array([seed], dtype=np.int64)
    grown[seed] = True
    count = 1
    rp, ci = adj.row_ptr, adj.col_index.astype(np.int64)
    while count < target and frontier.size:
        counts = rp[frontier + 1] - rp[frontier]
        total = int(counts.sum())
        if total == 0:
            break
        offsets = np.repeat(rp[frontier], counts) + (
            np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        )
        nbrs = ci[offsets]
        nbrs = np.unique(nbrs[inset[nbrs] & ~grown[nbrs]])
        if nbrs.size == 0:
            break
        take = nbrs[: max(target - count, 0)]
        grown[take] = True
        count += take.size
        frontier = take
    if count < target:  # disconnected: top up with arbitrary in-set vertices
        rest = verts[~grown[verts]]
        extra = rest[: target - count]
        grown[extra] = True
    left = verts[grown[verts]]
    right = verts[~grown[verts]]
    return left, right


def _metis_like_order(adj: CSRMatrix, parts: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pieces = [np.arange(adj.nrows, dtype=np.int64)]
    while len(pieces) < parts:
        nxt = []
        for piece in pieces:
            if piece.size <= 1:
                nxt.append(piece)
                continue
            l, r = _gggp_bisect(adj, piece, rng)
            nxt.extend([l, r])
        pieces = nxt
    # BFS-order within each part for intra-part locality, then concatenate.
    out = []
    for piece in pieces:
        mask = np.zeros(adj.nrows, dtype=bool)
        mask[piece] = True
        sub_order = [v for v in _bfs_order(adj, seeds=piece) if mask[v]]
        out.append(np.asarray(sub_order, dtype=np.int64)[: piece.size])
    return np.concatenate(out) if out else np.arange(adj.nrows)


def reordering_permutation(csr: CSRMatrix, method: str, *, seed: int = 0,
                           parts: int = 8) -> np.ndarray:
    """Compute the symmetric row+column permutation for one reordering.

    Parameters
    ----------
    csr : CSRMatrix
        Matrix whose (symmetrized) adjacency drives the graph orderings.
    method : {'none', 'random', 'bfs', 'metis', 'degree'}
        Reordering technique (see the module docstring; the accepted
        spellings are :data:`REORDERINGS`).
    seed : int, optional
        RNG seed for the stochastic methods (``random``, ``metis``).
    parts : int, optional
        Target part count for the METIS-like recursive bisection.

    Returns
    -------
    numpy.ndarray
        ``perm`` of shape ``(nrows,)`` with ``perm[old] = new`` — apply as
        ``csr.permuted(perm, perm)`` for the paper's P A P^T.

    Raises
    ------
    ValueError
        If ``method`` is not one of :data:`REORDERINGS`.

    Examples
    --------
    ``none`` is the identity, and every method returns a bijection:

    >>> import numpy as np
    >>> from repro.core.sparse_matrix import csr_from_coo
    >>> from repro.core.reorder import reordering_permutation
    >>> A = csr_from_coo(np.array([0, 1, 2, 3]), np.array([1, 2, 3, 0]),
    ...                  np.ones(4), (4, 4))
    >>> reordering_permutation(A, "none").tolist()
    [0, 1, 2, 3]
    >>> sorted(reordering_permutation(A, "random", seed=7).tolist())
    [0, 1, 2, 3]

    ``degree`` puts the heaviest row first:

    >>> B = csr_from_coo(np.array([2, 2, 2, 0]), np.array([0, 1, 3, 2]),
    ...                  np.ones(4), (4, 4))
    >>> int(reordering_permutation(B, "degree")[2])   # row 2 has 3 nnz
    0
    """
    M = csr.nrows
    if method == "none":
        return np.arange(M, dtype=np.int64)
    if method == "random":
        rng = np.random.default_rng(seed)
        new_of_old = np.empty(M, dtype=np.int64)
        new_of_old[rng.permutation(M)] = np.arange(M)  # Fisher-Yates via rng
        return new_of_old
    adj = _symmetrized_adjacency(csr)
    if method == "bfs":
        order = _bfs_order(adj)  # order[k] = old vertex at new position k
    elif method == "metis":
        order = _metis_like_order(adj, parts, seed)
    elif method == "degree":
        order = np.argsort(-csr_row_nnz(csr), kind="stable")
    else:
        raise ValueError(f"unknown reordering: {method!r}")
    new_of_old = np.empty(M, dtype=np.int64)
    new_of_old[order] = np.arange(M)
    return new_of_old


def reorder(csr: CSRMatrix, method: str, *, seed: int = 0, parts: int = 8) -> CSRMatrix:
    """Apply a symmetric reordering: return P A P^T.

    Parameters
    ----------
    csr : CSRMatrix
        Square matrix (the paper permutes rows and columns together).
    method : {'none', 'random', 'bfs', 'metis', 'degree'}
        Reordering technique; ``none`` returns ``csr`` unchanged.
    seed, parts : int, optional
        Passed through to :func:`reordering_permutation`.

    Returns
    -------
    CSRMatrix
        The permuted matrix (same shape, same nnz multiset).

    Raises
    ------
    ValueError
        If the matrix is not square.

    Examples
    --------
    Reordering preserves the spectrum of products: ``A @ x`` commutes with
    the permutation (this is the invariant
    ``tests/test_partition_invariants.py`` sweeps):

    >>> import numpy as np
    >>> from repro.core.sparse_matrix import csr_from_coo, csr_to_dense
    >>> from repro.core.reorder import reorder, reordering_permutation
    >>> A = csr_from_coo(np.array([0, 1, 2, 0]), np.array([1, 2, 0, 2]),
    ...                  np.array([1.0, 2.0, 3.0, 4.0]), (3, 3))
    >>> perm = reordering_permutation(A, "random", seed=3)
    >>> B = reorder(A, "random", seed=3)
    >>> x = np.array([1.0, 2.0, 3.0])
    >>> xp = np.empty(3); xp[perm] = x          # x in the new order
    >>> yp = csr_to_dense(B) @ xp
    >>> np.allclose(yp[perm], csr_to_dense(A) @ x)
    True
    """
    if csr.nrows != csr.ncols:
        raise ValueError("paper applies symmetric reorderings to square matrices")
    perm = reordering_permutation(csr, method, seed=seed, parts=parts)
    if method == "none":
        return csr
    return csr.permuted(perm, perm)
