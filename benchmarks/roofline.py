"""TPU roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads roofline_single.json (written by ``python -m repro.launch.dryrun
--unroll --json roofline_single.json``) and prints the per-cell terms:

    compute    = HLO_FLOPs / peak_FLOPs          (per chip)
    memory     = HLO_bytes / HBM_bw              (upper bound: per-op operand
                 counting over the optimized HLO — see EXPERIMENTS.md note)
    collective = collective_bytes / ICI_bw

plus the dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), and
the roofline fraction = model-flops time at peak / max(term)s — the number
§Perf hill-climbs.
"""
from __future__ import annotations

import json
import os

ARTIFACT = os.environ.get("ROOFLINE_JSON", "roofline_single.json")


def rows_from(path: str):
    with open(path) as f:
        cells = json.load(f)
    rows = []
    for c in cells:
        if c.get("status") == "skip":
            rows.append((f"{c['arch']}/{c['shape']}", "skip", "-", "-", "-",
                         "-", "-", c.get("reason", "")))
            continue
        if c.get("status") != "ok":
            rows.append((f"{c['arch']}/{c['shape']}", "fail", "-", "-", "-",
                         "-", "-", c.get("error", "")[:80]))
            continue
        tc, tm, tl = c["t_compute_s"], c["t_memory_s"], c["t_collective_s"]
        ideal = c["model_flops_total"] / c["chips"] / 197e12
        frac = ideal / max(tc, tm, tl, 1e-30)
        rows.append((f"{c['arch']}/{c['shape']}", c["mesh"],
                     f"{tc:.3e}", f"{tm:.3e}", f"{tl:.3e}",
                     c["bottleneck"],
                     f"{frac:.3f}",
                     f"useful={c['useful_flops_ratio']:.2f} "
                     f"peakGiB={c['bytes_per_device']['peak']/2**30:.1f}"))
    return rows


def run():
    if not os.path.exists(ARTIFACT):
        print(f"# {ARTIFACT} not found — run the dry-run first")
        return
    rows = rows_from(ARTIFACT)
    print("cell,mesh,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
          "roofline_frac,notes")
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    run()
