"""Per-cell perf probe for the §Perf hillclimb.

Compiles one (arch, shape) cell with RunConfig overrides and prints the
roofline terms + the top-N collective ops — the "profile" the iteration
loop reads (no real TPU, so the lowered IR is the profiler).

    PYTHONPATH=src python -m benchmarks.perf_probe gemma_7b train_4k \
        --fsdp 1 --grad-accum 8 --top 8
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import re

import numpy as np


def top_collectives(hlo: str, n: int = 10):
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    BY = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1,
          "f64": 8, "s64": 8}
    rows = []
    for line in hlo.splitlines():
        m = re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)[-\w]*\(", line)
        if not m or "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        seg = rhs[: rhs.find(m.group(1))]
        nb = 0
        for dt, dims in shape_re.findall(seg):
            k = 1
            for d in dims.split(","):
                if d:
                    k *= int(d)
            nb += k * BY.get(dt, 4)
        rows.append((nb, line.strip()[:160]))
    rows.sort(reverse=True)
    return rows[:n]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--fsdp", type=int, default=-1)
    ap.add_argument("--grad-accum", type=int, default=-1)
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--unroll", type=int, default=0)
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.launch.dryrun import analyze, lower_cell, _partial_unroll
    from repro.launch.mesh import make_production_mesh
    from repro.models import layers as L
    from repro.models.config import SHAPES
    from repro.train.loop import RunConfig

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    fsdp = cfg.param_count() > 8e9 if args.fsdp < 0 else bool(args.fsdp)
    ga = (8 if shape.kind == "train" else 1) if args.grad_accum < 0 \
        else args.grad_accum
    u = _partial_unroll(cfg) if args.unroll else 0
    run = RunConfig(fsdp=fsdp, remat=bool(args.remat), donate=True,
                    scan_unroll=u or False, grad_accum=ga)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    if u:
        L.ANALYSIS_UNROLL = True
    lo, co, _, _ = lower_cell(args.arch, args.shape, mesh, run=run)
    L.ANALYSIS_UNROLL = False
    res = analyze(lo, co, cfg, shape, mesh, grad_accum=ga)
    print(f"compute={res['t_compute_s']:.3e}s memory={res['t_memory_s']:.3e}s "
          f"collective={res['t_collective_s']:.3e}s -> {res['bottleneck']}")
    if u:
        print(f"NOTE: partial-unroll RAW module costs (~{u} of "
              f"{_partial_unroll(cfg) and 'n'} layer-units; NOT trip-count "
              f"extrapolated) — use repro.launch.dryrun --unroll for "
              f"step-accurate totals; this view is for comparing variants "
              f"and reading the top collectives.")
    print(f"peak/device={res['bytes_per_device']['peak']/2**30:.1f} GiB "
          f"useful_flops_ratio={res['useful_flops_ratio']:.3f} "
          f"(cost counts ~1 unit of the layer scan unless --unroll)")
    print(f"\ntop collectives (per appearance in HLO; scan bodies run "
          f"n_units x per step):")
    for nb, line in top_collectives(co.as_text(), args.top):
        print(f"  {nb/2**20:9.1f} MiB | {line[:130]}")


if __name__ == "__main__":
    main()
