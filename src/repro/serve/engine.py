"""Batched serving engine: prefill + decode over the distributed runtime,
plus the sparse-matrix serving path (:class:`SparseMatrixEngine`).

Small-scale runnable on CPU (examples/serve_lm.py); the same step functions
lower on the production mesh for the dry-run's decode cells.  The sparse
engine autotunes an :class:`~repro.core.spmv.SpmvPlan` for every ingested
matrix at load time (``core/plan.py``) and serves SpMV requests through the
plan-built slabs, so callers never pick layouts/kernels by hand.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PlanChoice, autotune
from repro.core.sparse_matrix import CSRMatrix
from repro.core.spmv import DistributedSpmv, SpmvPlan, build_distributed, \
    local_spmv
from repro.models import model as mm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy


@dataclasses.dataclass
class IngestedMatrix:
    """One served matrix: its autotuned choice + device-ready program."""

    name: str
    choice: PlanChoice
    dist: DistributedSpmv
    spmv_count: int = 0


class SparseMatrixEngine:
    """Serving front-end for SpMV: ingest once, autotune, serve many.

    ``ingest`` runs the cost-model autotuner (with Emu-simulator probe
    re-ranking by default — the vectorized tick engine makes a probe cost
    milliseconds, so serving ingestion gets measured rankings, not just
    analytic ones; pass ``probe=0`` to opt out) and builds the
    distributed program for the winning plan;
    ``spmv`` answers y = A @ x requests in the caller's original index
    order via the plan's slabs.  ``plans()`` exposes every decision as
    JSON (the :class:`~repro.core.plan.PlanChoice` round-trips), so an
    operator can audit *why* a matrix got its layout/kernel.
    """

    def __init__(self, *, num_shards: int = 8, probe: int | None = None,
                 seed: int = 0):
        self.num_shards = num_shards
        self.probe = probe
        self.seed = seed
        self._matrices: Dict[str, IngestedMatrix] = {}

    def ingest(self, name: str, csr: CSRMatrix,
               plan: SpmvPlan | None = None) -> PlanChoice:
        """Register ``csr`` under ``name`` with a load-time-tuned plan.

        Pass an explicit ``plan`` to bypass the autotuner (the choice is
        then recorded as a single-candidate ranking with its model cost).
        The engine's shard count is authoritative: an explicit plan is
        re-targeted to ``self.num_shards`` so the built program, its cost,
        and the recorded features all describe the same deployment.
        Re-ingesting a name replaces the previous matrix.
        """
        from repro.core.plan import estimate_cost, RankedPlan, \
            extract_features
        if plan is None:
            choice = autotune(csr, num_shards=self.num_shards,
                              seed=self.seed, probe=self.probe)
        else:
            plan = dataclasses.replace(plan, num_shards=self.num_shards)
            choice = PlanChoice(
                features=extract_features(csr, num_shards=self.num_shards),
                ranking=(RankedPlan(plan=plan,
                                    cost=estimate_cost(csr, plan)),),
                probed=0)
        dist = build_distributed(csr, choice.plan)
        self._matrices[name] = IngestedMatrix(name=name, choice=choice,
                                              dist=dist)
        return choice

    def spmv(self, name: str, x: np.ndarray) -> np.ndarray:
        """y = A @ x for the ingested matrix ``name`` (original order)."""
        m = self._matrices[name]
        m.spmv_count += 1
        return local_spmv(m.dist, x)

    def plan(self, name: str) -> SpmvPlan:
        """The plan serving ``name``."""
        return self._matrices[name].choice.plan

    def plans(self) -> Dict[str, str]:
        """name -> PlanChoice JSON for every ingested matrix."""
        return {n: m.choice.to_json() for n, m in self._matrices.items()}

    def stats(self) -> Dict[str, dict]:
        """Lightweight per-matrix serving stats (JSON-serializable)."""
        return {
            n: {"plan": dataclasses.asdict(m.choice.plan),
                "nnz": m.dist.matrix.nnz,
                "migrations": m.dist.traffic.migrations,
                "hotspot_share": m.dist.traffic.hotspot_share,
                "spmv_count": m.spmv_count}
            for n, m in self._matrices.items()}


class Engine:
    """Single-host batched generation (KV/recurrent caches threaded)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self._decode = jax.jit(
            lambda p, t, c, pos: mm.decode_step(p, cfg, t, c, pos))

    def generate(self, prompts: np.ndarray, steps: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + steps) tokens."""
        B, S0 = prompts.shape
        caches = mm.init_cache(self.cfg, B, self.serve_cfg.max_len)
        # Prefill by stepping tokens through the decode path (keeps one
        # compiled program; bulk-prefill lowering is exercised by dryrun).
        tok = None
        for t in range(S0):
            tok = prompts[:, t: t + 1]
            logits, caches = self._decode(self.params, jnp.asarray(tok),
                                          caches, jnp.int32(t))
        out = [prompts]
        pos = S0
        for _ in range(steps):
            if self.cfg.num_codebooks > 1:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, :1]   # head 0
            elif self.serve_cfg.temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, 0] / self.serve_cfg.temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            out.append(np.asarray(nxt, np.int32))
            logits, caches = self._decode(self.params, nxt, caches,
                                          jnp.int32(pos))
            pos += 1
        return np.concatenate(out, axis=1)
