"""Architecture registry: ``get_config(arch_id)`` + reduced smoke configs.

One module per architecture lives alongside this file; each exports CONFIG
(full assigned config) and ``smoke_config()`` (same family, tiny dims) used
by the per-arch CPU smoke tests.  Input specs for the dry-run are built here
(ShapeDtypeStructs only — no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig, SHAPES, shape_applicable

ARCH_IDS = (
    "gemma_7b", "qwen25_32b", "qwen3_4b", "command_r_plus_104b",
    "xlstm_1_3b", "recurrentgemma_2b", "musicgen_medium", "paligemma_3b",
    "deepseek_moe_16b", "grok_1_314b",
)

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins, weak-type-correct)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch x shape) cell.

    train / prefill: token batch (+labels for train).  decode: one new token
    plus the KV/recurrent cache of seq_len (built by abstract_cache).
    Modality frontends are stubs: precomputed frame/patch embeddings.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    def token_batch(with_labels: bool):
        if cfg.frontend == "encodec_stub":
            d = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)}
            if with_labels:
                d["labels"] = jax.ShapeDtypeStruct((B, S, cfg.num_codebooks), i32)
            return d
        if cfg.frontend == "siglip_stub":
            P = cfg.prefix_len
            d = {
                "image_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), bf16),
                "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
            }
            if with_labels:
                d["labels"] = jax.ShapeDtypeStruct((B, S - P), i32)
            return d
        d = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if with_labels:
            d["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return d

    if shape.kind == "train":
        return token_batch(with_labels=True)
    if shape.kind == "prefill":
        return token_batch(with_labels=False)
    if shape.kind == "decode":
        from repro.models.model import abstract_cache
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "caches": abstract_cache(cfg, B, S),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape.kind)


def grid_cells():
    """All 40 (arch x shape) cells with applicability flags."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            out.append((arch, sname, shape_applicable(cfg, shape)))
    return out
