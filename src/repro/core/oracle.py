"""Bottleneck-aware cost oracle: one model behind every re-plan tier.

The paper's central finding is that the *winning* sparse optimization
depends on which resource the matrix actually stresses — reordering buys
~70% on Emu when migratory hot-spots are the bottleneck and almost
nothing when they are not (§IV-B/D).  Elafrou et al. (arXiv 1711.05487)
make this precise by classifying each matrix as **bandwidth-**,
**latency-** or **imbalance-bound** and attacking only the live
bottleneck; Asudeh et al. (arXiv 2506.10356) show a reordering only pays
when its one-time cost amortizes over enough SpMVs.

:class:`CostOracle` folds both into a single facade that every consumer
queries instead of reaching into the scatter of cost primitives in
:mod:`repro.core.plan`:

* ``autotune`` (grid ranking + adaptive probe budget),
* ``device_path_model`` (SPMD serial-vs-pipelined latency),
* the rebalancer's partial tier (hot-shard kernel/exchange argmin) and
  full tier (budgeted re-autotune + swap gates), and
* the serving router's re-plan gate (amortization against per-tenant
  traffic volume).

The numeric cost tables themselves still live in ``plan.py`` (they are
the single set of weights); the oracle owns **classification** (which
bottleneck a matrix/shard is in), **class-aware scoring** (which
candidate attacks that bottleneck), **measured probing** (the Emu tick
machine, now format-aware via ``run_spmv(shard_kernels=...)``), and the
**amortization gate** (whether a re-plan pays at the observed request
volume).  Delegation keeps every legacy ranking bit-identical: consumers
that only need the tables get exactly the numbers they always got.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .emu import EmuConfig, EmuResult, run_spmv
from .layout import make_layout
from .partition import Partition, make_partition
from .sparse_matrix import CSRMatrix

__all__ = ["CostOracle", "ReplanDecision", "DEFAULT_ORACLE",
           "BOTTLENECK_CLASSES", "REPLAN_SPMV_EQUIV"]

#: The three Elafrou bottleneck classes, in reporting order.
BOTTLENECK_CLASSES = ("bandwidth", "latency", "imbalance")

#: Classification thresholds (deterministic functions of
#: :class:`~repro.core.plan.MatrixFeatures` — no sampling, no RNG, so the
#: class JSON-round-trips through ``PlanChoice`` exactly).
#:
#: *Imbalance-bound*: a heavy row-length tail means a few rows (or the
#: shards holding them) serialize the step — the paper's §IV-C/D trigger
#: for the nonzero distribution and the split family.
IMBALANCE_ROW_CV = 1.0
IMBALANCE_TAIL_SHARE = 0.25
#: A single hot column concentrates migration *arrivals* on its owner
#: nodelet (Fig. 8's nodelet-0 collapse) — ingress-limited, which the
#: model accounts as imbalance.
IMBALANCE_HOT_COL = 0.30
#: *Latency-bound*: most accesses migrate, so the machine is paying
#: migration round-trips rather than streaming local memory.
LATENCY_REMOTE_FRAC = 0.50

#: One-time cost of a swap, in *equivalent steady-state SpMVs* (the
#: Asudeh accounting).  A full re-plan re-runs the autotune grid, probes,
#: reorders and re-lowers every stage; a partial re-plan re-lowers only
#: hot shards through ``relower`` (shared stages are reused).  A re-plan
#: whose projected per-SpMV gain is ``g`` only pays if the tenant will
#: issue at least ``equiv / g`` more SpMVs against the new plan.
REPLAN_SPMV_EQUIV = {"full": 25.0, "partial": 4.0}


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    """Outcome of the amortization gate for one candidate swap.

    ``pays`` is the decision; ``break_even_spmvs`` is how many SpMVs the
    swap needs to amortize at the projected gain (``inf`` when the gain
    is non-positive); ``horizon`` echoes the projected request volume the
    gate saw (``None`` = volume-blind legacy behavior, always pays).
    """

    pays: bool
    mode: str
    gain_frac: float
    horizon: float | None
    break_even_spmvs: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class CostOracle:
    """Facade over the plan-layer cost model + Emu probe + re-plan gates.

    Stateless apart from the machine constants: one process-wide
    :data:`DEFAULT_ORACLE` serves every consumer.  All ranking-relevant
    numeric methods delegate to the single set of weights in
    :mod:`repro.core.plan`, so routing a consumer through the oracle
    never changes a legacy selection.
    """

    def __init__(self, emu: EmuConfig | None = None):
        self.emu = emu

    # -- delegated cost tables (the one set of weights) ------------------

    def kernel_costs(self, A: CSRMatrix, part: Partition) -> dict:
        """Per-shard analytic slot cost of every kernel format
        (:func:`~repro.core.plan.kernel_shard_costs`)."""
        from . import plan
        return plan.kernel_shard_costs(A, part)

    def exchange_costs(self, A: CSRMatrix, part: Partition,
                       layout="block") -> dict:
        """Per-shard weighted exchange cost of both policies
        (:func:`~repro.core.plan.exchange_shard_costs`)."""
        from . import plan
        return plan.exchange_shard_costs(A, part, layout)

    def select_kernels(self, A: CSRMatrix, part: Partition,
                       kernels: Sequence[str] | None = None,
                       costs: dict | None = None) -> tuple:
        """Per-shard kernel argmin
        (:func:`~repro.core.plan.select_shard_kernels`)."""
        from . import plan
        return plan.select_shard_kernels(
            A, part, kernels=plan.KERNELS if kernels is None else kernels,
            costs=costs)

    def select_exchanges(self, A: CSRMatrix, part: Partition, layout="block",
                         costs: dict | None = None) -> tuple:
        """Per-shard exchange argmin
        (:func:`~repro.core.plan.select_shard_exchanges`)."""
        from . import plan
        return plan.select_shard_exchanges(A, part, layout, costs=costs)

    def plan_cost(self, csr: CSRMatrix, plan_, *,
                  emu: EmuConfig | None = None,
                  col_weight: np.ndarray | None = None):
        """Analytic :class:`~repro.core.plan.PlanCost` of one plan
        (:func:`~repro.core.plan.estimate_cost`)."""
        from . import plan
        return plan.estimate_cost(csr, plan_, emu=emu or self.emu,
                                  col_weight=col_weight)

    def device_path(self, A: CSRMatrix, part: Partition, plan_,
                    emu: EmuConfig | None = None) -> dict:
        """SPMD serial-vs-pipelined latency terms
        (:func:`~repro.core.plan.device_path_model`)."""
        from . import plan
        return plan.device_path_model(A, part, plan_, emu=emu or self.emu)

    # -- bottleneck classification (Elafrou) -----------------------------

    def classify(self, features) -> str:
        """Bottleneck class of a whole matrix from its
        :class:`~repro.core.plan.MatrixFeatures`.

        Deterministic thresholds on exact structural reductions:

        * ``"imbalance"`` — heavy row tail (``row_nnz_cv`` /
          ``tail_share``) or a hot column concentrating migration
          arrivals (``hot_col_share``): a few rows or one ingress queue
          serialize the step.
        * ``"latency"``   — most accesses migrate
          (``remote_frac > 0.5``): the machine pays migration
          round-trips, so locality optimizations (reordering, block
          layout) are the live lever.
        * ``"bandwidth"`` — everything else: the step streams, and only
          format/padding efficiency moves the needle.
        """
        if (features.row_nnz_cv > IMBALANCE_ROW_CV
                or features.tail_share > IMBALANCE_TAIL_SHARE
                or features.hot_col_share > IMBALANCE_HOT_COL):
            return "imbalance"
        if features.remote_frac > LATENCY_REMOTE_FRAC:
            return "latency"
        return "bandwidth"

    def classify_shard(self, sf, remote_frac: float = 0.0) -> str:
        """Bottleneck class of one shard from its
        :class:`~repro.core.plan.ShardFeatures`.

        Shard features carry the row-tail statistics; the migration
        share is a whole-matrix property, so callers pass the matrix's
        ``remote_frac`` for the latency test.
        """
        if (sf.row_nnz_cv > IMBALANCE_ROW_CV
                or sf.tail_share > IMBALANCE_TAIL_SHARE):
            return "imbalance"
        if remote_frac > LATENCY_REMOTE_FRAC:
            return "latency"
        return "bandwidth"

    def classify_shards(self, shard_features, remote_frac: float = 0.0
                        ) -> tuple:
        """Per-shard classes (one per ``ShardFeatures`` entry)."""
        return tuple(self.classify_shard(sf, remote_frac)
                     for sf in shard_features)

    def kernel_affinity(self, bottleneck: str) -> tuple:
        """Kernel-family *tie-break* ordering for one bottleneck class.

        The per-shard selection is always the cost-table argmin
        (:meth:`select_kernels`); this ordering only decides exact ties,
        so routing a consumer through it never flips a strict winner.
        A **bandwidth**-bound shard prefers the streaming formats —
        ``tile`` first (dense lane-aligned tile streams, no per-element
        index traffic), then the regular ELL slab; an **imbalance**-bound
        shard prefers the load-balanced nnz-stream formats (``split``
        cuts the monster-row carry chain, then ``seg`` / ``hyb``); a
        **latency**-bound shard keeps the default order — format choice
        is not the live lever when most accesses migrate.
        """
        from .plan import KERNELS
        if bottleneck == "bandwidth":
            pref = ("tile", "ell")
        elif bottleneck == "imbalance":
            pref = ("split", "seg", "hyb")
        elif bottleneck == "latency":
            pref = ()
        else:
            raise ValueError(f"unknown bottleneck class: {bottleneck!r}; "
                             f"expected one of {BOTTLENECK_CLASSES}")
        return tuple(pref) + tuple(k for k in KERNELS if k not in pref)

    def score(self, cost, bottleneck: str) -> float:
        """Class-aware ranking key: the plan total plus the term that
        attacks the live bottleneck, double-weighted.

        A bandwidth-bound matrix re-weights the streaming issue term; a
        latency-bound one the migration + exchange terms; an
        imbalance-bound one the hottest-queue ingress term.  Used by the
        *new* decision paths (adaptive probe ordering, re-plan gates) —
        legacy rankings keep the plain ``cost.total`` key so frozen
        fixture selections do not move.
        """
        if bottleneck == "bandwidth":
            return float(cost.total + cost.issue_cycles)
        if bottleneck == "latency":
            return float(cost.total + cost.migration_cycles
                         + cost.comm_cycles)
        if bottleneck == "imbalance":
            return float(cost.total + cost.ingress_cycles)
        raise ValueError(f"unknown bottleneck class: {bottleneck!r}; "
                         f"expected one of {BOTTLENECK_CLASSES}")

    # -- measured probing (Emu tick machine, format-aware) ---------------

    def probe(self, A: CSRMatrix, part: Partition, plan_, *,
              emu: EmuConfig | None = None,
              engine: str = "vectorized",
              kernel_aware: bool = True) -> EmuResult:
        """Run the Emu tick machine on one prepared (matrix, partition).

        ``A``/``part`` must already be in the plan's reordered index
        space (callers thin/permute first — see
        ``plan._active_submatrix``).  ``kernel_aware`` replays the
        *format-shaped* per-shard instruction streams of the plan
        (:func:`~repro.core.emu.build_thread_traces`), so a kernel-only
        re-plan shows up in the measured probe instead of needing the
        analytic tables to break the tie.
        """
        emu = emu or self.emu or EmuConfig(nodelets=part.num_shards)
        xl = make_layout(plan_.layout, A.ncols, part.num_shards)
        sk = plan_.resolved_shard_kernels() if kernel_aware else None
        return run_spmv(A, part, xl, emu, engine=engine, shard_kernels=sk)

    def probe_seconds(self, csr: CSRMatrix, plan_, *,
                      col_weight: np.ndarray | None = None,
                      emu: EmuConfig | None = None,
                      kernel_aware: bool = True) -> float:
        """Measured Emu seconds of one plan on (optionally thinned) csr.

        Thins by traffic, reorders per the plan, partitions per the
        plan's distribution, and runs the format-aware probe — the
        rebalancer's swap-gate measurement in one call.
        """
        from . import plan as _p
        from .reorder import reordering_permutation
        A = csr if col_weight is None else \
            _p._active_submatrix(csr, col_weight, seed=plan_.seed)
        if plan_.reordering != "none":
            perm = reordering_permutation(csr, plan_.reordering,
                                          seed=plan_.seed,
                                          parts=plan_.num_shards)
            A = A.permuted(perm, perm)
        part = make_partition(A, plan_.num_shards, plan_.distribution)
        res = self.probe(A, part, plan_, emu=emu, kernel_aware=kernel_aware)
        return float(res.seconds)

    # -- split-swap structural guard -------------------------------------

    def split_span_ok(self, A: CSRMatrix, part: Partition,
                      shard: int) -> bool:
        """Whether shard ``shard`` of ``A`` has a row spanning at least
        ``SPLIT_MIN_SPAN`` seg chunks — the floor below which the split
        family's stage-2 combine is pure overhead.

        The rebalancer's partial tier evaluates swaps on a
        traffic-*thinned* structure: heavy thinning can shorten a truly
        monstrous row below the span floor, in which case a split swap
        chosen on the thinned table would deploy a useless stage 2 on
        the real matrix's short-row regime.  This guard makes the old
        docstring caveat executable.
        """
        from ..kernels.ops import SEG_CHUNK
        from .plan import SPLIT_MIN_SPAN
        from .sparse_matrix import csr_row_nnz
        r0, r1 = int(part.starts[shard]), int(part.starts[shard + 1])
        if r1 <= r0:
            return False
        max_row = int(csr_row_nnz(A)[r0:r1].max())
        span = (max_row + SEG_CHUNK - 1) // SEG_CHUNK
        return span >= SPLIT_MIN_SPAN

    # -- amortization gate (Asudeh) --------------------------------------

    def replan_pays(self, gain_frac: float, horizon: float | None,
                    mode: str = "full") -> ReplanDecision:
        """Whether a re-plan's one-time cost amortizes over the
        projected request volume.

        ``gain_frac`` is the projected fractional per-SpMV improvement
        (e.g. ``1 - new_total/old_total``); ``horizon`` the projected
        number of SpMVs the tenant will issue against the new plan (the
        router feeds its per-tenant traffic rate times the amortization
        window).  ``horizon=None`` is the legacy volume-blind gate:
        every positive-gain swap pays.  ``mode`` picks the swap's
        one-time cost in SpMV equivalents (:data:`REPLAN_SPMV_EQUIV`).
        """
        if mode not in REPLAN_SPMV_EQUIV:
            raise ValueError(f"unknown re-plan mode: {mode!r}; expected "
                             f"one of {tuple(REPLAN_SPMV_EQUIV)}")
        equiv = REPLAN_SPMV_EQUIV[mode]
        g = float(gain_frac)
        break_even = equiv / g if g > 0 else float("inf")
        if horizon is None:
            pays = g > 0
        else:
            pays = float(horizon) * max(g, 0.0) >= equiv
        return ReplanDecision(pays=pays, mode=mode, gain_frac=g,
                              horizon=None if horizon is None
                              else float(horizon),
                              break_even_spmvs=break_even)


#: Process-wide default oracle (stateless; machine constants default per
#: call-site shard count).  Every consumer that does not need custom
#: ``EmuConfig`` constants queries this instance.
DEFAULT_ORACLE = CostOracle()
