/* Emu Chick tick kernel — C transliteration of emu.simulate_reference.
 *
 * Compiled on demand by repro/core/_emu_cext.py (cc -O3 -shared -fPIC
 * -ffp-contract=off) and loaded through ctypes.  Semantics must stay
 * tick-for-tick identical to the Python reference engine:
 * tests/test_emu_vectorized.py pins ticks, migrations, per-nodelet
 * instruction counts and residency traces across all engines.
 *
 * -ffp-contract=off matters: the congestion / efficiency factors are IEEE
 * double expressions evaluated in the same order as numpy evaluates them;
 * a fused multiply-add would round differently and can flip the truncated
 * integer budgets by one cycle.
 *
 * The function runs ticks until the simulation finishes, max_ticks is hit,
 * or the residency sample buffer is full.  In the latter case it returns 1
 * with all state written back, and the caller grows the buffer and calls
 * again (the capacity check happens before a sampling tick mutates any
 * state, so re-entry is seamless).
 */
#include <stdint.h>

typedef int64_t i64;

enum { ST_RUNNING = 0, ST_WANT = 1, ST_QUEUED = 2, ST_FLIGHT = 3,
       ST_DONE = 4 };

i64 emu_run_ticks(
    /* machine configuration */
    i64 nthreads, i64 P, i64 tpn, i64 tick_cycles, i64 qcap,
    i64 me_rate, i64 ingress_rate, i64 resident_cap, i64 latency,
    i64 mig_cycles, i64 latency_hide, double cong_floor,
    i64 max_ticks, i64 sample_every,
    /* flattened segment traces (read-only) */
    const i64 *flat_nodes, const i64 *flat_cost, const i64 *seg_end,
    /* per-thread state */
    i64 *loc, int8_t *state, i64 *ptr, i64 *rem, i64 *dest, i64 *arrive,
    /* per-nodelet state: egress is (P, qcap) row-major FIFO */
    i64 *egress, i64 *qlen, i64 *instr,
    /* scratch (sizes: nthreads, P, P+1, nthreads, nthreads, P, P, P) */
    i64 *run_buf, i64 *run_cnt, i64 *run_off, i64 *cur, i64 *alive,
    i64 *residents, i64 *credits, double *cong,
    /* residency trace: (res_cap, P) int32, res_len rows used */
    int32_t *res_buf, i64 res_cap, i64 *res_len,
    /* loop registers (in/out) */
    i64 *tick_io, i64 *rr_io, i64 *migrations_io, i64 *n_done_io)
{
    i64 tick = *tick_io, rr = *rr_io, migrations = *migrations_io,
        n_done = *n_done_io, rlen = *res_len;
    i64 p, t, j;

    while (tick < max_ticks && n_done < nthreads) {
        int will_sample = (tick % sample_every) == 0;
        if (will_sample && rlen >= res_cap)
            break;                      /* pause: caller grows the buffer */

        /* Congestion factor per nodelet from egress-queue occupancy. */
        for (p = 0; p < P; p++)
            cong[p] = 1.0 - (1.0 - cong_floor) *
                ((double)qlen[p] / (double)qcap);

        /* --- 1. execute on each nodelet --------------------------------
         * Bucket RUNNING threads by nodelet in ascending id order. */
        for (p = 0; p < P; p++) run_cnt[p] = 0;
        for (t = 0; t < nthreads; t++)
            if (state[t] == ST_RUNNING) run_cnt[loc[t]]++;
        run_off[0] = 0;
        for (p = 0; p < P; p++) run_off[p + 1] = run_off[p] + run_cnt[p];
        for (p = 0; p < P; p++) residents[p] = run_off[p]; /* fill cursor */
        for (t = 0; t < nthreads; t++)
            if (state[t] == ST_RUNNING) run_buf[residents[loc[t]]++] = t;

        for (p = 0; p < P; p++) {
            i64 n = run_cnt[p];
            const i64 *base;
            i64 cap, ncur, shift, budget;
            double eff;
            if (n == 0) continue;
            /* Throttle thread activity as the migration queue fills. */
            cap = (i64)((double)tpn *
                        (1.0 - (double)qlen[p] / (double)qcap));
            if (cap < 2) cap = 2;
            /* np.roll(running, -rr)[:cap] */
            ncur = cap < n ? cap : n;
            base = run_buf + run_off[p];
            shift = rr % n;
            for (j = 0; j < ncur; j++)
                cur[j] = base[(j + shift) % n];
            /* Issue bandwidth degrades when too few threads hide latency,
             * and when the migration queue steals DRAM bandwidth. */
            eff = (double)ncur / (double)latency_hide;
            if (eff > 1.0) eff = 1.0;
            eff = eff * cong[p];
            budget = (i64)((double)tick_cycles * eff);
            /* Fair-share passes: threads cycle until budget or work runs
             * out.  Identical to the reference's inner while loop. */
            while (budget > 0 && ncur > 0) {
                i64 share = budget / ncur;
                i64 nalive = 0;
                if (share < 1) share = 1;
                for (j = 0; j < ncur; j++) {
                    i64 take, th;
                    if (budget <= 0) break;
                    th = cur[j];
                    take = share;
                    if (rem[th] < take) take = rem[th];
                    if (budget < take) take = budget;
                    rem[th] -= take;
                    budget -= take;
                    instr[p] += take;
                    if (rem[th] == 0) {
                        /* advance(): thread finished its segment */
                        ptr[th] += 1;
                        if (ptr[th] >= seg_end[th]) {
                            state[th] = ST_DONE;
                            n_done++;
                        } else {
                            i64 nxt = flat_nodes[ptr[th]];
                            rem[th] = flat_cost[ptr[th]];
                            if (nxt != loc[th]) {
                                state[th] = ST_WANT;
                                dest[th] = nxt;
                            }
                        }
                    }
                    if (state[th] == ST_RUNNING && loc[th] == p)
                        alive[nalive++] = th;
                }
                for (j = 0; j < nalive; j++) cur[j] = alive[j];
                ncur = nalive;
            }
        }
        rr += 1;

        /* --- 2. migration requests -> egress queues -------------------- */
        for (t = 0; t < nthreads; t++) {
            if (state[t] != ST_WANT) continue;
            p = loc[t];
            if (qlen[p] < qcap) {
                egress[p * qcap + qlen[p]] = t;
                qlen[p] += 1;
                state[t] = ST_QUEUED;
            }
        }

        /* --- 3. Migration Engine service with destination backpressure - */
        for (p = 0; p < P; p++) residents[p] = 0;
        for (t = 0; t < nthreads; t++)
            if (state[t] != ST_FLIGHT && state[t] != ST_DONE)
                residents[loc[t]]++;
        for (p = 0; p < P; p++) {
            i64 c = resident_cap - residents[p];
            if (c > ingress_rate) c = ingress_rate;
            if (c < 1) c = 1;           /* trickle-accept floor */
            credits[p] = c;
        }
        for (p = 0; p < P; p++) {
            i64 *q = egress + p * qcap;
            i64 n = qlen[p];
            i64 rate, sent = 0, kept = 0;
            if (n == 0) continue;
            rate = (i64)((double)me_rate * cong[p]);
            if (rate < 1) rate = 1;
            for (j = 0; j < n; j++) {
                i64 th = q[j];
                i64 d = dest[th];
                if (sent < rate && credits[d] > 0) {
                    credits[d] -= 1;
                    sent += 1;
                    state[th] = ST_FLIGHT;
                    arrive[th] = tick + latency;
                    migrations += 1;
                    instr[p] += mig_cycles;
                } else {
                    q[kept++] = th;
                }
            }
            qlen[p] = kept;
        }

        /* --- 4. arrivals ----------------------------------------------- */
        for (t = 0; t < nthreads; t++)
            if (state[t] == ST_FLIGHT && arrive[t] <= tick) {
                loc[t] = dest[t];
                dest[t] = -1;
                state[t] = ST_RUNNING;
            }

        /* --- residency sample ------------------------------------------ */
        if (will_sample) {
            int32_t *row = res_buf + rlen * P;
            for (p = 0; p < P; p++) row[p] = 0;
            for (t = 0; t < nthreads; t++)
                if (state[t] != ST_FLIGHT && state[t] != ST_DONE)
                    row[loc[t]] += 1;
            rlen += 1;
        }
        tick += 1;
    }

    *tick_io = tick;
    *rr_io = rr;
    *migrations_io = migrations;
    *n_done_io = n_done;
    *res_len = rlen;
    return (tick < max_ticks && n_done < nthreads) ? 1 : 0;
}
