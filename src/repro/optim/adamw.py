"""AdamW with fp32 master state, built for ZeRO-style sharding.

State tree mirrors the param tree (m, v in fp32), so the same PartitionSpec
tree shards optimizer state over the data axis (ZeRO-2/3) — the dominant
memory consumer for the 100B+ configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Tree
    v: Tree


def init_state(params: Tree) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(f32, params),
                      v=jax.tree.map(f32, params))


def abstract_state(params: Tree) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(f32, params),
                      v=jax.tree.map(f32, params))


def state_specs(param_spec_tree: Tree) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_spec_tree, v=param_spec_tree)


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params: Tree, grads: Tree, state: AdamWState,
                  cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"gnorm": gnorm, "lr": lr}
