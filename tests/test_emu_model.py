"""Emu machine model: paper-claim reproduction at scaled sizes."""
import numpy as np
import pytest

from repro.core.emu import EmuConfig, build_thread_traces, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix

CFG = EmuConfig()


@pytest.fixture(scope="module")
def cop():
    return make_matrix("cop20k_A", scale=0.02)


@pytest.fixture(scope="module")
def ford():
    return make_matrix("ford1", scale=0.25)


def bw(mat, layout="block", strategy="nonzero", cfg=CFG):
    part = make_partition(mat, cfg.nodelets, strategy)
    return run_spmv(mat, part, make_layout(layout, mat.ncols, cfg.nodelets), cfg)


class TestTraces:
    def test_trace_instruction_budget(self, ford):
        part = make_partition(ford, 8, "row")
        nodes, weights, homes = build_thread_traces(
            ford, part, make_layout("block", ford.ncols, 8), 64)
        total = sum(int(w.sum()) for w in weights)
        # 3 instrs per nnz (2 home + 1 x load) + 2 per row
        assert total == 3 * ford.nnz + 2 * ford.nrows

    def test_all_threads_terminate(self, ford):
        res = bw(ford)
        assert res.ticks < CFG.max_ticks
        assert res.bandwidth_mbs > 0


class TestPaperClaims:
    def test_block_beats_cyclic(self, ford):
        """Fig. 3: block layout outperforms cyclic on every matrix."""
        assert bw(ford, "block").bandwidth_mbs > bw(ford, "cyclic").bandwidth_mbs

    def test_nonzero_beats_row_on_skewed(self):
        """Fig. 6: nnz distribution wins on row-length-skewed matrices
        (paper: up to 3.34x; our model shows ~2.1x on the rmat suite)."""
        A = make_matrix("rmat", scale=0.01)
        assert bw(A, strategy="nonzero").bandwidth_mbs > \
            1.5 * bw(A, strategy="row").bandwidth_mbs

    def test_bfs_reordering_wins_on_hotspot(self, cop):
        """Fig. 10: BFS/METIS reordering beats original on cop20k-like."""
        base = bw(cop).bandwidth_mbs
        bfs = bw(reorder(cop, "bfs")).bandwidth_mbs
        assert bfs > 1.2 * base

    def test_random_reordering_direction(self, cop, ford):
        """Fig. 10: random helps on the hot-spot matrix (paper: up to +50%)
        and buys nothing on the already-banded one."""
        assert bw(reorder(cop, "random")).bandwidth_mbs > \
            1.1 * bw(cop).bandwidth_mbs
        assert bw(reorder(ford, "random")).bandwidth_mbs < \
            1.05 * bw(ford).bandwidth_mbs

    def test_residency_trace_shape(self, cop):
        res = bw(cop)
        assert res.residency.shape[1] == 8
        assert (res.residency.sum(axis=1) <= 512).all()

    def test_hotspot_congestion_visible(self, cop):
        """Fig. 8/11 system signature: with the original ordering the
        late-run residency stays badly imbalanced (one resource saturated,
        others drained); random reordering flattens it.  (Our model shows
        the pile-up *at* the hot nodelet, bounded by register sets, rather
        than at the parents — deviation noted in EXPERIMENTS.md §Paper.)"""
        from repro.core.reorder import reorder

        def tail_imbalance(mat):
            res = bw(mat)
            r = res.residency.astype(float)
            tail = r[int(len(r) * 0.7):]
            return (tail.max(axis=1) - tail.min(axis=1)).mean(), res.ticks

        imb_none, t_none = tail_imbalance(cop)
        imb_rand, t_rand = tail_imbalance(reorder(cop, "random"))
        assert imb_rand < 0.6 * imb_none     # hot-spot dispersal
        assert t_rand < t_none               # and it is faster end-to-end
