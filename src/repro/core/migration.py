"""Exact migration / remote-traffic accounting (the paper's core metric).

Thread walk model (paper §II-A, §III): a worker thread lives on its parent
nodelet (which owns its rows' mini-CSR).  Reading the next row's metadata
happens at the parent; every x[j] load happens wherever the layout placed
x[j]; b[i] is accumulated in a register and written once per row as a local
store or *remote update* (never a migration).  A migration is counted every
time the walk's current nodelet changes:

    home, x_own(j1), x_own(j2), ..., home, x_own(...), ...
          row r                      row r+1

This reproduces the paper's observations by construction: a cyclic layout
changes owner on (almost) every consecutive access; a block layout costs one
migration per run of accesses into the same remote block.

On TPU the same counts convert to collective bytes: each remote x access
moves 8 bytes over ICI (gather) instead of a 200-byte thread context, and the
per-device *skew* of remote traffic is the hot-spot analogue.  Everything
here is vectorized numpy over the full-scale matrices.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .layout import VectorLayout
from .partition import Partition
from .sparse_matrix import CSRMatrix, csr_row_nnz

__all__ = ["TrafficReport", "count_migrations", "remote_access_matrix",
           "migration_arrivals"]


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    migrations: int                 # owner changes in the thread walk
    remote_x_loads: int             # x loads not on the home nodelet
    remote_b_updates: int           # b stores issued to a remote nodelet
    mem_instr_per_nodelet: np.ndarray   # (P,) memory instructions executed
    inbound_x_loads: np.ndarray     # (P,) x loads *served by* each nodelet
    nnz_per_nodelet: np.ndarray     # (P,) work assigned to each nodelet

    @property
    def mem_instr_cv(self) -> float:
        m = self.mem_instr_per_nodelet
        mu = m.mean()
        return float(m.std() / mu) if mu else 0.0

    @property
    def inbound_cv(self) -> float:
        m = self.inbound_x_loads
        mu = m.mean()
        return float(m.std() / mu) if mu else 0.0

    @property
    def hotspot_share(self) -> float:
        """Fraction of all x loads served by the single hottest nodelet."""
        tot = self.inbound_x_loads.sum()
        return float(self.inbound_x_loads.max() / tot) if tot else 0.0


def count_migrations(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
                     b_layout: VectorLayout) -> TrafficReport:
    """Count migrations for SpMV under a partition + vector layouts."""
    P = part.num_shards
    M = csr.nrows
    nnz_per_row = csr_row_nnz(csr)
    rows = np.repeat(np.arange(M), nnz_per_row)           # (nnz,)
    home = part.owner_of_rows(M)                          # (M,) row -> nodelet
    home_of_nnz = home[rows]                              # (nnz,)
    owners = x_layout.owner_of(csr.col_index)             # (nnz,)

    # --- migrations: owner changes along the walk --------------------------
    # Within-row transitions between consecutive x owners.
    same_row = np.empty(csr.nnz, dtype=bool)
    if csr.nnz:
        same_row[0] = False
        same_row[1:] = rows[1:] == rows[:-1]
    inner = int(np.count_nonzero(same_row[1:] & (owners[1:] != owners[:-1]))) if csr.nnz > 1 else 0
    # Row starts: home -> first owner.
    starts = csr.row_ptr[:-1][nnz_per_row > 0]
    enter = int(np.count_nonzero(owners[starts] != home_of_nnz[starts]))
    # Row ends: last owner -> home (to fetch the next row's metadata).
    ends = (csr.row_ptr[1:] - 1)[nnz_per_row > 0]
    leave = int(np.count_nonzero(owners[ends] != home_of_nnz[ends]))
    migrations = inner + enter + leave

    remote_x = int(np.count_nonzero(owners != home_of_nnz))
    b_owner = b_layout.owner_of(np.arange(M))
    remote_b = int(np.count_nonzero(b_owner != home))

    # --- per-nodelet instruction/work accounting ---------------------------
    # At home: 2 loads per nnz (value + colIndex) + 2 per row (rowPtr, b acc).
    mem = np.zeros(P, dtype=np.int64)
    np.add.at(mem, home_of_nnz, 2)
    np.add.at(mem, home, 2)
    # x loads execute on the owner nodelet.
    np.add.at(mem, owners, 1)
    # Remote b updates execute on the b-owner's memory-side processor.
    np.add.at(mem, b_owner, 1)

    inbound = np.zeros(P, dtype=np.int64)
    np.add.at(inbound, owners, 1)

    nnz_per_nodelet = np.zeros(P, dtype=np.int64)
    np.add.at(nnz_per_nodelet, home_of_nnz, 1)

    return TrafficReport(
        migrations=migrations,
        remote_x_loads=remote_x,
        remote_b_updates=remote_b,
        mem_instr_per_nodelet=mem,
        inbound_x_loads=inbound,
        nnz_per_nodelet=nnz_per_nodelet,
    )


def migration_arrivals(csr: CSRMatrix, part: Partition,
                       x_layout: VectorLayout) -> np.ndarray:
    """(P,) migrations *arriving at* each nodelet under the thread walk.

    Same walk as :func:`count_migrations` (home, x owners..., home per row),
    but attributed to the *destination* nodelet of each owner change.  This
    is the ingress pressure the Nodelet Queue Manager must absorb — the
    quantity that saturates on cop20k_A's nodelet 0 (§IV-D) and that the
    plan cost model (``core/plan.py``) uses as its hot-spot term.
    """
    P = part.num_shards
    M = csr.nrows
    nnz_per_row = csr_row_nnz(csr)
    rows = np.repeat(np.arange(M), nnz_per_row)
    home = part.owner_of_rows(M)
    home_of_nnz = home[rows]
    owners = x_layout.owner_of(csr.col_index)

    arrivals = np.zeros(P, dtype=np.int64)
    if csr.nnz > 1:
        same_row = rows[1:] == rows[:-1]
        moved = same_row & (owners[1:] != owners[:-1])
        np.add.at(arrivals, owners[1:][moved], 1)
    starts = csr.row_ptr[:-1][nnz_per_row > 0]
    enter = owners[starts] != home_of_nnz[starts]
    np.add.at(arrivals, owners[starts][enter], 1)
    ends = (csr.row_ptr[1:] - 1)[nnz_per_row > 0]
    leave = owners[ends] != home_of_nnz[ends]
    np.add.at(arrivals, home_of_nnz[ends][leave], 1)
    return arrivals


def remote_access_matrix(csr: CSRMatrix, part: Partition,
                         x_layout: VectorLayout) -> np.ndarray:
    """(P, P) matrix T where T[p, q] = x loads issued by shard p into shard q.

    The TPU collective analogue: off-diagonal mass is ICI traffic; column
    skew is the hot-spot (all-to-one convergence the paper observes on
    cop20k_A's nodelet 0).
    """
    P = part.num_shards
    M = csr.nrows
    rows = np.repeat(np.arange(M), csr_row_nnz(csr))
    home_of_nnz = part.owner_of_rows(M)[rows]
    owners = x_layout.owner_of(csr.col_index)
    T = np.zeros((P, P), dtype=np.int64)
    np.add.at(T, (home_of_nnz, owners), 1)
    return T
