"""On-demand C tick kernel for the Emu simulator (optional fast path).

Compiles ``_emu_tick.c`` with the system C compiler into a content-hashed
shared object under the user cache directory and binds it through
:mod:`ctypes`.  No Python package is installed or required; if anything in
the chain is missing (no compiler, read-only cache, exotic platform), the
caller falls back to the pure-numpy engine.

Set ``REPRO_EMU_DISABLE_CEXT=1`` to force the fallback (used by tests to
exercise the numpy path explicitly).
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import shutil
import subprocess
import tempfile

import numpy as np
from numpy.ctypeslib import ndpointer

_SRC = os.path.join(os.path.dirname(__file__), "_emu_tick.c")
_kernel = None
_load_attempted = False

_i64 = ctypes.c_int64
_f64 = ctypes.c_double


def _arr(dtype):
    return ndpointer(dtype=dtype, flags="C_CONTIGUOUS")


def _cache_dir() -> str:
    root = os.environ.get("XDG_CACHE_HOME") or \
        os.path.join(os.path.expanduser("~"), ".cache")
    return os.path.join(root, "repro-emu")


def _compile(src_path: str) -> str | None:
    """Build the shared object (content-addressed, atomic rename)."""
    with open(src_path, "rb") as f:
        digest = hashlib.sha1(f.read()).hexdigest()[:16]
    for cand_dir in (_cache_dir(), tempfile.gettempdir()):
        so_path = os.path.join(cand_dir, f"_emu_tick-{digest}.so")
        if os.path.exists(so_path):
            return so_path
        cc = shutil.which("cc") or shutil.which("gcc") or \
            shutil.which("clang")
        if cc is None:
            return None
        try:
            os.makedirs(cand_dir, exist_ok=True)
            tmp = so_path + f".tmp{os.getpid()}"
            # -ffp-contract=off: the double-precision congestion math must
            # round exactly like numpy's (no FMA), or truncated cycle
            # budgets can differ by one and break engine equivalence.
            subprocess.run(
                [cc, "-O3", "-shared", "-fPIC", "-ffp-contract=off",
                 src_path, "-o", tmp],
                check=True, capture_output=True, timeout=120)
            os.replace(tmp, so_path)
            return so_path
        except (OSError, subprocess.SubprocessError):
            continue
    return None


def load_kernel():
    """Return the bound ``emu_run_ticks`` function, or None if unavailable.

    The result is cached for the process (including a negative result, so
    a missing compiler costs one probe, not one per simulation).
    """
    global _kernel, _load_attempted
    if _load_attempted:
        return _kernel
    _load_attempted = True
    if os.environ.get("REPRO_EMU_DISABLE_CEXT"):
        return None
    try:
        so_path = _compile(_SRC)
        if so_path is None:
            return None
        lib = ctypes.CDLL(so_path)
        fn = lib.emu_run_ticks
        fn.restype = _i64
        fn.argtypes = [
            # config
            _i64, _i64, _i64, _i64, _i64, _i64, _i64, _i64, _i64, _i64,
            _i64, _f64, _i64, _i64,
            # traces
            _arr(np.int64), _arr(np.int64), _arr(np.int64),
            # per-thread state
            _arr(np.int64), _arr(np.int8), _arr(np.int64), _arr(np.int64),
            _arr(np.int64), _arr(np.int64),
            # per-nodelet state
            _arr(np.int64), _arr(np.int64), _arr(np.int64),
            # scratch
            _arr(np.int64), _arr(np.int64), _arr(np.int64), _arr(np.int64),
            _arr(np.int64), _arr(np.int64), _arr(np.int64), _arr(np.float64),
            # residency buffer
            _arr(np.int32), _i64, _arr(np.int64),
            # loop registers
            _arr(np.int64), _arr(np.int64), _arr(np.int64), _arr(np.int64),
        ]
        _kernel = fn
    except OSError:
        _kernel = None
    return _kernel
