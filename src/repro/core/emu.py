"""Discrete-tick model of the Emu Chick (paper §II + §IV-D dynamics).

This is the reproduction vehicle for the paper's *Emu-side* results: the
container has no Emu hardware, so we model the machine the paper describes —

* P nodelets, each with one single-issue Gossamer Core (1 instr/cycle,
  150 MHz) and up to 64 resident threads;
* thread migration on any remote load, ~2x the cost of a local access;
* a finite egress migration queue per nodelet, serviced by the Migration
  Engine at a fixed packet rate, with per-nodelet ingress acceptance;
* thread-activity throttling when the migration queue fills (the mechanism
  behind Fig. 8's nodelet-0 collapse).

Threads execute compressed *segment traces* (nodelet, n_instructions) built
from the same walk the migration accounting uses, so the simulator and the
counter agree by construction.  Outputs: per-tick residency traces
(Figs. 8/11), total runtime -> bandwidth (Figs. 3/6/10), and per-nodelet
instruction counts (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .layout import VectorLayout
from .partition import Partition
from .sparse_matrix import CSRMatrix

__all__ = ["EmuConfig", "EmuResult", "build_thread_traces", "simulate", "run_spmv"]

# Thread states
_RUNNING, _WANT, _QUEUED, _FLIGHT, _DONE = range(5)


@dataclasses.dataclass(frozen=True)
class EmuConfig:
    nodelets: int = 8
    threads_per_nodelet: int = 64
    clock_hz: float = 150e6
    tick_cycles: int = 250
    migration_queue_cap: int = 64      # egress packets per nodelet
    me_rate: int = 24                  # packets/tick a nodelet can send
    ingress_rate: int = 24             # NQM per-dest acceptance/tick
    resident_cap: int = 80             # register sets + run-queue contexts
    migration_latency_ticks: int = 1
    migration_overhead_cycles: int = 2  # ~2x a local access (paper §II-A)
    # A single-issue GC only reaches 1 instr/cycle when enough threads are
    # resident to hide DRAM latency; below this count throughput scales
    # linearly with active threads.  This is the mechanism that makes the
    # Fig. 8 throttling collapse hurt: a starved/throttled nodelet loses
    # issue bandwidth, not just queue slots.
    latency_hide_threads: int = 32
    # Cycles per memory instruction (narrow-channel DDR4 at a 150 MHz GC:
    # row activation + transfer amortize to ~8 GC cycles per 8-byte access).
    access_cycles: int = 8
    # Congestion collapse (paper §IV-D): thread contexts in a saturated
    # migration queue are staged in the nodelet's narrow-channel DRAM, so a
    # full queue steals memory bandwidth from the GC, the memory-side
    # processor *and* the NQM itself — service capacity drops with queue
    # occupancy instead of merely queueing.  ``congestion_floor`` is the
    # residual capacity at full saturation.  The paper observes exactly
    # this: "the nodelet reduces the number of threads that can be
    # executed" and fewer threads/nodelet relieve the pressure.
    congestion_floor: float = 0.3
    max_ticks: int = 2_000_000


@dataclasses.dataclass
class EmuResult:
    ticks: int
    seconds: float
    bandwidth_mbs: float
    migrations: int
    residency: np.ndarray        # (ticks_sampled, P)
    instr_per_nodelet: np.ndarray  # (P,)
    sample_every: int

    @property
    def residency_cv(self) -> float:
        m = self.instr_per_nodelet
        return float(m.std() / m.mean()) if m.mean() else 0.0


def build_thread_traces(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
                        threads_per_nodelet: int) -> tuple[List[np.ndarray], List[np.ndarray], np.ndarray]:
    """Compressed (node, weight) segments per thread.

    Per row: the home nodelet executes 2 instrs/nnz (value+colIndex loads) +
    2 instrs (rowPtr read, b accumulate/remote-update issue); each x load is
    1 instr on the owner nodelet.  Consecutive same-node entries merge.
    """
    P = part.num_shards
    thread_starts = part.thread_splits(csr, threads_per_nodelet)
    seg_nodes: List[np.ndarray] = []
    seg_weights: List[np.ndarray] = []
    homes = []
    owners_all = x_layout.owner_of(csr.col_index).astype(np.int32)
    rp = csr.row_ptr
    for p in range(P):
        starts = thread_starts[p]
        for t in range(threads_per_nodelet):
            r0, r1 = int(starts[t]), int(starts[t + 1])
            homes.append(p)
            if r1 <= r0:
                seg_nodes.append(np.zeros(0, np.int32))
                seg_weights.append(np.zeros(0, np.int64))
                continue
            lo, hi = int(rp[r0]), int(rp[r1])
            k = hi - lo
            nrows = r1 - r0
            # Interleaved walk: home-entry at every row start, owner per nnz.
            row_nnz = np.diff(rp[r0 : r1 + 1]).astype(np.int64)
            seq = np.empty(k + nrows, dtype=np.int32)
            wts = np.empty(k + nrows, dtype=np.int64)
            home_pos = (rp[r0:r1] - lo + np.arange(nrows)).astype(np.int64)
            mask = np.zeros(k + nrows, dtype=bool)
            mask[home_pos] = True
            seq[mask] = p
            wts[mask] = 2 + 2 * row_nnz        # rowPtr + b + (val+col)/nnz
            seq[~mask] = owners_all[lo:hi]
            wts[~mask] = 1                      # the x load itself
            #

            # Compress consecutive equal nodes.
            if seq.size:
                bound = np.empty(seq.size, dtype=bool)
                bound[0] = True
                bound[1:] = seq[1:] != seq[:-1]
                idx = np.flatnonzero(bound)
                nodes = seq[idx]
                csum = np.concatenate([[0], np.cumsum(wts)])
                ends = np.concatenate([idx[1:], [seq.size]])
                weights = csum[ends] - csum[idx]
            else:
                nodes = np.zeros(0, np.int32)
                weights = np.zeros(0, np.int64)
            seg_nodes.append(nodes)
            seg_weights.append(weights)
    return seg_nodes, seg_weights, np.asarray(homes, dtype=np.int32)


def simulate(seg_nodes: Sequence[np.ndarray], seg_weights: Sequence[np.ndarray],
             homes: np.ndarray, cfg: EmuConfig, useful_bytes: float) -> EmuResult:
    nthreads = len(seg_nodes)
    P = cfg.nodelets
    loc = homes.copy()
    state = np.full(nthreads, _RUNNING, dtype=np.int8)
    ptr = np.zeros(nthreads, dtype=np.int64)
    rem = np.zeros(nthreads, dtype=np.int64)
    dest = np.full(nthreads, -1, dtype=np.int32)
    arrive = np.full(nthreads, -1, dtype=np.int64)
    nseg = np.array([s.size for s in seg_nodes], dtype=np.int64)
    for t in range(nthreads):
        if nseg[t] == 0:
            state[t] = _DONE
        else:
            rem[t] = seg_weights[t][0] * cfg.access_cycles
            if seg_nodes[t][0] != homes[t]:
                # First segment is remote (possible under nnz distribution).
                state[t] = _WANT
                dest[t] = seg_nodes[t][0]
            else:
                loc[t] = seg_nodes[t][0]

    egress: list[list[int]] = [[] for _ in range(P)]
    instr = np.zeros(P, dtype=np.int64)
    migrations = 0
    res_trace = []
    sample_every = 1
    rr = 0  # round-robin offset for fairness

    def advance(t: int) -> None:
        """Thread t finished its segment; set up the next one."""
        nonlocal migrations
        ptr[t] += 1
        if ptr[t] >= nseg[t]:
            state[t] = _DONE
            return
        rem[t] = seg_weights[t][ptr[t]] * cfg.access_cycles
        nxt = seg_nodes[t][ptr[t]]
        if nxt != loc[t]:
            state[t] = _WANT
            dest[t] = nxt
        # else: stays RUNNING on the same nodelet

    tick = 0
    while tick < cfg.max_ticks:
        if not (state != _DONE).any():
            break
        # Congestion factor per nodelet from egress-queue occupancy.
        cong = np.array([1.0 - (1.0 - cfg.congestion_floor) *
                         (len(egress[p]) / cfg.migration_queue_cap)
                         for p in range(P)])
        # --- 1. execute on each nodelet ---------------------------------
        for p in range(P):
            running = np.flatnonzero((state == _RUNNING) & (loc == p))
            if running.size == 0:
                continue
            occ = len(egress[p])
            # Throttle thread activity as the migration queue fills
            # (paper §IV-D: ~32 of 64 threads active on the hot nodelet).
            cap = max(2, int(cfg.threads_per_nodelet *
                             (1.0 - occ / cfg.migration_queue_cap)))
            running = np.roll(running, -rr)[:cap]
            # Issue bandwidth degrades when too few threads hide latency,
            # and when the migration queue steals DRAM bandwidth.
            eff = min(1.0, running.size / cfg.latency_hide_threads) * cong[p]
            budget = int(cfg.tick_cycles * eff)
            # Fair-share pass: threads cycle until budget or work runs out.
            while budget > 0 and running.size:
                share = max(budget // running.size, 1)
                alive = []
                for t in running:
                    if budget <= 0:
                        break
                    take = min(share, int(rem[t]), budget)
                    rem[t] -= take
                    budget -= take
                    instr[p] += take
                    if rem[t] == 0:
                        advance(int(t))
                    if state[t] == _RUNNING and loc[t] == p:
                        alive.append(t)
                running = np.asarray(alive, dtype=np.int64)
        rr += 1

        # --- 2. migration requests -> egress queues ----------------------
        want = np.flatnonzero(state == _WANT)
        for t in want:
            p = int(loc[t])
            if len(egress[p]) < cfg.migration_queue_cap:
                egress[p].append(int(t))
                state[t] = _QUEUED
        # --- 3. Migration Engine service with destination backpressure ---
        # Egress service degrades with the source's congestion; a packet is
        # accepted only while the destination has run-queue slots left, so a
        # hot nodelet's overflow backs up into every parent's egress queue
        # (the paper's Fig. 8 pile-up on the non-hot nodelets).
        residents = np.zeros(P, dtype=np.int64)
        on_node = (state != _FLIGHT) & (state != _DONE)
        np.add.at(residents, loc[on_node], 1)
        # Floor of 1 credit: a full nodelet still trickle-accepts, which is
        # both what the hardware does and the anti-deadlock guarantee.
        credits = np.maximum(
            np.minimum(cfg.ingress_rate, cfg.resident_cap - residents), 1)
        for p in range(P):
            q = egress[p]
            rate_p = max(int(cfg.me_rate * cong[p]), 1)
            sent, kept = 0, []
            for t in q:
                d = int(dest[t])
                if sent < rate_p and credits[d] > 0:
                    credits[d] -= 1
                    sent += 1
                    state[t] = _FLIGHT
                    arrive[t] = tick + cfg.migration_latency_ticks
                    migrations += 1
                    instr[p] += cfg.migration_overhead_cycles
                else:
                    kept.append(t)
            egress[p] = kept
        # --- 4. arrivals --------------------------------------------------
        landing = np.flatnonzero((state == _FLIGHT) & (arrive <= tick))
        for t in landing:
            loc[t] = dest[t]
            dest[t] = -1
            state[t] = _RUNNING

        # --- residency sample (threads on nodelet: running/waiting/queued) -
        if tick % sample_every == 0:
            counts = np.zeros(P, dtype=np.int32)
            on_node = state != _FLIGHT
            live = on_node & (state != _DONE)
            np.add.at(counts, loc[live], 1)
            res_trace.append(counts)
        tick += 1

    seconds = tick * cfg.tick_cycles / cfg.clock_hz
    bw = useful_bytes / seconds / 1e6 if seconds > 0 else 0.0
    return EmuResult(ticks=tick, seconds=seconds, bandwidth_mbs=bw,
                     migrations=migrations,
                     residency=np.asarray(res_trace), instr_per_nodelet=instr,
                     sample_every=sample_every)


def run_spmv(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
             cfg: EmuConfig | None = None) -> EmuResult:
    """End-to-end: build traces for (matrix, partition, layout) and simulate."""
    cfg = cfg or EmuConfig(nodelets=part.num_shards)
    nodes, weights, homes = build_thread_traces(csr, part, x_layout,
                                                cfg.threads_per_nodelet)
    # Useful bytes: values + colIndex + x loads (8 B each) + rowPtr + b.
    useful = 8.0 * (3 * csr.nnz + 2 * csr.nrows)
    return simulate(nodes, weights, homes, cfg, useful)
