"""Shared benchmark utilities: the scaled paper suite + CSV emission.

All figure benchmarks run the Emu machine model on the Table I suite.
Migration *counting* is exact and always runs at ``COUNT_SCALES``.  The
timeline simulator historically ran tiny ``SIM_SCALES`` because the
Python-loop engine was O(total instructions); the vectorized tick engine
(PR 3) runs the **full synthetic matrix sizes** (``FULL_SIM_SCALES``) for
the Fig. 6/8/11 benchmarks — only the two largest matrices stay capped,
by host memory for the flattened segment traces, not by simulator speed.
Every CSV row carries its scale through these tables.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix

# name -> legacy simulator scale (the Python-loop engine is O(total
# instrs); these sizes keep it usable for equivalence tests and --fast).
SIM_SCALES = {
    "ford1": 0.25,
    "cop20k_A": 0.02,
    "webbase-1M": 0.005,
    "rmat": 0.01,
    "nd24k": 0.002,
    "audikw_1": 0.001,
}

# name -> vectorized-engine simulator scale: the full Table-I synthetic
# sizes wherever the flattened traces fit comfortably in host memory
# (~16 B per stored nonzero); nd24k (28.7M nnz) and audikw_1 (77.6M nnz)
# are capped by that memory bound, not by simulator throughput.
FULL_SIM_SCALES = {
    "ford1": 1.0,
    "cop20k_A": 1.0,
    "webbase-1M": 1.0,
    "rmat": 1.0,
    "nd24k": 0.5,
    "audikw_1": 0.1,
}

COUNT_SCALES = {       # exact migration counting is vectorized -> larger
    "ford1": 1.0,
    "cop20k_A": 0.5,
    "webbase-1M": 0.2,
    "rmat": 0.1,
    "nd24k": 0.05,
    "audikw_1": 0.02,
}


#: Repo-root trajectory file shared by perf_probe (--emu / --drift).
BENCH_PATH = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 "..", "BENCH_emu.json"))


def append_bench_entry(entry: dict, path: str | None = None) -> str:
    """Append one entry to the ``BENCH_emu.json`` trajectory (atomic write).

    Corrupt/truncated *existing* files are treated as empty rather than
    fatal, so a crashed previous run never blocks recording new numbers.
    Recording nothing is fatal, though: an empty ``entry`` raises, and the
    rewritten file is re-read to prove the append actually landed — a
    bench run that "succeeds" while recording zero entries is a silent
    data loss this helper refuses to allow.
    """
    if not entry:
        raise ValueError("refusing to record an empty bench entry — the "
                         "bench produced no headline numbers")
    path = path or BENCH_PATH
    doc = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict) and \
                    isinstance(loaded.get("entries"), list):
                doc = loaded
        except (OSError, ValueError):
            pass                 # corrupt/truncated file: start fresh
    n_before = len(doc["entries"])
    doc["entries"].append(entry)
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
    with open(path) as f:
        written = json.load(f)
    if len(written.get("entries", [])) != n_before + 1:
        raise RuntimeError(f"bench entry did not land in {path}: "
                           f"{n_before} entries before, "
                           f"{len(written.get('entries', []))} after")
    return path


def sim_bandwidth(name: str, *, layout="block", strategy="nonzero",
                  reordering="none", seed=0, cfg: EmuConfig | None = None,
                  scale: float | None = None, engine: str = "vectorized"):
    """Simulate one suite matrix; returns (matrix, EmuResult).

    ``scale`` defaults to the legacy ``SIM_SCALES`` entry; the full-size
    figure benchmarks pass ``FULL_SIM_SCALES[name]``.  ``engine`` selects
    the tick engine (``vectorized`` / ``numpy`` / ``cext`` /
    ``reference``).
    """
    A = make_matrix(name, scale=SIM_SCALES[name] if scale is None else scale,
                    seed=seed)
    A = reorder(A, reordering, seed=seed)
    part = make_partition(A, 8, strategy)
    res = run_spmv(A, part, make_layout(layout, A.ncols, 8),
                   cfg or EmuConfig(), engine=engine)
    return A, res


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def us(fn, *args, repeats=3, **kw):
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeats * 1e6
