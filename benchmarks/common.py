"""Shared benchmark utilities: the scaled paper suite + CSV emission.

All figure benchmarks run the Emu machine model on pattern-preserving
scaled-down versions of Table I (full-scale migration *counting* is exact;
the timeline simulator runs scaled for CPU-time reasons — scales noted in
every CSV row).
"""
from __future__ import annotations

import time

from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix

# name -> simulator scale (timeline sim is O(total instrs) in python)
SIM_SCALES = {
    "ford1": 0.25,
    "cop20k_A": 0.02,
    "webbase-1M": 0.005,
    "rmat": 0.01,
    "nd24k": 0.002,
    "audikw_1": 0.001,
}

COUNT_SCALES = {       # exact migration counting is vectorized -> larger
    "ford1": 1.0,
    "cop20k_A": 0.5,
    "webbase-1M": 0.2,
    "rmat": 0.1,
    "nd24k": 0.05,
    "audikw_1": 0.02,
}


def sim_bandwidth(name: str, *, layout="block", strategy="nonzero",
                  reordering="none", seed=0, cfg: EmuConfig | None = None):
    A = make_matrix(name, scale=SIM_SCALES[name], seed=seed)
    A = reorder(A, reordering, seed=seed)
    part = make_partition(A, 8, strategy)
    res = run_spmv(A, part, make_layout(layout, A.ncols, 8),
                   cfg or EmuConfig())
    return A, res


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def us(fn, *args, repeats=3, **kw):
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeats * 1e6
