"""Distributed-layer tests.

The multi-device cases run in a subprocess so the 8 fake host devices never
leak into this session (smoke tests must see 1 device — brief requirement).
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.launch.mesh import auto_axis_types
from repro.models import model as mm, params as pp
from repro.optim import adamw
from repro.train.loop import RunConfig, make_train_step


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.spmv import (SpmvPlan, build_distributed, make_spmv_fn,
                                 make_seg_spmv_fn)
    from repro.core.sparse_matrix import csr_to_dense
    from repro.data.matrices import make_matrix
    from repro.launch.mesh import auto_axis_types

    mesh = jax.make_mesh((8,), ("model",), **auto_axis_types(1))
    A = make_matrix("cop20k_A", scale=0.005)
    x = np.random.default_rng(1).standard_normal(A.ncols).astype(np.float32)
    out = {}
    from repro.core.spmv import build_halo, make_halo_spmv_fn
    for layout in ("block", "cyclic"):
        for reord in ("none", "bfs"):
            plan = SpmvPlan(layout=layout, distribution="nonzero",
                            reordering=reord, num_shards=8)
            d = build_distributed(A, plan)
            fn = make_spmv_fn(d, mesh)
            with mesh:
                y = fn(jnp.array(d.data), jnp.array(d.cols),
                       jnp.array(d.x_to_device(x)))
            b = np.zeros(A.nrows)
            for p in range(8):
                r = int(d.rows_per_shard[p])
                o = int(d.row_offset[p])
                b[o:o+r] = np.asarray(y[p])[:r]
            ref = csr_to_dense(d.matrix) @ x
            out[f"{layout}/{reord}"] = bool(np.allclose(b, ref, atol=1e-3))
    # halo-exchange path: correctness on the hot matrix; the ICI saving
    # holds on the *banded* matrix (H3: halo only pays under locality)
    plan = SpmvPlan(layout="block", distribution="nonzero",
                    reordering="none", num_shards=8)
    d = build_distributed(A, plan)
    h = build_halo(d)
    fn = make_halo_spmv_fn(d, h, mesh)
    with mesh:
        y = fn(jnp.array(d.data), jnp.array(h.cols_remap),
               jnp.array(h.send_idx), jnp.array(d.x_to_device(x)))
    b = np.zeros(A.nrows)
    for p in range(8):
        r = int(d.rows_per_shard[p]); o = int(d.row_offset[p])
        b[o:o+r] = np.asarray(y[p])[:r]
    out["halo"] = bool(np.allclose(b, csr_to_dense(d.matrix) @ x, atol=1e-3))
    # segmented nonzero-balanced kernel path, both distributions
    for strat in ("nnz", "row"):
        seg_plan = SpmvPlan(layout="block", distribution=strat, kernel="seg",
                            num_shards=8)
        d = build_distributed(A, seg_plan)
        fn = make_seg_spmv_fn(d, mesh, use_kernel=True, interpret=True)
        with mesh:
            y = fn(jnp.array(d.seg_vals), jnp.array(d.seg_cols),
                   jnp.array(d.seg_rows), jnp.array(d.seg_pieces),
                   jnp.array(d.x_to_device(x)))
        b = np.zeros(A.nrows)
        for p in range(8):
            r = int(d.rows_per_shard[p]); o = int(d.row_offset[p])
            b[o:o+r] = np.asarray(y[p])[:r]
        out[f"seg/{strat}"] = bool(np.allclose(b, csr_to_dense(d.matrix) @ x,
                                               atol=1e-3))
    F = make_matrix("ford1", scale=0.05)
    df = build_distributed(F, plan)
    hf = build_halo(df)
    out["halo_saves_ici_banded"] = bool(hf.comm_elems_per_shard
                                        < df.x_layout.padded_length())
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_distributed_spmv_8dev_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(res.values()), res


def test_train_step_factory_single_device():
    """The jitted train step runs on a 1x1 mesh (CPU) and reduces loss."""
    cfg = get_smoke_config("qwen3_4b")
    mesh = jax.make_mesh((1, 1), ("data", "model"), **auto_axis_types(2))
    run = RunConfig(fsdp=False, remat=True, donate=False, grad_accum=2)
    _, jit_for, _ = make_train_step(cfg, adamw.AdamWConfig(lr=1e-2), mesh, run)
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    with mesh:
        step = jit_for(batch)
        losses = []
        for i in range(3):
            params, opt, m = step(params, opt, batch,
                                  jax.random.fold_in(key, i))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_moe_valiant_shuffle_preserves_output_distribution():
    """Valiant shuffle is a relabeling: loss stats stay comparable and the
    expert load CV does not degrade."""
    import dataclasses
    from repro.models.moe import moe_ffn
    cfg = get_smoke_config("deepseek_moe_16b")
    m = cfg.moe
    key = jax.random.PRNGKey(0)
    d = cfg.d_model
    params = {
        "router": jax.random.normal(key, (d, m.num_experts), jnp.float32) * 0.02,
        "w_gate": jax.random.normal(key, (m.num_experts, d, m.d_expert),
                                    jnp.bfloat16) * 0.05,
        "w_up": jax.random.normal(key, (m.num_experts, d, m.d_expert),
                                  jnp.bfloat16) * 0.05,
        "w_down": jax.random.normal(key, (m.num_experts, m.d_expert, d),
                                    jnp.bfloat16) * 0.05,
    }
    x = jax.random.normal(key, (2, 32, d), jnp.bfloat16)
    y0, _ = moe_ffn(params, x, m, "swiglu")
    m2 = dataclasses.replace(m, valiant_shuffle=True)
    y1, _ = moe_ffn(params, x, m2, "swiglu", rng=jax.random.PRNGKey(7))
    # same tokens, same experts — only dispatch order changed; outputs match
    # up to capacity-drop differences (loose tolerance).
    diff = np.abs(np.asarray(y0, np.float32) - np.asarray(y1, np.float32))
    assert np.median(diff) < 0.05
