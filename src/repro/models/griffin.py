"""Griffin/RecurrentGemma blocks: RG-LRU recurrent block (arXiv:2402.19427).

The RG-LRU is a diagonal gated linear recurrence — h_t = a_t * h_{t-1} +
sqrt(1 - a_t^2) * (i_t * u_t) — which trains with a log-depth
``associative_scan`` (the sub-quadratic path that makes long_500k feasible)
and decodes with an O(1) step.  The block is the Griffin recurrent block:
a GeLU linear branch gating a (causal conv -> RG-LRU) branch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
_C = 8.0  # Griffin's fixed recurrence sharpness


def _rg_lru_scan(u, r_gate, i_gate, lam, h0=None):
    """u/r_gate/i_gate: (B, S, D); lam: (D,) logits of a. Returns (B,S,D), hS."""
    log_a = -_C * jax.nn.softplus(lam.astype(F32)) * \
        jax.nn.sigmoid(r_gate.astype(F32))                  # (B, S, D) <= 0
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_gate.astype(F32)) * u.astype(F32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if h0 is not None:
        # Fold the carried state into the first step's offset.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(F32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(jnp.bfloat16), h[:, -1]


def _rg_lru_step(u, r_gate, i_gate, lam, h_prev):
    log_a = -_C * jax.nn.softplus(lam.astype(F32)) * \
        jax.nn.sigmoid(r_gate.astype(F32))
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_gate.astype(F32)) * u.astype(F32)
    h = a * h_prev.astype(F32) + \
        jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return h.astype(jnp.bfloat16), h


def causal_conv1d(x, kernel, conv_state=None):
    """Depthwise causal conv.  x: (B, S, D); kernel: (W, D).

    conv_state: (B, W-1, D) trailing inputs from the previous call (decode).
    Returns (y, new_state).
    """
    W = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, D)
    y = sum(xp[:, i : i + x.shape[1]] * kernel[i][None, None]
            for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return y, new_state


def rglru_block(params, x, cfg, state=None, *, decode=False):
    """Griffin recurrent block.  state: (h, conv_state)."""
    B, S, d = x.shape
    width = params["lam"].shape[0]
    gate = jax.nn.gelu(jnp.einsum(
        "bsd,dm->bsm", x, params["w_gelu_gate"]).astype(F32)).astype(x.dtype)
    u = jnp.einsum("bsd,dm->bsm", x, params["w_in"])
    h_prev, conv_state = (None, None) if state is None else state
    u, conv_state = causal_conv1d(u, params["conv_kernel"], conv_state)
    r_gate = jnp.einsum("bsm,mg->bsg", u, params["w_rgate"])
    i_gate = jnp.einsum("bsm,mg->bsg", u, params["w_igate"])
    if decode:
        h, h_last = _rg_lru_step(u[:, 0], r_gate[:, 0], i_gate[:, 0],
                                 params["lam"], h_prev)
        h = h[:, None]
    else:
        h0 = h_prev
        h, h_last = _rg_lru_scan(u, r_gate, i_gate, params["lam"], h0)
    out = jnp.einsum("bsm,md->bsd", h * gate, params["w_out"])
    return out, (h_last, conv_state)
