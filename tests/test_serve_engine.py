"""Serving-path regression tests: Engine.generate edge semantics, the
SparseMatrixEngine error/stats contract, batched multi-RHS SpMV exactness,
and the feature-keyed plan cache.
"""
import numpy as np
import pytest

from repro.core.sparse_matrix import csr_to_dense
from repro.core.spmv import SpmvPlan, build_distributed, local_spmv
from repro.data.matrices import make_matrix
from repro.serve.engine import Engine, ServeConfig, SparseMatrixEngine


# --------------------------------------------------------------------------
# Engine.generate edges (prefill/decode semantics)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_engine():
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models import params as pp
    cfg = get_smoke_config("qwen3_4b")
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_steps_zero_returns_prompts(lm_engine):
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = np.array([[1, 2, 3], [4, 5, 6]], dtype=np.int32)
    out = eng.generate(prompts, steps=0)
    np.testing.assert_array_equal(out, prompts)
    # and a (B, 0) prompt with steps=0 is a harmless no-op
    empty = np.zeros((2, 0), dtype=np.int32)
    assert eng.generate(empty, steps=0).shape == (2, 0)
    # steps=0 never samples, so it must not demand a key either
    sampling = Engine(cfg, params, ServeConfig(max_len=32, temperature=0.9))
    np.testing.assert_array_equal(sampling.generate(prompts, steps=0),
                                  prompts)


def test_generate_empty_prefill_raises(lm_engine):
    """S0 == 0 with steps > 0 used to crash with NameError on `logits`;
    the chosen semantics are an explicit error telling callers to seed
    the prompt (e.g. BOS)."""
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    empty = np.zeros((2, 0), dtype=np.int32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate(empty, steps=4)


def test_generate_temperature_requires_key(lm_engine):
    """temperature > 0 without a key used to silently decode greedily."""
    import jax
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32, temperature=0.8))
    prompts = np.array([[1, 2]], dtype=np.int32)
    with pytest.raises(ValueError, match="PRNG key"):
        eng.generate(prompts, steps=2)
    out = eng.generate(prompts, steps=2, key=jax.random.PRNGKey(0))
    assert out.shape == (1, 4)


def test_generate_greedy_still_works(lm_engine):
    cfg, params = lm_engine
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = np.array([[1, 2]], dtype=np.int32)
    out = eng.generate(prompts, steps=3)
    assert out.shape == (1, 5)
    np.testing.assert_array_equal(out[:, :2], prompts)


# --------------------------------------------------------------------------
# SparseMatrixEngine contract
# --------------------------------------------------------------------------

def test_spmv_unknown_name_is_actionable_and_uncounted():
    eng = SparseMatrixEngine(num_shards=4)
    A = make_matrix("ford1", scale=0.05)
    eng.ingest("ford", A)
    x = np.zeros(A.ncols)
    with pytest.raises(KeyError, match="ford"):
        eng.spmv("typo", x)
    # the failed call neither counted nor created anything
    assert eng.stats()["ford"]["spmv_count"] == 0
    assert set(eng.stats()) == {"ford"}
    eng.spmv("ford", x)
    assert eng.stats()["ford"]["spmv_count"] == 1
    with pytest.raises(KeyError):
        eng.plan("typo")


def test_batched_spmv_bitwise_matches_per_vector():
    """(M, B) blocks equal per-vector calls bitwise, both kernels."""
    A = make_matrix("cop20k_A", scale=0.005)
    rng = np.random.default_rng(0)
    X = rng.standard_normal((A.ncols, 4))
    for kernel in ("ell", "seg"):
        dist = build_distributed(A, SpmvPlan(kernel=kernel, num_shards=4,
                                             reordering="bfs"))
        Y = local_spmv(dist, X)
        assert Y.shape == (A.nrows, 4)
        for b in range(X.shape[1]):
            assert np.array_equal(Y[:, b], local_spmv(dist, X[:, b])), \
                (kernel, b)
        np.testing.assert_allclose(Y, csr_to_dense(A) @ X, atol=1e-6)
    with pytest.raises(ValueError, match="elements"):
        local_spmv(dist, X[: A.ncols // 2])
    with pytest.raises(ValueError, match=r"\(N,\) or \(N, B\)"):
        local_spmv(dist, X[..., None])


def test_engine_serves_batched_requests():
    eng = SparseMatrixEngine(num_shards=4)
    A = make_matrix("rmat", scale=0.002)
    eng.ingest("r", A)
    X = np.random.default_rng(1).standard_normal((A.ncols, 3))
    Y = eng.spmv("r", X)
    np.testing.assert_allclose(Y, csr_to_dense(A) @ X, atol=1e-6)
    for b in range(3):
        assert np.array_equal(eng.spmv("r", X[:, b]), Y[:, b])


def test_plan_cache_reuses_structural_twins():
    eng = SparseMatrixEngine(num_shards=4)
    c1 = eng.ingest("m1", make_matrix("rmat", scale=0.002, seed=0))
    assert eng.plan_cache_hits == 0
    c2 = eng.ingest("m2", make_matrix("rmat", scale=0.002, seed=7))
    assert eng.plan_cache_hits == 1
    assert eng.stats()["m2"]["plan_cache_hit"]
    assert not eng.stats()["m1"]["plan_cache_hit"]
    assert c2.plan == c1.plan
    assert len(c2.ranking) == 1 and c2.probed == 0   # no grid re-run
    # a different archetype misses
    eng.ingest("banded", make_matrix("ford1", scale=0.05))
    assert eng.plan_cache_hits == 1
    # cached plans still serve correctly
    A2 = make_matrix("rmat", scale=0.002, seed=7)
    x = np.random.default_rng(2).standard_normal(A2.ncols)
    np.testing.assert_allclose(eng.spmv("m2", x), csr_to_dense(A2) @ x,
                               atol=1e-6)


def test_plan_cache_can_be_disabled():
    eng = SparseMatrixEngine(num_shards=4, plan_cache=False)
    eng.ingest("m1", make_matrix("rmat", scale=0.002, seed=0))
    c2 = eng.ingest("m2", make_matrix("rmat", scale=0.002, seed=7))
    assert eng.plan_cache_hits == 0
    assert len(c2.ranking) > 1                       # full grid ran
