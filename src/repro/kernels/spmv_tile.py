"""Pallas TPU kernel: bitmask-tiled SpMV with scalar-prefetched tile walk.

The two-level tiled layout (`docs/ARCHITECTURE.md` §"Bitmask-tiled
layout") streams dense ``(bm, bn)`` tiles with whole-tile FMAs and **no
per-element column indices**: the coarse pointer grid (``tile_ptr``) is
flattened host-side into per-block-row prefetch tables so the BlockSpec
index maps can walk exactly the occupied tiles of each block row —

    y[mb*bm : (mb+1)*bm] += data[tid[mb, k]] @ x[bc[mb, k]*bn : ...]

Empty tiles are never visited (they have no table entry past
``counts[mb]``); partially-occupied tiles are zero-filled so their dead
lanes contribute exact zeros.  This is the cache-blocked answer of
Elafrou et al. applied at the shard level: one ``bc`` id moves a whole
lane-aligned x tile across the memory hierarchy and feeds ``bm*bn``
FMAs, versus one gathered element per FMA for the scalar row formats.

Like ``spmv_bell.py`` before it (this kernel family absorbs Block-ELL),
the tables are *scalar-prefetched* (``PrefetchScalarGridSpec``) so the
index maps run ahead of the compute stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["tile_walk_spmv", "tile_contrib"]


def _tile_spmv_kernel(counts_ref, tid_ref, bc_ref, data_ref, xb_ref, y_ref):
    mb = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    tile = data_ref[0]                         # (bm, bn)
    xtile = xb_ref[0]                          # (bn,)
    contrib = jnp.dot(tile, xtile, preferred_element_type=y_ref.dtype)
    # Slots past this block row's tile count re-read the last valid tile
    # (the index map clamps); mask their contribution to an exact zero.
    y_ref[...] += jnp.where(k < counts_ref[mb], contrib, 0.0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_walk_spmv(data: jnp.ndarray, counts: jnp.ndarray, tid: jnp.ndarray,
                   bc: jnp.ndarray, x: jnp.ndarray, *,
                   interpret: bool = False) -> jnp.ndarray:
    """y = A @ x over the flattened tile walk (single vector).

    data:   (T, bm, bn) dense zero-filled tiles
    counts: (Mb,) int32 occupied tiles per block row
    tid:    (Mb, K) int32 tile id per walk slot (clamped on padding)
    bc:     (Mb, K) int32 block-column id per walk slot
    x:      (Nb*bn,)  ->  returns y: (Mb*bm,)
    """
    Mb, K = tid.shape
    _, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    return pl.pallas_call(
        _tile_spmv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(Mb, K),
            in_specs=[
                # Walk the occupied tiles of block row mb, in bc order.
                pl.BlockSpec((1, bm, bn),
                             lambda mb, k, cnt, tid, bc: (tid[mb, k], 0, 0)),
                # Stream exactly the x tile this tile multiplies.
                pl.BlockSpec((1, bn),
                             lambda mb, k, cnt, tid, bc: (bc[mb, k], 0)),
            ],
            out_specs=pl.BlockSpec((1, bm),
                                   lambda mb, k, cnt, tid, bc: (mb, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mb, bm), x.dtype),
        interpret=interpret,
    )(counts, tid, bc, data, xb).reshape(Mb * bm)


def _tile_contrib_kernel(d_ref, x_ref, o_ref):
    o_ref[0] = jnp.dot(d_ref[0], x_ref[0], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_contrib(data: jnp.ndarray, xg: jnp.ndarray, *,
                 interpret: bool = False) -> jnp.ndarray:
    """Per-tile dense matvec (T, bm, bn) x (T, bn) -> (T, bm).

    The device executor's flat tile path: x lanes are pre-gathered
    through the remapped augmented buffer (so there is no block grid to
    index), and the dense per-tile FMA stream runs here; the caller
    scatter-adds the contributions into block rows.
    """
    T, bm, bn = data.shape
    return pl.pallas_call(
        _tile_contrib_kernel,
        grid=(T,),
        in_specs=[pl.BlockSpec((1, bm, bn), lambda t: (t, 0, 0)),
                  pl.BlockSpec((1, bn), lambda t: (t, 0))],
        out_specs=pl.BlockSpec((1, bm), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, bm), data.dtype),
        interpret=interpret,
    )(data, xg)
