"""deepseek-moe-16b [moe] — arXiv:2401.06066 (hf).  Fine-grained MoE:
64 routed experts top-6 + 2 shared, d_expert=1408, dense first layer."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=10944,
    vocab_size=102_400, activation="swiglu", dense_first_layers=1,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408))

def smoke_config():
    return ModelConfig(
        name="deepseek-moe-smoke", family="moe", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=256,
        vocab_size=512, activation="swiglu", dense_first_layers=1,
        block_pattern=("moe",),
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_expert=32))
