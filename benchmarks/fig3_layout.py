"""Fig. 3 — SpMV bandwidth: cyclic vs block vector layout (Emu model),
plus exact full-scale migration counts (block should be 1.42-6.3x fewer)."""
from repro.core.layout import make_layout
from repro.core.migration import count_migrations
from repro.core.partition import make_partition
from repro.data.matrices import make_matrix
from .common import COUNT_SCALES, SIM_SCALES, emit, sim_bandwidth


def run():
    rows = []
    for name in SIM_SCALES:
        bws = {}
        for layout in ("cyclic", "block"):
            _, res = sim_bandwidth(name, layout=layout, strategy="row")
            bws[layout] = res.bandwidth_mbs
        A = make_matrix(name, scale=COUNT_SCALES[name])
        p = make_partition(A, 8, "row")
        migs = {}
        for layout in ("cyclic", "block"):
            migs[layout] = count_migrations(
                A, p, make_layout(layout, A.ncols, 8),
                make_layout(layout, A.nrows, 8)).migrations
        rows.append((f"fig3/{name}", round(bws["cyclic"], 1),
                     round(bws["block"], 1),
                     round(bws["block"] / max(bws["cyclic"], 1e-9), 2),
                     migs["cyclic"], migs["block"],
                     round(migs["cyclic"] / max(migs["block"], 1), 2)))
    emit(rows, ("name", "cyclic_mbs", "block_mbs", "block_speedup",
                "mig_cyclic", "mig_block", "mig_ratio"))


if __name__ == "__main__":
    run()
