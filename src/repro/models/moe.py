"""Mixture-of-Experts layer — where the paper's technique lives in an LM.

Token->expert dispatch is an SpMV-shaped irregular gather
(docs/ARCHITECTURE.md#design-4):
the routing matrix is a sparse (tokens x experts) matrix, expert capacity
is the nnz-balanced work distribution, and the optional *Valiant shuffle*
is the paper's random-reordering insight applied to the all-to-all — a
random pre-permutation of tokens prevents correlated token runs from
converging on one expert shard at the same time (the cop20k_A hot-spot,
but on ICI).

Dispatch is sort-based (no (tokens x E x capacity) one-hot): tokens are
sorted by expert id, ranked within expert, and scattered into an
(E, capacity, d) buffer — O(tokens * top_k) memory.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import MoEConfig

F32 = jnp.float32


def _constrain(x, *axes):
    from .model import _maybe_constrain
    return _maybe_constrain(x, *axes)


def _ep_possible(num_experts: int) -> bool:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return (not m.empty and "model" in m.axis_names
                and num_experts % m.shape["model"] == 0)
    except Exception:
        return False


def _expert_constraint(t):
    """(E, cap, d)-shaped buffers: expert-parallel over "model" when E
    divides the axis (deepseek: 64/16); otherwise shard capacity over
    "data" (grok: 8 experts on a 16-wide axis would silently replicate a
    15 GB f32 buffer — §Perf H2).  Never both: 2D E x cap sharding makes
    the expert einsum re-gather capacity slices (§Perf H1 iteration 2)."""
    if _ep_possible(t.shape[0]):
        return _constrain(t, "model", None, None)
    return _constrain(t, None, "data", None)


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8 * ((cap + 7) // 8), 8)      # sublane aligned


def route(params, x2d: jnp.ndarray, cfg: MoEConfig):
    """Router logits -> (weights, expert ids) per token, top-k."""
    logits = jnp.einsum("td,de->te", x2d.astype(F32), params["router"].astype(F32))
    weights, ids = jax.lax.top_k(logits, cfg.top_k)           # (T, K)
    weights = jax.nn.softmax(weights, axis=-1)
    # z-loss keeps router logits bounded (GShard/ST-MoE practice).
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_zloss
    return weights, ids, zloss


def moe_ffn(params, x: jnp.ndarray, cfg: MoEConfig, activation: str,
            *, rng: Optional[jnp.ndarray] = None,
            combine: str = "scatter_psum"):
    """x: (B, S, d) -> (B, S, d), aux-loss scalar.

    Expert tensors: params["w_gate"|"w_up"]: (E, d, f), params["w_down"]:
    (E, f, d) — sharded over the "model" axis on their E (deepseek) or f
    (grok) dimension by the runtime's param specs.
    """
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)

    perm = None
    if cfg.valiant_shuffle:
        # Paper §IV-E random reordering -> Valiant-style spread: permute the
        # token order entering dispatch so same-expert runs decorrelate.
        key = rng if rng is not None else jax.random.PRNGKey(0)
        perm = jax.random.permutation(key, T)
        x2d = jnp.take(x2d, perm, axis=0)

    weights, ids, zloss = route(params, x2d, cfg)
    sp = cfg.expert_split
    if sp > 1:
        # exact decomposition: expert e == sum of thin experts (e*sp + j);
        # each half receives the token with the SAME routing weight.
        ids = (ids[..., None] * sp +
               jnp.arange(sp, dtype=ids.dtype)).reshape(ids.shape[0], -1)
        weights = jnp.repeat(weights, sp, axis=-1)
    E, K = cfg.num_experts * sp, cfg.top_k * sp
    # NB: every thin expert receives the same tokens as its parent expert
    # (the split duplicates routing), so capacity is NOT divided by sp.
    cap = _capacity(T, cfg)

    flat_ids = ids.reshape(-1)                                  # (T*K,)
    # Rank of each (token, k) within its expert = position in capacity buf.
    order = jnp.argsort(flat_ids, stable=True)
    ranked = jnp.zeros((T * K,), jnp.int32)
    seg_pos = jnp.arange(T * K) - jnp.searchsorted(
        flat_ids[order], flat_ids[order], side="left")
    ranked = ranked.at[order].set(seg_pos.astype(jnp.int32))
    keep = ranked < cap                                        # capacity drop
    slot = jnp.where(keep, flat_ids * cap + ranked, E * cap)   # E*cap = trash

    # Dispatch: GATHER tokens into the (E, cap, d) buffer via the inverse
    # slot->token map instead of scattering (token, k) rows.  A scatter
    # into a sharded buffer made GSPMD materialize + all-gather (T*K, d)
    # u32 index tensors (6 GB each at deepseek train scale — §Perf H1);
    # the gather keeps index math on small replicated int vectors and the
    # buffer 2D-sharded: experts over "model" (when E divides it) and
    # capacity over "data" — no replicated activation buffers.
    tok_of_slot = jnp.full((E * cap + 1,), T, jnp.int32).at[slot].set(
        (jnp.arange(T * K, dtype=jnp.int32) // K).astype(jnp.int32))
    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x.dtype)], axis=0)
    expert_in = jnp.take(x_pad, tok_of_slot[: E * cap], axis=0
                         ).reshape(E, cap, d)
    expert_in = _expert_constraint(expert_in)

    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if not _ep_possible(E):
        # Capacity-over-data mode: the FSDP shard of the expert weights'
        # d-dim collides with the cap-over-data activations and GSPMD
        # prefers gathering the 7.7 GB f32 activations (§Perf H2).  Force
        # the cheap gather instead: un-shard the weights' d-dim (the
        # ZeRO-3 per-layer weight gather, ~200 MB) and keep f TP-sharded.
        wg = _constrain(wg, None, None, "model")
        wu = _constrain(wu, None, None, "model")
        wd = _constrain(wd, None, "model", None)

    h_gate = jnp.einsum("ecd,edf->ecf", expert_in, wg)
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, wu)
    if activation == "geglu":
        h = jax.nn.gelu(h_gate.astype(F32)).astype(x.dtype) * h_up
    else:
        h = jax.nn.silu(h_gate.astype(F32)).astype(x.dtype) * h_up
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd)
    expert_out = _expert_constraint(expert_out)

    # Combine back to token order.  Two lowerings:
    #  - "gather": take() rows of the (E*cap, d) buffer per (token, k) —
    #    GSPMD turns the gather from an expert-sharded operand into an
    #    all-gather of the whole expert output buffer (2.5x token bytes);
    #  - "scatter_psum": scatter-add expert outputs into the (T, d) token
    #    buffer — each expert shard contributes only its rows and GSPMD
    #    reduces with one activation-sized all-reduce (the TP-FFN pattern).
    #    This is the §Perf MoE iteration (EXPERIMENTS.md).
    flat_out = expert_out.reshape(E * cap, d)
    w_flat = (weights * keep.reshape(T, K)).reshape(T * K)
    if combine == "scatter_psum":
        w_of_slot = jnp.zeros((E * cap + 1,), F32).at[slot].set(w_flat)
        # bf16 contributions: the psum over the model axis carries half the
        # bytes; each token sums <= top_k bf16 terms (error ~1e-2, on par
        # with the rest of the bf16 pipeline).
        contrib = (flat_out.astype(F32) *
                   w_of_slot[: E * cap, None]).astype(x.dtype)
        y = jnp.zeros((T + 1, d), x.dtype).at[tok_of_slot[: E * cap]].add(
            contrib)[:T]
    else:
        flat_pad = jnp.concatenate(
            [flat_out, jnp.zeros((1, d), x.dtype)], axis=0)
        gathered = jnp.take(flat_pad, slot, axis=0).reshape(T, K, d)
        y = jnp.einsum("tkd,tk->td", gathered.astype(F32),
                       weights * keep.reshape(T, K)).astype(x.dtype)

    # Load-balance aux loss (Switch-style): mean prob * mean assignment.
    me = jnp.mean(jax.nn.one_hot(ids, E, dtype=F32), axis=(0, 1))
    aux = jnp.sum(me * me) * E * 1e-2 / max(sp, 1) + zloss

    if perm is not None:
        inv = jnp.argsort(perm)
        y = jnp.take(y, inv, axis=0)
    return y.reshape(B, S, d), aux


def shared_ffn(params, x: jnp.ndarray, activation: str):
    """Always-on shared experts (DeepSeekMoE): standard FFN on every token."""
    from .layers import ffn_block
    return ffn_block(params, x, activation)


def expert_load(ids: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Tokens per expert — the collective-skew diagnostic (Fig. 8 analogue)."""
    return jnp.sum(jax.nn.one_hot(ids.reshape(-1), num_experts), axis=0)
