"""Autotuned SpMV serving in ~30 lines.

Ingest three structurally different matrices into the sparse serving
engine; each gets its own cost-model-tuned plan at load time (no
hand-picked layouts/kernels), then serve y = A @ x requests and print
which plan each matrix ended up with and why it differs.

    PYTHONPATH=src python examples/autotune_serve.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.sparse_matrix import csr_to_dense
from repro.data.matrices import make_matrix
from repro.serve.engine import SparseMatrixEngine


def main():
    eng = SparseMatrixEngine(num_shards=8)
    suite = {"cop20k_A": 0.02, "webbase-1M": 0.002, "audikw_1": 0.001}
    rng = np.random.default_rng(0)

    print(f"{'matrix':12s} {'chosen plan':34s} {'migrations':>10s} "
          f"{'hot-share':>9s} {'served-ok':>9s}")
    for name, scale in suite.items():
        A = make_matrix(name, scale=scale)
        eng.ingest(name, A)                       # autotunes here
        x = rng.standard_normal(A.ncols)
        y = eng.spmv(name, x)
        ok = np.allclose(y, csr_to_dense(A) @ x, atol=1e-6)
        s = eng.stats()[name]
        p = s["plan"]
        plan = f"{p['reordering']}/{p['layout']}/{p['distribution']}/{p['kernel']}"
        print(f"{name:12s} {plan:34s} {s['migrations']:10d} "
              f"{s['hotspot_share']:9.3f} {str(ok):>9s}")

    print("\nhot-spot FEM -> reordered; power-law -> nonzero split; "
          "wide-band -> plain block. The study, applied as policy.")


if __name__ == "__main__":
    main()
