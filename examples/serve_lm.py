"""Serve a small LM with batched requests through the production engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import params as pp
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = get_smoke_config("qwen3_4b")
    params = pp.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=64))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 8)).astype(np.int32)
    out = engine.generate(prompts, steps=16)
    print("batched generation (4 requests, 8-token prompts, +16 tokens):")
    for i, row in enumerate(out):
        print(f"  req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
