"""Fig. 12 — the cache-memory baseline: identical SpMV measured on THIS
machine's real CPU.  Paper: reorderings buy <=16%, random never helps."""
from repro.core.cache_model import measure_cpu_spmv
from repro.core.reorder import reorder
from repro.data.matrices import make_matrix
from .common import emit

SCALES = {"ford1": 1.0, "cop20k_A": 0.3, "webbase-1M": 0.1, "rmat": 0.05}


def run():
    rows = []
    for name, scale in SCALES.items():
        A = make_matrix(name, scale=scale)
        bws = {}
        for reord in ("none", "random", "bfs", "metis"):
            B = reorder(A, reord)
            bws[reord] = measure_cpu_spmv(B, trials=5).bandwidth_mbs
        base = max(bws["none"], 1e-9)
        rows.append((f"fig12/{name}",
                     *[round(bws[r], 1) for r in
                       ("none", "random", "bfs", "metis")],
                     *[round(bws[r] / base, 3) for r in
                       ("random", "bfs", "metis")]))
    emit(rows, ("name", "none_mbs", "random_mbs", "bfs_mbs", "metis_mbs",
                "random_x", "bfs_x", "metis_x"))


if __name__ == "__main__":
    run()
