"""Mixed-tenant trace replay: cold vs warm-restart serving (PAPER.md §IV).

The paper's amortization story — optimization only pays once its cost is
spread over enough SpMVs — has a fleet-scale corollary: a *restart* that
re-runs feature extraction, the autotune grid, the Emu probe and the full
lowering resets the amortization clock for every tenant at once.  This
bench replays one realistic serving trace against two engines:

* **cold**: a fresh :class:`~repro.serve.router.SparseMatrixEngine` with
  an empty artifact store — every tenant pays autotune + probe + lower;
* **warm**: a second engine instance pointed at the artifact store the
  cold engine populated — every tenant digest-hits its bundle and loads
  device-ready slabs (no autotune, no probe, no lower).

The trace is mixed-tenant (a skewed power-law "web" tenant interleaved
with a banded "grid" tenant), **bursty** (tenants arrive in geometric
bursts, not round-robin), and **log-structured** in column activity: each
request's hot columns form a window that advances through the matrix like
a log head, so consecutive requests overlap but the active set drifts —
the workload shape the paper's §IV load-balance study worries about.

Recorded per engine: total ingest seconds, requests/sec and p99 latency
over the identical trace; the headline is the warm-restart ingest speedup
(gate: >= 5x) with **bitwise-identical** ``y`` on every replayed request.
A final phase replays a concurrent slice of the trace with cross-request
micro-batching enabled and records its requests/sec and batch widths.

CLI mirrors ``hetero_bench``: ``--fast`` shrinks the tenants for the CI
smoke step, ``--budget-seconds`` is the wall-clock tripwire, and
``perf_probe --serve`` appends the entry to ``BENCH_emu.json``.
"""
from __future__ import annotations

import argparse
import concurrent.futures
import json
import shutil
import sys
import tempfile
import time

import numpy as np


# --------------------------------------------------------------------------
# trace generation
# --------------------------------------------------------------------------

def make_tenants(*, fast: bool, seed: int = 0) -> dict:
    """name -> CSRMatrix for the two serving tenants."""
    from repro.data.matrices import banded, powerlaw
    if fast:
        return {"web": powerlaw(384, 12_000, seed=seed),
                "grid": banded(384, 10_000, 12, seed=seed + 1)}
    return {"web": powerlaw(2048, 120_000, seed=seed),
            "grid": banded(2048, 100_000, 24, seed=seed + 1)}


def make_trace(tenants: dict, n_requests: int, *, seed: int = 0,
               burst_mean: float = 6.0, hot_frac: float = 0.06,
               advance_frac: float = 0.01) -> list:
    """A bursty, log-structured request trace: ``[(tenant, x), ...]``.

    Tenants arrive in geometric bursts of mean ``burst_mean``.  Each
    request's x is small background noise plus a hot window of
    ``hot_frac * N`` columns; the window start advances by
    ``advance_frac * N`` per request to that tenant (wrapping), so the
    active column set crawls through the matrix like a log head.
    """
    rng = np.random.default_rng(seed)
    names = sorted(tenants)
    heads = {n: 0 for n in names}
    trace = []
    while len(trace) < n_requests:
        name = names[int(rng.integers(len(names)))]
        burst = 1 + int(rng.geometric(1.0 / burst_mean))
        N = tenants[name].ncols
        W = max(int(hot_frac * N), 8)
        step = max(int(advance_frac * N), 1)
        for _ in range(min(burst, n_requests - len(trace))):
            x = 0.01 * rng.standard_normal(N)
            lo = heads[name]
            idx = (lo + np.arange(W)) % N
            x[idx] += 1.0 + 0.1 * rng.standard_normal(W)
            heads[name] = (lo + step) % N
            trace.append((name, x))
    return trace


# --------------------------------------------------------------------------
# replay
# --------------------------------------------------------------------------

def _replay(engine, trace) -> dict:
    """Serve the whole trace sequentially; returns timings + outputs."""
    lat = np.empty(len(trace))
    outs = []
    t0 = time.perf_counter()
    for i, (name, x) in enumerate(trace):
        r0 = time.perf_counter()
        outs.append(engine.spmv(name, x))
        lat[i] = time.perf_counter() - r0
    total = time.perf_counter() - t0
    return {"rps": round(len(trace) / total, 1),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "total_seconds": round(total, 3),
            "outs": outs}


def _ingest_all(engine, tenants: dict) -> dict:
    per = {}
    for name, csr in tenants.items():
        t0 = time.perf_counter()
        engine.ingest(name, csr)
        per[name] = round(time.perf_counter() - t0, 4)
    return per


def run_trace_replay(*, fast: bool = False, shards: int = 8,
                     probe: int | None = None, seed: int = 0,
                     n_requests: int | None = None,
                     threads: int = 4) -> dict:
    from repro.serve.router import MicroBatchConfig, SparseMatrixEngine

    tenants = make_tenants(fast=fast, seed=seed)
    n = n_requests if n_requests is not None else (160 if fast else 600)
    trace = make_trace(tenants, n, seed=seed + 7)
    store = tempfile.mkdtemp(prefix="trace_replay_artifacts_")
    try:
        cold_eng = SparseMatrixEngine(num_shards=shards, probe=probe,
                                      seed=seed, artifact_dir=store)
        cold_ing = _ingest_all(cold_eng, tenants)
        cold = _replay(cold_eng, trace)

        warm_eng = SparseMatrixEngine(num_shards=shards, probe=probe,
                                      seed=seed, artifact_dir=store)
        warm_ing = _ingest_all(warm_eng, tenants)
        warm = _replay(warm_eng, trace)
        warm_stats = warm_eng.stats()

        bitwise = all(np.array_equal(a, b)
                      for a, b in zip(cold.pop("outs"), warm.pop("outs")))

        # Concurrent phase: the same tenants behind micro-batching, a
        # thread pool firing a slice of the trace at once per wave.
        mb_eng = SparseMatrixEngine(
            num_shards=shards, probe=probe, seed=seed, artifact_dir=store,
            micro_batch=MicroBatchConfig(max_batch=threads, max_wait_ms=2.0))
        _ingest_all(mb_eng, tenants)
        mb_lat = []
        mb_outs = []
        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(threads) as pool:
            for w0 in range(0, len(trace), threads):
                wave = trace[w0: w0 + threads]
                r0 = time.perf_counter()
                futs = [pool.submit(mb_eng.spmv, nm, x) for nm, x in wave]
                ys = [f.result() for f in futs]
                mb_lat.append(time.perf_counter() - r0)
                mb_outs.append((wave, ys))
        mb_total = time.perf_counter() - t0
        for wave, ys in mb_outs:
            for (nm, x), y in zip(wave, ys):
                if not np.array_equal(y, warm_eng.spmv(nm, x)):
                    raise AssertionError(
                        "micro-batched output differs from solo serve")
        mb_stats = mb_eng.stats()

        cold_total = round(sum(cold_ing.values()), 4)
        warm_total = round(sum(warm_ing.values()), 4)
        return {
            "workload": "serve/trace_replay",
            "shards": shards,
            "n_requests": n,
            "threads": threads,
            "fast": fast,
            "tenants": {name: {"shape": list(csr.shape), "nnz": csr.nnz,
                               "plan_kernel": cold_eng.plan(name).kernel,
                               "warm_start":
                                   warm_stats[name]["warm_start"]}
                        for name, csr in tenants.items()},
            "cold": {"ingest_seconds": cold_ing,
                     "total_ingest_seconds": cold_total,
                     "rps": cold["rps"], "p99_ms": cold["p99_ms"]},
            "warm": {"ingest_seconds": warm_ing,
                     "total_ingest_seconds": warm_total,
                     "rps": warm["rps"], "p99_ms": warm["p99_ms"]},
            "ingest_speedup": round(cold_total / max(warm_total, 1e-9), 1),
            "bitwise_equal": bitwise,
            "micro_batch": {
                "rps": round(len(trace) / mb_total, 1),
                "p99_wave_ms": round(
                    float(np.percentile(mb_lat, 99)) * 1e3, 3),
                **{name: mb_stats[name]["micro_batch"]
                   for name in tenants}},
        }
    finally:
        shutil.rmtree(store, ignore_errors=True)


def check(entry: dict) -> bool:
    """Acceptance gate: >= 2 tenants all warm-started, warm-restart ingest
    >= 5x faster than cold, bitwise-identical outputs, positive rps."""
    tenants = entry["tenants"]
    return (len(tenants) >= 2
            and all(t["warm_start"] for t in tenants.values())
            and entry["ingest_speedup"] >= 5.0
            and entry["bitwise_equal"]
            and entry["cold"]["rps"] > 0
            and entry["warm"]["rps"] > 0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: small two-tenant trace, same gates")
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--probe", type=int, default=None,
                    help="autotune probe budget for the cold ingests "
                         "(default: repro.core.plan.DEFAULT_PROBE)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--budget-seconds", type=float, default=None,
                    help="fail if the whole run exceeds this wall-clock "
                         "budget (CI tripwire)")
    ap.add_argument("--json", action="store_true",
                    help="print the entry as JSON only")
    args = ap.parse_args()

    t0 = time.perf_counter()
    entry = run_trace_replay(fast=args.fast, shards=args.shards,
                             probe=args.probe, seed=args.seed,
                             n_requests=args.requests,
                             threads=args.threads)
    ok = check(entry)
    wall = time.perf_counter() - t0
    entry["wall_seconds"] = round(wall, 2)
    if args.budget_seconds is not None and wall > args.budget_seconds:
        ok = False
        entry["budget_exceeded"] = True

    if args.json:
        print(json.dumps(entry, indent=2))
    else:
        print(f"trace replay: {len(entry['tenants'])} tenants, "
              f"{entry['n_requests']} requests, shards={entry['shards']}")
        for name, t in entry["tenants"].items():
            print(f"  {name:>6}: shape={t['shape']} nnz={t['nnz']} "
                  f"kernel={t['plan_kernel']} warm_start={t['warm_start']}")
        c, w = entry["cold"], entry["warm"]
        print(f"  cold : ingest {c['total_ingest_seconds']}s, "
              f"{c['rps']} req/s, p99 {c['p99_ms']}ms")
        print(f"  warm : ingest {w['total_ingest_seconds']}s, "
              f"{w['rps']} req/s, p99 {w['p99_ms']}ms")
        print(f"  warm-restart ingest speedup: "
              f"{entry['ingest_speedup']}x (bar >= 5), bitwise "
              f"{entry['bitwise_equal']}")
        mb = entry["micro_batch"]
        print(f"  micro-batch x{entry['threads']}: {mb['rps']} req/s, "
              f"p99 wave {mb['p99_wave_ms']}ms")
        print(f"  wall {entry['wall_seconds']}s -> "
              f"{'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
