"""Distributed SpMV: the paper's optimization axes as a TPU `shard_map`.

``SpmvPlan`` is the first-class configuration object: layout x distribution
x reordering, exactly the paper's study grid.  ``build_distributed`` turns a
host CSR matrix into per-device ELL slabs (each device holds the mini-CSR ->
mini-ELL of its rows, Fig. 2) plus the collective program that exchanges x:

* ``allgather``  — every device gathers the full x then gathers locally;
                   the Hein et al. baseline the paper contrasts against
                   (x replicated), maximal ICI bytes, zero imbalance.
* ``halo``       — each device fetches only the x shards it actually reads
                   (block layout + reordered matrices make this cheap); the
                   faithful analogue of migratory access.

The migration analogue for the roofline: cross-shard x elements actually
moved.  ``plan_traffic`` reports them without compiling anything.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                    # 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_norep(fn, **kw):
    """shard_map with replication checking off (pallas_call has no rep rule);
    the flag is ``check_rep`` on 0.4.x and ``check_vma`` on newer jax."""
    try:
        return _shard_map(fn, check_rep=False, **kw)
    except TypeError:
        return _shard_map(fn, check_vma=False, **kw)

from .layout import VectorLayout, make_layout
from .migration import TrafficReport, count_migrations, remote_access_matrix
from .partition import Partition, make_partition
from .reorder import reordering_permutation
from .sparse_matrix import CSRMatrix, ELL_LANE, ELL_SUBLANE, csr_to_ell
from repro.kernels import ops as kops

__all__ = ["SpmvPlan", "DistributedSpmv", "build_distributed",
           "make_spmv_fn", "make_seg_spmv_fn", "build_halo",
           "make_halo_spmv_fn", "local_spmv"]


@dataclasses.dataclass(frozen=True)
class SpmvPlan:
    """The paper's optimization grid as one config object.

    ``distribution="nnz"`` is the nonzero-balanced split (alias of
    ``"nonzero"``): device row-ranges are chosen by cumulative-nnz split
    instead of equal rows, so a power-law matrix cannot converge all the
    work on one device the way it converges threads on one nodelet in the
    paper's §IV-D.  ``kernel="seg"`` additionally builds per-shard
    nonzero-balanced segmented slabs (kernels/spmv_seg.py) whose *grid* is
    load-balance-aware too, instead of the row-tiled ELL slabs.
    """

    layout: Literal["block", "cyclic"] = "block"
    distribution: Literal["row", "nonzero", "nnz"] = "nonzero"
    reordering: Literal["none", "random", "bfs", "metis", "degree"] = "none"
    exchange: Literal["allgather", "halo"] = "halo"
    kernel: Literal["ell", "seg"] = "ell"
    num_shards: int = 8
    seed: int = 0

    @classmethod
    def auto(cls, csr: CSRMatrix, *, num_shards: int = 8, seed: int = 0,
             probe: int | None = None, **grid) -> "SpmvPlan":
        """Pick a plan for ``csr`` with the cost-model autotuner.

        Thin wrapper over :func:`repro.core.plan.autotune` (which see for
        the candidate grid and the ``probe`` refinement — simulator
        re-ranking of the top ``plan.DEFAULT_PROBE`` bases unless
        overridden); returns only the winning plan.  Use ``autotune`` directly when the full ranking or
        the JSON-serializable :class:`~repro.core.plan.PlanChoice` is
        needed (the serving engine persists it per ingested matrix).
        """
        from .plan import autotune
        return autotune(csr, num_shards=num_shards, seed=seed, probe=probe,
                        **grid).plan


@dataclasses.dataclass
class DistributedSpmv:
    """Device-ready distributed SpMV program + its traffic accounting."""

    plan: SpmvPlan
    matrix: CSRMatrix                 # reordered matrix (host)
    partition: Partition
    x_layout: VectorLayout
    b_layout: VectorLayout
    # Stacked per-shard ELL slabs, padded to common shape: (S, rows_pad, W)
    data: np.ndarray
    cols: np.ndarray                  # local x index if owner==self else remote
    rows_per_shard: np.ndarray        # true row counts (S,)
    row_offset: np.ndarray            # absolute first row per shard (S,)
    traffic: TrafficReport
    shard_traffic: np.ndarray         # (S, S) x-elements moved p<-q
    # Stacked per-shard segmented slabs (plan.kernel == "seg" only):
    # vals/cols/rows (S, C_pad, L), pieces (S, P_pad, 4) int32 columns
    # [chunk, lo, hi, local_row]; padded pieces target the dummy row and
    # encode (lo=1, hi=0) so their prefix difference is exactly zero.
    seg_vals: np.ndarray | None = None
    seg_cols: np.ndarray | None = None
    seg_rows: np.ndarray | None = None
    seg_pieces: np.ndarray | None = None
    # Symmetric permutation applied by plan.reordering: perm[old] = new.
    # None for reordering="none"; local_spmv uses it to accept/return
    # vectors in the caller's original index order.
    perm: np.ndarray | None = None

    def x_to_device(self, x: np.ndarray) -> np.ndarray:
        return self.x_layout.to_sharded(x)

    def b_from_device(self, b_shards: np.ndarray) -> np.ndarray:
        return self.b_layout.from_sharded(b_shards)


def build_distributed(csr: CSRMatrix, plan: SpmvPlan) -> DistributedSpmv:
    if csr.nrows != csr.ncols:
        raise ValueError("paper applies symmetric reorderings to square matrices")
    perm = None
    A = csr
    if plan.reordering != "none":
        perm = reordering_permutation(csr, plan.reordering, seed=plan.seed,
                                      parts=plan.num_shards)
        A = csr.permuted(perm, perm)
    part = make_partition(A, plan.num_shards, plan.distribution)
    x_layout = make_layout(plan.layout, A.ncols, plan.num_shards)
    b_layout = make_layout(plan.layout, A.nrows, plan.num_shards)
    traffic = count_migrations(A, part, x_layout, b_layout)
    shard_traffic = remote_access_matrix(A, part, x_layout)

    S = plan.num_shards
    slabs = [csr_to_ell(A.row_slice(int(part.starts[p]), int(part.starts[p + 1])),
                        lane=ELL_LANE, sublane=ELL_SUBLANE) for p in range(S)]
    rows_pad = max(s.data.shape[0] for s in slabs)
    width = max(s.width for s in slabs)
    data = np.zeros((S, rows_pad, width), dtype=np.float32)
    cols = np.zeros((S, rows_pad, width), dtype=np.int32)
    for p, s in enumerate(slabs):
        r, w = s.data.shape
        data[p, :r, :w] = s.data
        cols[p, :r, :w] = s.cols
        if s.overflow_vals.size:
            raise AssertionError("uncapped ELL conversion cannot overflow")
    seg_arrays = _build_seg_slabs(A, part) if plan.kernel == "seg" else {}
    return DistributedSpmv(
        plan=plan, matrix=A, partition=part, x_layout=x_layout,
        b_layout=b_layout, data=data, cols=cols,
        rows_per_shard=part.rows_per_shard().astype(np.int64),
        row_offset=part.starts[:-1].astype(np.int64),
        traffic=traffic, shard_traffic=shard_traffic, perm=perm,
        **seg_arrays)


def _build_seg_slabs(A: CSRMatrix, part: Partition) -> dict:
    """Stacked per-shard SegMatrix slabs, padded to common shapes.

    Column ids stay global (the allgather path gathers the full x); row ids
    are shard-local.  Piece padding targets the per-shard dummy row
    (``rows_pad``) with (lo=1, hi=0) so ``psum[c, hi] - psum[c, lo-1]``
    evaluates to an exact zero for padded entries.
    """
    S = part.num_shards
    segs = [kops.seg_from_csr(A.row_slice(int(part.starts[p]),
                                          int(part.starts[p + 1])))
            for p in range(S)]
    C_pad = max(s.num_chunks for s in segs)
    L = segs[0].chunk
    P_pad = max(max(s.n_pieces for s in segs), 1)
    rows_pad = int(part.rows_per_shard().max())
    vals = np.zeros((S, C_pad, L), dtype=np.float32)
    cols = np.zeros((S, C_pad, L), dtype=np.int32)
    rows = np.zeros((S, C_pad, L), dtype=np.int32)
    pieces = np.zeros((S, P_pad, 4), dtype=np.int32)
    pieces[:, :, 1] = 1                       # (lo=1, hi=0) -> exact zero
    pieces[:, :, 3] = rows_pad                # dummy row, sliced off later
    for p, s in enumerate(segs):
        vals[p, : s.num_chunks] = s.vals
        cols[p, : s.num_chunks] = s.cols
        rows[p, : s.num_chunks] = s.rows
        n = s.n_pieces
        pieces[p, :n, 0] = s.piece_chunk
        pieces[p, :n, 1] = s.piece_lo
        pieces[p, :n, 2] = s.piece_hi
        pieces[p, :n, 3] = s.piece_row
    return dict(seg_vals=vals, seg_cols=cols, seg_rows=rows,
                seg_pieces=pieces)


def _gathered_x_to_global(x_all: jnp.ndarray, kind: str) -> jnp.ndarray:
    """(S, per_shard) all-gathered shards -> global index order (padded)."""
    if kind == "block":
        return x_all.reshape(-1)
    return x_all.T.reshape(-1)              # cyclic: idx = i*S + p


def make_spmv_fn(dist: DistributedSpmv, mesh: Mesh, axis: str = "model",
                 *, use_kernel: bool = False, interpret: bool = True):
    """Return a jit-able f(data, cols, x_shards) -> b (global, on host layout).

    x_shards: (S, per_shard) in layout order.  Exchange strategy per plan:
    ``allgather`` gathers x across the axis, then every device gathers its
    ELL operands from the replicated vector.
    """
    x_layout = dist.x_layout
    per_shard = x_layout.padded_length() // x_layout.num_shards
    kind = x_layout.kind
    spmv_local = partial(kops.ell_spmv, interpret=interpret) if use_kernel \
        else kops.ell_spmv_ref

    def local_x_to_global(x_all: jnp.ndarray) -> jnp.ndarray:
        return _gathered_x_to_global(x_all, kind)

    def shard_fn(data, cols, x_shard):
        # data/cols: (1, rows_pad, W); x_shard: (1, per_shard)
        x_all = jax.lax.all_gather(x_shard[0], axis)       # (S, per_shard)
        x_global = local_x_to_global(x_all)
        y = spmv_local(data[0], cols[0], x_global)
        return y[None]

    fn = _shard_map_norep(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    return jax.jit(fn)


def make_seg_spmv_fn(dist: DistributedSpmv, mesh: Mesh, axis: str = "model",
                     *, use_kernel: bool = False, interpret: bool = True):
    """Segmented-kernel analogue of :func:`make_spmv_fn`.

    f(seg_vals, seg_cols, seg_rows, seg_pieces, x_shards) -> (S, rows_pad)
    shards.  Requires ``plan.kernel == "seg"`` so the slabs exist.  Both
    the device *row ranges* (distribution="nnz") and the local kernel grid
    (equal-nnz chunks) are load-balanced — the full nonzero-split story.
    """
    if dist.seg_vals is None:
        raise ValueError("build_distributed was not run with plan.kernel='seg'")
    kind = dist.x_layout.kind
    rows_pad = int(dist.rows_per_shard.max())

    def shard_fn(vals, cols, rows, pieces, x_shard):
        x_all = jax.lax.all_gather(x_shard[0], axis)       # (S, per_shard)
        x_global = _gathered_x_to_global(x_all, kind)
        pc = pieces[0]
        y = kops.seg_spmv(
            (vals[0], cols[0], rows[0], pc[:, 0], pc[:, 1], pc[:, 2],
             pc[:, 3]),
            x_global, num_rows=rows_pad + 1,               # +1: dummy row
            use_kernel=use_kernel, interpret=interpret)
        return y[None, :rows_pad]

    fn = _shard_map_norep(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    return jax.jit(fn)


def local_spmv(dist: DistributedSpmv, x: np.ndarray) -> np.ndarray:
    """Single-host execution of a built plan: y = A @ x, original order.

    Runs the same per-shard slabs the device path consumes, but with plain
    numpy on one host — no mesh, no jit.  ``x`` and the returned ``y`` are
    in the *caller's* index order; the reordering permutation recorded in
    ``dist.perm`` is applied/inverted internally.  This is the execution
    path for correctness tests and for small single-host serving
    (``serve.engine.SparseMatrixEngine``).

    ``x`` may be a single (N,) vector or a multi-RHS block (N, B); the
    result matches ((M,) or (M, B)).  The batched path broadcasts the same
    per-shard slab products over the trailing axis with the identical
    summation/scatter order, so column b of a batched call is *bitwise*
    equal to the per-vector call on ``x[:, b]``.
    """
    if x.shape[0] != dist.matrix.ncols:
        raise ValueError(f"x has {x.shape[0]} elements, matrix expects "
                         f"{dist.matrix.ncols}")
    if x.ndim == 1:
        return _local_spmv_block(dist, x[:, None])[:, 0]
    if x.ndim != 2:
        raise ValueError(f"x must be (N,) or (N, B), got shape {x.shape}")
    return _local_spmv_block(dist, x)


def _local_spmv_block(dist: DistributedSpmv, x: np.ndarray) -> np.ndarray:
    """(N, B) -> (M, B), batch-major internally.

    The RHS block is held as (B, N) so every per-row reduction is over the
    last *contiguous* axis regardless of B — numpy then applies the same
    pairwise-summation tree for every batch width, which is what makes
    column b of a block call bitwise-equal to a B=1 call on ``x[:, b]``.
    The seg scatter-add loops per RHS for the same reason (np.add.at
    accumulates in identical index order per column).
    """
    B = x.shape[1]
    xr = x if dist.perm is None else _apply_perm(x, dist.perm)
    x_pad = np.zeros((B, dist.x_layout.padded_length()), dtype=np.float64)
    x_pad[:, : dist.matrix.ncols] = xr.T

    S = dist.plan.num_shards
    y = np.zeros((B, dist.matrix.nrows), dtype=np.float64)
    for p in range(S):
        r = int(dist.rows_per_shard[p])
        o = int(dist.row_offset[p])
        if dist.plan.kernel == "seg":
            rows_pad = int(dist.rows_per_shard.max())
            vals = dist.seg_vals[p].astype(np.float64)
            contrib = vals * x_pad[:, dist.seg_cols[p]]   # (B, C, L)
            yp = np.zeros((B, rows_pad + 1))
            for b in range(B):
                np.add.at(yp[b], dist.seg_rows[p], contrib[b])
            y[:, o:o + r] = yp[:, :r]
        else:
            data = dist.data[p].astype(np.float64)
            slab = data * x_pad[:, dist.cols[p]]          # (B, R, W)
            y[:, o:o + r] = np.ascontiguousarray(slab).sum(axis=2)[:, :r]
    yt = y.T
    return yt if dist.perm is None else yt[dist.perm]


def _apply_perm(v: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """v in old order -> v in new order (perm[old] = new)."""
    out = np.empty_like(v)
    out[perm] = v
    return out


# --------------------------------------------------------------------------
# halo exchange — the migratory-access analogue (beyond the all-gather
# baseline, which is the Hein et al. x-replication the paper contrasts)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class HaloProgram:
    """Host-precomputed halo exchange for one DistributedSpmv.

    Shard q sends to shard p exactly the x entries p's rows read from q
    (``send_idx[q, p]``, padded to the max halo H).  On device one
    ``all_to_all`` moves S*H elements per shard instead of the full vector;
    the ELL column ids are remapped into [local_x ++ recv_buffer].
    """

    send_idx: np.ndarray      # (S, S, H) local indices on the sender
    cols_remap: np.ndarray    # (S, rows_pad, W) into the augmented buffer
    halo: int                 # H
    comm_elems_per_shard: int  # S * H (vs padded_length for all-gather)


def build_halo(dist: DistributedSpmv) -> HaloProgram:
    S = dist.plan.num_shards
    lay = dist.x_layout
    per = lay.padded_length() // S
    # Padded ELL slots (and stored explicit zeros) carry value 0 and point
    # at col 0; they contribute nothing to y, so they must not widen the
    # halo — otherwise every shard p != 0 appears to read global id 0 from
    # shard 0 and H (hence comm_elems_per_shard) is inflated.
    needed = [[None] * S for _ in range(S)]
    for p in range(S):
        cols_p = dist.cols[p].reshape(-1)
        act_p = dist.data[p].reshape(-1) != 0
        own_p = lay.owner_of(cols_p)
        for q in range(S):
            ids = np.unique(cols_p[act_p & (own_p == q)]) if q != p \
                else np.zeros(0, np.int64)
            needed[p][q] = ids
    H = max((ids.size for row in needed for ids in row), default=1)
    H = max(H, 1)
    send_idx = np.zeros((S, S, H), dtype=np.int32)
    # augmented-buffer position of each global id, per receiving shard p
    recv_pos = [dict() for _ in range(S)]
    for p in range(S):
        for q in range(S):
            ids = needed[p][q]
            send_idx[q, p, : ids.size] = lay.local_index(ids)
            base = per + q * H
            for slot, gid in enumerate(ids):
                recv_pos[p][int(gid)] = base + slot
    cols_remap = np.zeros_like(dist.cols)
    for p in range(S):
        cols_p = dist.cols[p]
        own_p = lay.owner_of(cols_p)
        local = lay.local_index(cols_p)
        remap = np.where(own_p == p, local, 0)
        # Zero-value slots keep remap 0: x_local[0] times value 0 is 0.
        rem_mask = (own_p != p) & (dist.data[p] != 0)
        if rem_mask.any():
            flat = cols_p[rem_mask]
            remap_rem = np.array([recv_pos[p][int(g)] for g in flat],
                                 dtype=np.int32)
            remap[rem_mask] = remap_rem
        cols_remap[p] = remap
    return HaloProgram(send_idx=send_idx, cols_remap=cols_remap, halo=H,
                       comm_elems_per_shard=S * H)


def make_halo_spmv_fn(dist: DistributedSpmv, halo: HaloProgram, mesh: Mesh,
                      axis: str = "model", *, use_kernel: bool = False,
                      interpret: bool = True):
    """f(data, cols_remap, send_idx, x_shards) -> b shards.

    Collective volume: S*H elements/shard (halo) vs padded_length
    (all-gather) — the ratio is exactly the paper's block-layout locality
    win, measured in ICI bytes.
    """
    spmv_local = partial(kops.ell_spmv, interpret=interpret) if use_kernel \
        else kops.ell_spmv_ref

    def shard_fn(data, cols, send_idx, x_shard):
        x_local = x_shard[0]                               # (per,)
        to_send = jnp.take(x_local, send_idx[0], axis=0)   # (S, H)
        recv = jax.lax.all_to_all(to_send, axis, split_axis=0,
                                  concat_axis=0, tiled=True)  # (S, H)
        x_aug = jnp.concatenate([x_local, recv.reshape(-1)])
        y = spmv_local(data[0], cols[0], x_aug)
        return y[None]

    fn = _shard_map_norep(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    return jax.jit(fn)
