"""Elastic restart: node failure -> smaller mesh -> re-shard -> continue.

The 1000+-node failure story this framework implements:

1. The launcher monitors per-step heartbeats (``train_loop``'s deadline
   hook).  A missed heartbeat or device error marks hosts dead.
2. ``shrink_mesh`` rebuilds the largest valid (data, model) mesh from the
   survivors — model-axis width is preserved (TP degree is a property of
   the checkpointed layout), the data axis absorbs the loss, and the
   global batch is kept by raising per-replica batch.
3. ``resume`` re-shards the latest checkpoint onto the new mesh (the
   checkpoint stores full logical arrays, so re-sharding is just a
   different ``device_put``) and training continues from the same step —
   the counter-based data pipeline replays the exact batch sequence.

Tested in tests/test_fault_tolerance.py by training on 8 fake devices,
"failing" half, and resuming on 4 with loss-curve continuity.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.loop import RunConfig, make_train_step, param_shardings

Tree = Any


def shrink_mesh(devices: Sequence[jax.Device], model_parallel: int,
                *, axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh from surviving devices; TP width fixed."""
    n = len(devices)
    if n < model_parallel:
        raise RuntimeError(
            f"only {n} devices survive; cannot keep TP={model_parallel}")
    data = n // model_parallel
    keep = data * model_parallel
    dev = np.asarray(devices[:keep]).reshape(data, model_parallel)
    return Mesh(dev, axis_names)


def resume(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, ckpt_dir: str,
           new_mesh: Mesh, run: RunConfig = RunConfig()):
    """Restore the latest checkpoint re-sharded for ``new_mesh``."""
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    from repro.models import params as pp
    abstract = {"params": pp.abstract_params(cfg), "opt": None}
    # Build abstract opt state from abstract params.
    abstract["opt"] = adamw.abstract_state(abstract["params"])
    p_shard = param_shardings(cfg, new_mesh, run)
    shardings = {"params": p_shard,
                 "opt": adamw.AdamWState(
                     step=jax.sharding.NamedSharding(
                         new_mesh, jax.sharding.PartitionSpec()),
                     m=p_shard, v=p_shard)}
    state, step = ckpt.restore(ckpt_dir, step, abstract, shardings)
    return state["params"], state["opt"], step
