"""Persistent program artifacts: save/load round-trips, digest + schema
fallback paths, and the frozen on-disk fixture bundle.

The contract under test (core/artifacts.py): a loaded bundle's
``execute()`` outputs are **bitwise** equal to the freshly lowered
program's for every kernel family and per-shard exchange mix, and every
way a bundle can be wrong — different matrix bytes, a schema bump, a torn
write — degrades to an :class:`ArtifactError` (the serving layer's signal
to fall back to a cold ``lower()``), never to silently wrong numerics.
"""
import json
import os

import numpy as np
import pytest

from repro.core import artifacts as art
from repro.core.plan import PlanChoice, RankedPlan, estimate_cost, \
    extract_features
from repro.core.program import execute, lower
from repro.core.sparse_matrix import CSRMatrix, csr_matvec
from repro.core.spmv import SpmvPlan
from repro.data.matrices import mixed_structure, powerlaw_tail

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


def _roundtrip(csr, plan, tmp_path):
    prog = lower(csr, plan)
    bundle = art.save_program(prog, str(tmp_path / "bundle"), source=csr)
    loaded, choice = art.load_program(bundle, expect=csr)
    return prog, loaded, choice


@pytest.mark.parametrize("kernel", ["ell", "seg", "hyb", "split", "tile"])
def test_roundtrip_bitwise_all_kernel_families(kernel, tmp_path):
    csr = mixed_structure(256, 6000, seed=1)
    plan = SpmvPlan(kernel=kernel, num_shards=4)
    prog, loaded, _ = _roundtrip(csr, plan, tmp_path)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(csr.ncols)
    X = rng.standard_normal((csr.ncols, 3))
    assert np.array_equal(execute(prog, x), execute(loaded, x))
    assert np.array_equal(execute(prog, X), execute(loaded, X))
    assert loaded.plan == prog.plan
    assert np.allclose(execute(loaded, x), csr_matvec(csr, x))


def test_roundtrip_mixed_shards_and_exchanges_with_reordering(tmp_path):
    """Per-shard heterogeneous kernels + exchanges + a bfs permutation —
    the artifact must carry the perm so caller-order I/O is preserved."""
    csr = powerlaw_tail(256, 6000, n_monster=2, seed=2)
    plan = SpmvPlan(kernel="seg", num_shards=4, reordering="bfs",
                    shard_kernels=("ell", "seg", "hyb", "split"),
                    shard_exchanges=("halo", "allgather", "halo",
                                     "allgather"),
                    split_counts=(1, 1, 1, 2))
    prog, loaded, _ = _roundtrip(csr, plan, tmp_path)
    assert loaded.perm is not None
    assert np.array_equal(loaded.perm, prog.perm)
    x = np.random.default_rng(3).standard_normal(csr.ncols)
    assert np.array_equal(execute(prog, x), execute(loaded, x))
    assert tuple(loaded.shard_kernels()) == ("ell", "seg", "hyb", "split")


def test_tile_slab_roundtrips_bitwise(tmp_path):
    """Tile stages persist the pointer grid + occupancy bitmask verbatim:
    the loaded TileMatrix must be field-for-field identical, on a mixed
    tile/split program over a block-structured matrix."""
    from repro.data.matrices import blocked_band
    csr = blocked_band(512, 215 * 512, seed=0)
    plan = SpmvPlan(kernel="tile", num_shards=4, exchange="halo",
                    shard_kernels=("tile", "tile", "split", "seg"))
    prog, loaded, _ = _roundtrip(csr, plan, tmp_path)
    assert sum(st.tile is not None for st in loaded.stages) == 2
    for st, lst in zip(prog.stages, loaded.stages):
        assert (st.tile is None) == (lst.tile is None)
        if st.tile is None:
            continue
        assert (lst.tile.shape == st.tile.shape and
                (lst.tile.bm, lst.tile.bn) == (st.tile.bm, st.tile.bn) and
                lst.tile.nnz == st.tile.nnz)
        for f in ("tile_ptr", "tile_rows", "tile_cols", "data", "mask"):
            a, b = getattr(st.tile, f), getattr(lst.tile, f)
            assert a.dtype == b.dtype and np.array_equal(a, b), f
    x = np.random.default_rng(12).standard_normal(csr.ncols)
    assert np.array_equal(execute(prog, x), execute(loaded, x))
    assert np.allclose(execute(loaded, x), csr_matvec(csr, x))


def test_reordered_save_requires_source():
    csr = mixed_structure(128, 2500, seed=4)
    prog = lower(csr, SpmvPlan(num_shards=4, reordering="bfs"))
    with pytest.raises(ValueError, match="source"):
        art.save_program(prog, "/nonexistent-never-written")


def test_choice_roundtrips_through_bundle(tmp_path):
    csr = mixed_structure(128, 2500, seed=5)
    plan = SpmvPlan(num_shards=4, kernel="hyb")
    choice = PlanChoice(
        features=extract_features(csr, num_shards=4),
        ranking=(RankedPlan(plan=plan, cost=estimate_cost(csr, plan)),),
        probed=0)
    prog = lower(csr, plan)
    bundle = art.save_program(prog, str(tmp_path / "b"), source=csr,
                              choice=choice)
    _, loaded_choice = art.load_program(bundle, expect=csr)
    assert loaded_choice == choice


def test_digest_mismatch_raises(tmp_path):
    """Same structure, different values: the digest must miss — a warm
    start may never serve stale numerics."""
    csr = mixed_structure(128, 2500, seed=6)
    prog = lower(csr, SpmvPlan(num_shards=4))
    bundle = art.save_program(prog, str(tmp_path / "b"), source=csr)
    revalued = CSRMatrix(shape=csr.shape, values=csr.values * 1.5,
                         col_index=csr.col_index, row_ptr=csr.row_ptr)
    with pytest.raises(art.ArtifactMismatch):
        art.load_program(bundle, expect=revalued)
    # ... while the original bytes still load.
    art.load_program(bundle, expect=csr)


def test_schema_version_bump_raises(tmp_path):
    csr = mixed_structure(128, 2500, seed=7)
    prog = lower(csr, SpmvPlan(num_shards=4))
    bundle = art.save_program(prog, str(tmp_path / "b"), source=csr)
    mpath = os.path.join(bundle, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["schema_version"] = art.SCHEMA_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(art.ArtifactMismatch):
        art.load_program(bundle, expect=csr)


def test_missing_or_invalidated_bundle_raises(tmp_path):
    with pytest.raises(art.ArtifactMissing):
        art.load_program(str(tmp_path / "nope"))
    csr = mixed_structure(128, 2500, seed=8)
    prog = lower(csr, SpmvPlan(num_shards=4))
    bundle = art.save_program(prog, str(tmp_path / "b"), source=csr)
    art.invalidate_bundle(bundle)     # the swap's atomic invalidation
    with pytest.raises(art.ArtifactMissing):
        art.load_program(bundle, expect=csr)


def test_torn_manifest_reads_as_missing(tmp_path):
    csr = mixed_structure(128, 2500, seed=9)
    prog = lower(csr, SpmvPlan(num_shards=4))
    bundle = art.save_program(prog, str(tmp_path / "b"), source=csr)
    with open(os.path.join(bundle, "manifest.json"), "w") as f:
        f.write('{"format": "spmv-program-bu')    # crash mid-write
    with pytest.raises(art.ArtifactMissing):
        art.load_program(bundle, expect=csr)


def test_frozen_fixture_bundle_still_loads():
    """The checked-in v1 bundle (mixed per-shard kernels + exchanges +
    bfs reordering) must keep loading as the format evolves — the
    on-disk analogue of the frozen PlanChoice JSON fixtures."""
    bundle = os.path.join(FIXTURES, "artifact_bundle_v1")
    src = mixed_structure(128, 2500, seed=3)    # the generating matrix
    prog, choice = art.load_program(bundle, expect=src)
    assert prog.plan.reordering == "bfs"
    assert tuple(prog.shard_kernels()) == ("ell", "seg", "hyb", "split")
    assert prog.plan.shard_exchanges == ("halo", "allgather", "halo",
                                         "allgather")
    assert choice is not None and choice.plan == prog.plan
    x = np.random.default_rng(11).standard_normal(src.ncols)
    assert np.allclose(execute(prog, x), csr_matvec(src, x))
    # and it is bitwise-equal to lowering the same plan today
    assert np.array_equal(execute(prog, x),
                          execute(lower(src, prog.plan), x))


def test_structure_digest_sensitivity():
    csr = mixed_structure(128, 2500, seed=10)
    d0 = art.structure_digest(csr)
    assert d0 == art.structure_digest(csr)
    v = csr.values.copy()
    v[0] += 1.0
    assert art.structure_digest(
        CSRMatrix(csr.shape, v, csr.col_index, csr.row_ptr)) != d0
