"""Online hot-spot detection and live re-planning for the SpMV serving path.

The paper's central finding is that distributing work well *once* is not
enough on a migratory-thread machine: sparsity makes threads converge on a
single nodelet over time, and only re-arranging the work restores balance
(§V, Figs. 7-8).  The serving engine had exactly that blind spot — a plan
autotuned at ingest and never revisited while request traffic shifts which
columns are hot.  This module closes the loop:

1. **Monitor** — :class:`LoadMonitor` accumulates per-column activity from
   every served request and folds it through a precomputed column→shard
   attribution map (:func:`~repro.core.migration.shard_load_map`), so each
   observation window costs one matvec, not a matrix walk.
2. **Detect** — the induced per-shard load CV is compared against an
   absolute threshold *and* the ingest-time baseline, with hysteresis
   (``patience`` consecutive hot windows to trip, ``cooldown`` windows of
   grace after a swap) so a single bursty window never thrashes the plan.
3. **Re-plan** — two tiers, cheapest first:

   * **Partial (hot shards only).** Since the per-shard program refactor
     the plan carries a kernel per shard, so the first response to a trip
     is local: re-derive the hot shards' kernels on the
     traffic-thinned structure (:func:`~repro.core.plan._active_submatrix`
     + the :class:`~repro.core.oracle.CostOracle` kernel table against
     the *deployed* partition), gate on the load-weighted kernel-slot
     cost improving by ``min_gain``, and rebuild **only the changed
     stages** (:func:`~repro.core.program.relower` shares every other
     stage with the incumbent program).  No grid, no probes, no full
     rebuild.
   * **Full.** When no hot-shard kernel change pays, :func:`replan`
     reruns the autotuner traffic-weighted (``autotune(...,
     col_weight=...)``) under a budget (restricted reordering grid, small
     Emu-probe count), then uses the cheap vectorized Emu engine as a
     *drift oracle*: both the incumbent and the candidate plan are
     simulated on the traffic-active submatrix, and the candidate must
     win by ``min_gain`` before it is considered.  If the winning base
     matches the incumbent's, the build still goes through ``relower``
     (per-shard double-buffered swap).
4. **Swap** — the candidate program is built double-buffered: in-flight
   ``spmv`` calls keep the old :class:`~repro.core.program.SpmvProgram`
   while the new one is constructed and validated against the exact CSR
   oracle (:func:`~repro.core.sparse_matrix.csr_matvec`) on sample
   vectors; only then does the engine swing its reference (a single
   attribute assignment) and re-attach the monitor.

This is the serving-layer analogue of the paper's reordering win: the
workload decides when the plan is re-derived, not the load-time snapshot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.emu import EmuConfig
from repro.core.migration import shard_load_map
from repro.core.partition import make_partition
from repro.core.oracle import DEFAULT_ORACLE as _oracle
from repro.core.plan import KERNELS, PlanChoice, RankedPlan, \
    _active_submatrix, _permute_weights, autotune
from repro.core.program import SpmvProgram, lower, relower
from repro.core.reorder import REORDERINGS, reordering_permutation
from repro.core.sparse_matrix import CSRMatrix, csr_matvec
from repro.core.spmv import PLAN_EXCHANGES, SpmvPlan, local_spmv

__all__ = ["RebalanceConfig", "RebalanceEvent", "LoadMonitor", "replan",
           "hot_shards", "probe_plan_seconds", "weighted_shard_load"]


def weighted_shard_load(dist: SpmvProgram,
                        w_caller: np.ndarray) -> np.ndarray:
    """(P,) expected per-shard load of one request on a built program.

    ``w_caller`` is per-column activity in the *caller's* index order; it
    is permuted into the program's (possibly reordered) order and folded
    through :func:`~repro.core.migration.shard_load_map`.  This is the
    single definition of the load-attribution formula — the monitor's
    cached fast path, the re-planner's post-swap CV, and the drift
    benchmark all compute exactly this.
    """
    lm, base = shard_load_map(dist.matrix, dist.partition, dist.x_layout,
                              dist.b_layout)
    w = _permute_weights(w_caller, dist.perm) if dist.perm is not None \
        else w_caller
    return lm @ w + base


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    """Knobs for the monitor → detect → re-plan → swap loop.

    The detector trips when the EMA-smoothed per-shard load CV exceeds
    ``max(cv_trigger, cv_ratio * baseline_cv)`` for ``patience``
    consecutive windows (the baseline is the same metric under uniform
    traffic on the currently-active plan), outside the post-swap
    ``cooldown``.  The re-plan budget is ``probe`` Emu-simulated bases
    over the ``reorderings`` sub-grid; a candidate must beat the incumbent
    by ``min_gain`` (relative, Emu-modeled seconds on the traffic-active
    submatrix) and reproduce :func:`~repro.core.sparse_matrix.csr_matvec`
    on ``validate_samples`` random vectors before it is swapped in.
    """

    window: int = 64
    ema: float = 0.5
    cv_trigger: float = 0.35
    cv_ratio: float = 1.5
    patience: int = 2
    cooldown: int = 4
    probe: int = 2
    reorderings: tuple = REORDERINGS
    min_gain: float = 0.02
    validate_samples: int = 2
    validate_atol: float = 1e-5   # fp32 slabs vs the float64 CSR oracle
    seed: int = 0
    #: A shard is *hot* when its traffic-weighted load exceeds
    #: ``hot_factor`` x the mean — the set the partial re-plan is allowed
    #: to re-kernel.
    hot_factor: float = 1.25
    #: Try the hot-shard-only kernel re-selection before the full
    #: traffic-weighted autotune (no grid, no probes, only the changed
    #: stages rebuilt).  Disable to force every trip through the full
    #: re-plan.
    partial_first: bool = True
    #: Run the re-plan on a daemon worker thread instead of inline in the
    #: request that closed the hot window.  Inline (the default) is
    #: deterministic — the swap has happened by the time ``spmv`` returns —
    #: but charges the full autotune + probe + build + validation to that
    #: one request; async keeps request latency flat and swaps when the
    #: worker finishes (requests served meanwhile use the old program).
    async_replan: bool = False
    #: Asudeh amortization gate (arXiv 2506.10356): project re-plan
    #: amortization over this many future *engine* requests — the router
    #: scales it by the tenant's observed traffic share into the
    #: ``amortization_horizon`` it hands :func:`replan`, and a swap only
    #: goes through when ``horizon * gain`` covers the swap's one-time
    #: cost in SpMV equivalents
    #: (:data:`~repro.core.oracle.REPLAN_SPMV_EQUIV`).  ``None`` (the
    #: default) keeps the legacy volume-blind gate: every swap that
    #: clears ``min_gain`` pays, regardless of traffic volume.
    amortization_lookahead: int | None = None


@dataclasses.dataclass
class RebalanceEvent:
    """One detector trip: what was measured, decided, and (maybe) swapped.

    ``mode`` records which re-plan tier produced the decision:
    ``"partial"`` (hot-shard kernel/exchange re-selection, only
    ``swapped_shards`` stages rebuilt) or ``"full"`` (budgeted
    traffic-weighted autotune).  ``exchange_flips`` lists the shards whose
    exchange policy changed — those need no stage rebuild at all, only
    the device-operand cache (exchange is not a lowering-base field).
    """

    request_index: int
    window_index: int
    old_plan: SpmvPlan
    new_plan: SpmvPlan | None
    load_cv_before: float
    load_cv_after: float | None
    probe_old_seconds: float | None
    probe_new_seconds: float | None
    swapped: bool
    reason: str
    mode: str = "full"
    swapped_shards: tuple = ()
    exchange_flips: tuple = ()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["old_plan"] = dataclasses.asdict(self.old_plan)
        d["new_plan"] = None if self.new_plan is None else \
            dataclasses.asdict(self.new_plan)
        return d


class LoadMonitor:
    """Per-shard load watcher for one ingested matrix.

    ``observe(x)`` is called on every served request with the request
    vector/block (caller index order).  Activity is |x| accumulated per
    column; when ``cfg.window`` requests have been seen the window closes:
    the window's mean activity is normalized to mean 1 (so uniform dense
    traffic reproduces the static instruction counts), EMA-folded into the
    running estimate, and pushed through the active plan's column→shard
    load map.  ``observe`` returns ``True`` when the hysteresis logic says
    the engine should attempt a re-plan *now*.
    """

    def __init__(self, dist: SpmvProgram, cfg: RebalanceConfig):
        self.cfg = cfg
        self._ncols = dist.matrix.ncols
        self._act_sum = np.zeros(self._ncols, dtype=np.float64)
        self._requests_in_window = 0
        self._act_ema: np.ndarray | None = None
        self._hot_streak = 0
        self._cooldown_left = 0
        self.requests_seen = 0
        self.windows_closed = 0
        self.last_cv = 0.0
        self.trips = 0
        self.attach(dist)

    def attach(self, dist: SpmvProgram) -> None:
        """(Re)bind to the active program; called again after every swap.

        The (load_map, base, perm) triple is swapped in as **one**
        attribute assignment so a concurrent ``observe`` (async re-plan
        worker swapping while request threads serve) never computes a
        load with the new map but the old permutation.
        """
        lm, base = shard_load_map(dist.matrix, dist.partition, dist.x_layout,
                                  dist.b_layout)
        self._bound = (lm, base, dist.perm)
        self.baseline_cv = _cv(lm @ np.ones(self._ncols) + base)
        self.last_cv = self.baseline_cv
        self._hot_streak = 0

    # -- per-request path ---------------------------------------------------

    def observe(self, x: np.ndarray) -> bool:
        """Fold one request (or (N, B) block) in; True => attempt re-plan."""
        a = np.abs(np.asarray(x, dtype=np.float64))
        if a.ndim == 2:
            self._act_sum += a.sum(axis=1)
            self.requests_seen += a.shape[1]
            self._requests_in_window += a.shape[1]
        else:
            self._act_sum += a
            self.requests_seen += 1
            self._requests_in_window += 1
        if self._requests_in_window < self.cfg.window:
            return False
        return self._close_window()

    def _close_window(self) -> bool:
        w = self._act_sum / max(self._requests_in_window, 1)
        mean = w.mean()
        w = w / mean if mean > 0 else np.ones_like(w)
        self._act_sum = np.zeros(self._ncols, dtype=np.float64)
        self._requests_in_window = 0
        self.windows_closed += 1

        e = self.cfg.ema
        self._act_ema = w if self._act_ema is None else \
            e * self._act_ema + (1.0 - e) * w
        # Detection runs on the *instantaneous* window CV — ``patience``
        # then genuinely means "this many consecutive hot windows", and a
        # single burst cannot bleed into the streak through the EMA.  The
        # EMA (reported as last_cv, and handed to the re-planner) smooths
        # the weights the new plan is derived from.
        window_cv = _cv(self._shard_load_for(w))
        self.last_cv = _cv(self.shard_load())

        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self._hot_streak = 0
            return False
        threshold = max(self.cfg.cv_trigger,
                        self.cfg.cv_ratio * self.baseline_cv)
        if window_cv > threshold:
            self._hot_streak += 1
        else:
            self._hot_streak = 0
        if self._hot_streak >= self.cfg.patience:
            self._hot_streak = 0
            self.trips += 1
            return True
        return False

    # -- read-side ----------------------------------------------------------

    def activity(self) -> np.ndarray:
        """Current EMA per-column activity (caller order, mean 1)."""
        if self._act_ema is None:
            return np.ones(self._ncols, dtype=np.float64)
        return self._act_ema

    def shard_load(self) -> np.ndarray:
        """(P,) expected per-shard load of one request under current traffic.

        The activity estimate lives in caller index order; the active
        program may be reordered, so the weights are permuted into the
        program's order before hitting the load map.
        """
        return self._shard_load_for(self.activity())

    def _shard_load_for(self, w_caller: np.ndarray) -> np.ndarray:
        # Cached-map fast path of :func:`weighted_shard_load` (one window
        # = one matvec); the triple is read in one statement for the same
        # atomicity reason attach() writes it in one.
        lm, base, perm = self._bound
        w = _permute_weights(w_caller, perm) if perm is not None else w_caller
        return lm @ w + base

    def cooldown(self) -> None:
        """Start the post-swap (or post-rejected-replan) grace period."""
        self._cooldown_left = self.cfg.cooldown
        self._hot_streak = 0

    def stats(self) -> dict:
        return {"requests_seen": self.requests_seen,
                "windows_closed": self.windows_closed,
                "baseline_cv": round(self.baseline_cv, 6),
                "last_cv": round(self.last_cv, 6),
                "trips": self.trips}


def _cv(v: np.ndarray) -> float:
    mu = v.mean()
    return float(v.std() / mu) if mu else 0.0


def probe_plan_seconds(csr: CSRMatrix, plan: SpmvPlan,
                       col_weight: np.ndarray,
                       emu: EmuConfig | None = None) -> float:
    """Emu-modeled seconds for one SpMV of ``plan`` under observed traffic.

    The drift oracle: the matrix is reordered per the plan, restricted to
    the traffic-active columns
    (:func:`~repro.core.plan._active_submatrix`), and run through the
    vectorized Emu timeline engine with the plan's partition/layout — a
    millisecond-cheap measurement of how the *deployed* program handles
    the traffic the monitor actually saw.  The probe goes through
    :meth:`~repro.core.oracle.CostOracle.probe` with the plan's per-shard
    kernels, so the tick machine replays each shard's *format-shaped*
    instruction stream (seg carry chains, hyb overflow scatter, split
    combine) — kernel differences now show up in measured seconds instead
    of being invisible to the probe.
    """
    emu = emu or EmuConfig(nodelets=plan.num_shards)
    # Thin once in caller order (identical entry set for every plan being
    # compared), then permute the thinned matrix alongside the plan.
    sub = _active_submatrix(csr, np.asarray(col_weight, np.float64))
    perm = reordering_permutation(csr, plan.reordering, seed=plan.seed,
                                  parts=plan.num_shards)
    if plan.reordering == "none":
        A, sub_r = csr, sub
    else:
        A = csr.permuted(perm, perm)
        sub_r = sub.permuted(perm, perm)
    # The partition is the deployed one: cut on the full matrix, probed on
    # the traffic it actually serves.
    part = make_partition(A, plan.num_shards, plan.distribution)
    res = _oracle.probe(sub_r, part, plan, emu=emu)
    return float(res.seconds)


def hot_shards(load: np.ndarray, factor: float) -> np.ndarray:
    """Shards whose load exceeds ``factor`` x the mean (the partial
    re-plan's working set)."""
    mu = load.mean()
    if mu <= 0:
        return np.zeros(0, dtype=np.int64)
    return np.flatnonzero(load > factor * mu)


def _validated(dist: SpmvProgram, csr: CSRMatrix, cfg: RebalanceConfig,
               request_index: int) -> bool:
    """Candidate program reproduces the exact CSR oracle on sample vectors."""
    rng = np.random.default_rng(cfg.seed + request_index)
    for _ in range(cfg.validate_samples):
        xs = rng.standard_normal(csr.ncols)
        if not np.allclose(local_spmv(dist, xs), csr_matvec(csr, xs),
                           atol=cfg.validate_atol, rtol=1e-5):
            return False
    return True


def _try_partial_replan(csr: CSRMatrix, monitor: LoadMonitor,
                        current: PlanChoice, program: SpmvProgram,
                        w: np.ndarray, cfg: RebalanceConfig,
                        request_index: int,
                        amortization_horizon: float | None = None):
    """Hot-shard-only kernel/exchange re-selection; None when inapplicable.

    Two independent axes, each with its own gate:

    * **Kernel.**  The hot shards' kernels are re-derived from the
      *traffic-thinned* structure (:func:`~repro.core.plan._active_submatrix`
      permuted into the deployed program's order) against the **deployed**
      partition — the format each hot shard would want for the entries the
      request stream actually touches.  The gate is the load-weighted
      kernel-slot cost (sum over shards of ``load_p * cost[kernel_p][p]``)
      improving by ``cfg.min_gain``; the Emu drift oracle cannot see
      kernels, so the analytic table is the authoritative metric here.
      The candidate grid is the full :data:`~repro.core.plan.KERNELS` —
      including the split-nnz two-stage ``split`` family, so a shard that
      drifted onto a monster-row hot-spot can be swapped onto split
      partials without a full re-plan (the split count re-derives from
      :func:`~repro.core.plan.split_meta` at relower time), and the
      bitmask-tiled ``tile`` family, so a shard whose hot traffic
      concentrates on a banded/blocked substructure swaps onto dense
      tile streams the same way.  Exact cost ties break by the shard's
      bottleneck class
      (:meth:`~repro.core.oracle.CostOracle.kernel_affinity`).  ``split`` is
      only offered to a hot shard when the *thinned* structure still has
      a row spanning at least ``SPLIT_MIN_SPAN`` seg chunks
      (:meth:`~repro.core.oracle.CostOracle.split_span_ok`): heavy
      thinning of a mildly-skewed stream can shorten a monster row below
      the span floor, and a split chosen on that table would deploy a
      pure-overhead stage 2 against the real matrix.
    * **Exchange.**  The hot shards' exchange policies are re-derived the
      same way from the oracle's exchange table on the
      thinned structure, gated on the load-weighted exchange cost
      improving by ``cfg.min_gain``.  A flip rebuilds **no** stages at
      all — exchange is not a lowering-base field, so ``relower`` shares
      every stage and only the device-operand cache is re-derived.

    An axis whose gate fails is reverted; the partial tier applies
    whichever axes survive (``None`` when neither does).  Only the
    kernel-changed stages are rebuilt (:func:`~repro.core.program.relower`)
    and the candidate must still reproduce ``csr_matvec`` before the swap.
    """
    old_plan = current.plan
    if old_plan.num_shards != program.plan.num_shards:
        return None
    load = monitor.shard_load()
    hot = hot_shards(load, cfg.hot_factor)
    if hot.size == 0 or hot.size >= load.size:
        return None
    sub = _active_submatrix(csr, w, seed=cfg.seed)
    if sub is csr:
        return None                       # uniform traffic: nothing local
    sub_r = sub if program.perm is None else \
        sub.permuted(program.perm, program.perm)

    # -- kernel axis --------------------------------------------------------
    costs = _oracle.kernel_costs(sub_r, program.partition)
    old_k = old_plan.resolved_shard_kernels()
    new_k = list(old_k)
    sbn = current.shard_bottlenecks
    for p in hot:
        # Ties break by the hot shard's bottleneck-class affinity (a
        # bandwidth-bound shard leans tile/ell streaming, an
        # imbalance-bound one split/seg) — order only, never a flip of a
        # strict cost winner.
        order = KERNELS if sbn is None else \
            _oracle.kernel_affinity(sbn[p])
        kerns = order if _oracle.split_span_ok(sub_r, program.partition,
                                               int(p)) \
            else tuple(k for k in order if k != "split")
        new_k[p] = min(kerns, key=lambda k: (costs[k][p],
                                             kerns.index(k)))
    kernel_ok = tuple(new_k) != tuple(old_k)
    if kernel_ok:
        old_c = float(sum(load[p] * costs[k][p]
                          for p, k in enumerate(old_k)))
        new_c = float(sum(load[p] * costs[k][p]
                          for p, k in enumerate(new_k)))
        if not new_c < (1.0 - cfg.min_gain) * max(old_c, 1e-30):
            kernel_ok = False
    if not kernel_ok:
        new_k = list(old_k)

    # -- exchange axis ------------------------------------------------------
    ex_costs = _oracle.exchange_costs(sub_r, program.partition,
                                      layout=old_plan.layout)
    old_e = old_plan.resolved_shard_exchanges()
    new_e = list(old_e)
    for p in hot:
        new_e[p] = min(PLAN_EXCHANGES,
                       key=lambda e: (ex_costs[e][p],
                                      PLAN_EXCHANGES.index(e)))
    ex_ok = tuple(new_e) != tuple(old_e)
    if ex_ok:
        old_ec = float(sum(load[p] * ex_costs[e][p]
                           for p, e in enumerate(old_e)))
        new_ec = float(sum(load[p] * ex_costs[e][p]
                           for p, e in enumerate(new_e)))
        if not new_ec < (1.0 - cfg.min_gain) * max(old_ec, 1e-30):
            ex_ok = False
    if not ex_ok:
        new_e = list(old_e)

    if not (kernel_ok or ex_ok):
        return None

    # Asudeh amortization gate: even a relower-only swap has a one-time
    # cost; at low projected volume it never pays back.
    num = den = 0.0
    if kernel_ok:
        num += old_c - new_c
        den += old_c
    if ex_ok:
        num += old_ec - new_ec
        den += old_ec
    gain = num / max(den, 1e-30)
    if not _oracle.replan_pays(gain, amortization_horizon,
                               mode="partial").pays:
        return None                       # fall through to the full tier

    new_plan = old_plan
    if kernel_ok:
        new_plan = dataclasses.replace(new_plan, shard_kernels=tuple(new_k))
    if ex_ok:
        if len(set(new_e)) == 1:          # flips converged on one policy
            new_plan = dataclasses.replace(new_plan, exchange=new_e[0],
                                           shard_exchanges=None)
        else:
            new_plan = dataclasses.replace(new_plan,
                                           shard_exchanges=tuple(new_e))

    dist = relower(program, new_plan)
    if not _validated(dist, csr, cfg, request_index):
        return None                       # fall through to the full tier
    changed = tuple(int(p) for p in range(len(old_k))
                    if new_k[p] != old_k[p])
    flips = tuple(int(p) for p in range(len(old_e))
                  if new_e[p] != old_e[p])
    choice = PlanChoice(
        features=current.features,
        ranking=(RankedPlan(plan=new_plan,
                            cost=_oracle.plan_cost(csr, new_plan)),),
        probed=0, shard_features=current.shard_features,
        bottleneck=current.bottleneck,
        shard_bottlenecks=current.shard_bottlenecks)
    parts = []
    if kernel_ok:
        parts.append(
            f"re-lowered hot shard(s) {list(changed)} "
            f"({'/'.join(old_k[p] for p in changed)} -> "
            f"{'/'.join(new_k[p] for p in changed)}), weighted kernel cost "
            f"{(1.0 - new_c / max(old_c, 1e-30)):.1%} down")
    if ex_ok:
        parts.append(
            f"flipped exchange on shard(s) {list(flips)} "
            f"({'/'.join(old_e[p] for p in flips)} -> "
            f"{'/'.join(new_e[p] for p in flips)}), weighted exchange cost "
            f"{(1.0 - new_ec / max(old_ec, 1e-30)):.1%} down")
    event = RebalanceEvent(
        request_index=request_index, window_index=monitor.windows_closed,
        old_plan=old_plan, new_plan=new_plan,
        load_cv_before=monitor.last_cv,
        load_cv_after=_cv(weighted_shard_load(dist, w)),
        probe_old_seconds=None, probe_new_seconds=None,
        swapped=True, mode="partial", swapped_shards=changed,
        exchange_flips=flips,
        reason="partial: " + "; ".join(parts))
    return dist, choice, event


def replan(csr: CSRMatrix, monitor: LoadMonitor, current: PlanChoice, *,
           num_shards: int, seed: int, cfg: RebalanceConfig,
           request_index: int, program: SpmvProgram | None = None,
           amortization_horizon: float | None = None
           ) -> tuple[SpmvProgram | None, PlanChoice | None,
                      RebalanceEvent]:
    """Budgeted traffic-weighted re-plan with oracle gate + validated build.

    Two tiers.  With ``cfg.partial_first`` and the deployed ``program``
    supplied, the hot-shard-only kernel re-selection
    (:func:`_try_partial_replan`) runs first — when it pays, only the hot
    shards' stages are rebuilt and swapped.  Otherwise the full budgeted
    autotune runs (traffic-weighted grid + Emu drift oracle); when its
    winner shares the incumbent's base the build still goes through
    :func:`~repro.core.program.relower`, so even full re-plans reuse every
    unchanged stage.

    ``amortization_horizon`` (projected SpMVs the tenant will issue
    against the new plan; the router derives it from per-tenant traffic
    stats and ``cfg.amortization_lookahead``) arms the Asudeh gate: each
    tier's swap must additionally satisfy
    :meth:`~repro.core.oracle.CostOracle.replan_pays` — a positive-gain
    swap a volume-blind model would take is refused when the projected
    volume cannot amortize its one-time cost.  ``None`` (the default)
    keeps the legacy volume-blind behavior.

    Returns ``(new_dist, new_choice, event)``; the first two are ``None``
    when the re-plan was rejected (plan unchanged, no modeled gain, or
    validation failure) — the caller keeps serving the old program either
    way, which is what makes the swap double-buffered.
    """
    w = monitor.activity()
    cv_before = monitor.last_cv

    if cfg.partial_first and program is not None:
        partial = _try_partial_replan(csr, monitor, current, program, w,
                                      cfg, request_index,
                                      amortization_horizon)
        if partial is not None:
            return partial

    choice = autotune(csr, num_shards=num_shards, seed=seed,
                      probe=cfg.probe, reorderings=cfg.reorderings,
                      col_weight=w)
    new_plan = choice.plan
    old_plan = current.plan

    def rejected(reason: str, old_s=None, new_s=None) -> tuple:
        return None, None, RebalanceEvent(
            request_index=request_index, window_index=monitor.windows_closed,
            old_plan=old_plan, new_plan=new_plan,
            load_cv_before=cv_before, load_cv_after=None,
            probe_old_seconds=old_s, probe_new_seconds=new_s,
            swapped=False, reason=reason)

    if new_plan == old_plan:
        return rejected("re-plan chose the incumbent plan")

    old_s = probe_plan_seconds(csr, old_plan, w)
    new_s = probe_plan_seconds(csr, new_plan, w)
    # Exchange is deliberately NOT a base field: flipping it re-lowers
    # cheaply (every stage shared, only device operands rebuilt), so a
    # kernel- or exchange-only winner goes through relower below.
    same_base = all(getattr(new_plan, f) == getattr(old_plan, f)
                    for f in ("layout", "distribution", "reordering",
                              "num_shards", "seed"))
    if same_base:
        # The format-aware Emu probe can separate same-base candidates
        # too, but the traffic-weighted analytic model stays the
        # authoritative same-base gate (cheaper, and pinned by the
        # frozen-fixture suite); the probe gates across bases.
        old_t = _oracle.plan_cost(csr, old_plan, col_weight=w).total
        new_t = _oracle.plan_cost(csr, new_plan, col_weight=w).total
        if new_t > (1.0 - cfg.min_gain) * old_t:
            return rejected("analytic model: no modeled gain over incumbent "
                            "(same base)", old_s, new_s)
        gain = 1.0 - new_t / max(old_t, 1e-30)
    elif new_s > (1.0 - cfg.min_gain) * old_s:
        return rejected("drift oracle: no modeled gain over incumbent",
                        old_s, new_s)
    else:
        gain = 1.0 - new_s / max(old_s, 1e-30)

    decision = _oracle.replan_pays(gain, amortization_horizon, mode="full")
    if not decision.pays:
        return rejected(
            f"amortization gate: modeled gain {gain:.1%} needs "
            f"{decision.break_even_spmvs:.0f} SpMVs to pay off, but the "
            f"projected horizon is {amortization_horizon:.0f}",
            old_s, new_s)

    # Double-buffered build: the old program keeps serving until the new
    # one exists and reproduces the exact CSR oracle.  Same-base winners
    # re-lower only the stages whose kernel changed.
    if same_base and program is not None:
        dist = relower(program, new_plan)
    else:
        dist = lower(csr, new_plan)
    if not _validated(dist, csr, cfg, request_index):
        return rejected("validation failed: candidate program does not "
                        "reproduce csr_matvec", old_s, new_s)

    old_k = old_plan.resolved_shard_kernels()
    new_k = new_plan.resolved_shard_kernels()
    changed = tuple(int(p) for p in range(num_shards)
                    if p >= len(old_k) or new_k[p] != old_k[p]) \
        if same_base else tuple(range(num_shards))
    old_e = old_plan.resolved_shard_exchanges()
    new_e = new_plan.resolved_shard_exchanges()
    flips = tuple(int(p) for p in range(num_shards)
                  if p >= len(old_e) or new_e[p] != old_e[p])
    cv_after = _cv(weighted_shard_load(dist, w))
    event = RebalanceEvent(
        request_index=request_index, window_index=monitor.windows_closed,
        old_plan=old_plan, new_plan=new_plan,
        load_cv_before=cv_before, load_cv_after=cv_after,
        probe_old_seconds=old_s, probe_new_seconds=new_s,
        swapped=True, mode="full", swapped_shards=changed,
        exchange_flips=flips,
        reason="swapped: modeled gain "
        f"{(1.0 - new_s / max(old_s, 1e-30)):.1%}")
    return dist, choice, event
