"""Kernel micro-bench: Pallas-oracle parity cost on CPU (interpret mode is
a correctness vehicle; real perf numbers come from the TPU dry-run).
Reports us/call of the jnp oracle paths that the models actually execute."""
import jax.numpy as jnp
import numpy as np
from repro.core.sparse_matrix import csr_from_coo, csr_to_ell
from repro.data.matrices import blocked_band, powerlaw, powerlaw_tail
from repro.kernels import ops
from .common import emit, us


def run():
    rng = np.random.default_rng(0)
    rows = []
    for M, N, nnz in ((512, 512, 8000), (2048, 2048, 40000)):
        A = csr_from_coo(rng.integers(0, M, nnz), rng.integers(0, N, nnz),
                         rng.standard_normal(nnz), (M, N))
        x = jnp.asarray(rng.standard_normal(N), jnp.float32)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data), jnp.asarray(e.cols)
        t = us(lambda: ops.ell_spmv_ref(data, cols, x).block_until_ready())
        rows.append((f"ell_ref/{M}x{N}/nnz{nnz}", round(t, 1),
                     f"pad={e.padding_ratio:.2f}"))
        tm = ops.tile_from_csr(A)
        t = us(lambda: ops.tile_spmv(tm, x).block_until_ready())
        rows.append((f"tile_ref/{M}x{N}/nnz{nnz}", round(t, 1),
                     f"tiles={tm.num_tiles};fill={tm.fill_ratio:.2f}"))
        # Segmented (nonzero-balanced) family: oracle path timing on the
        # uniform matrix above plus a skewed power-law one, where the
        # row-tiled ELL slab pays max-row-nnz padding and the seg slab
        # stays at ~chunk granularity (see the pad/chunks column).
        seg = ops.seg_from_csr(A)
        t = us(lambda: ops.seg_spmv(seg, x).block_until_ready())
        rows.append((f"seg_ref/{M}x{N}/nnz{nnz}", round(t, 1),
                     f"chunks={seg.num_chunks};pieces={seg.n_pieces};"
                     f"pad={seg.padding_ratio:.2f}"))
    P = powerlaw(2048, 40_000, seed=0)
    xp = jnp.asarray(rng.standard_normal(P.ncols), jnp.float32)
    e = csr_to_ell(P)
    data, cols = jnp.asarray(e.data), jnp.asarray(e.cols)
    t = us(lambda: ops.ell_spmv_ref(data, cols, xp).block_until_ready())
    rows.append((f"ell_ref/powerlaw2048/nnz{P.nnz}", round(t, 1),
                 f"pad={e.padding_ratio:.2f}"))
    seg = ops.seg_from_csr(P)
    t = us(lambda: ops.seg_spmv(seg, xp).block_until_ready())
    rows.append((f"seg_ref/powerlaw2048/nnz{P.nnz}", round(t, 1),
                 f"chunks={seg.num_chunks};pieces={seg.n_pieces};"
                 f"pad={seg.padding_ratio:.2f}"))
    # Split-nnz (two-stage) family: the seg slab with each row's carry
    # chain cut across num_splits partial accumulators.  Timed on the
    # same power-law matrix and on a monster-row matrix (a handful of
    # fully dense rows — the §IV-D hot spot the family exists for),
    # oracle path and Pallas-interpret kernel path.
    for name, Q in (("powerlaw2048", P),
                    ("monster2048", powerlaw_tail(2048, 2 * 4 * 2048,
                                                  n_monster=4, seed=0))):
        xq = jnp.asarray(rng.standard_normal(Q.ncols), jnp.float32)
        for ns in (2, 8):
            spl = ops.split_from_csr(Q, ns)
            t = us(lambda: ops.split_spmv(spl, xq).block_until_ready())
            rows.append((f"split_ref/{name}/nnz{Q.nnz}/ns{spl.num_splits}",
                         round(t, 1),
                         f"chunks={spl.chunks_per_split};"
                         f"pieces={spl.n_pieces};"
                         f"pad={spl.padding_ratio:.2f}"))
        spl = ops.split_from_csr(Q, 8)
        t = us(lambda: ops.split_spmv(spl, xq, use_kernel=True,
                                      interpret=True).block_until_ready())
        rows.append((f"split_pallas/{name}/nnz{Q.nnz}/ns{spl.num_splits}",
                     round(t, 1), "interpret=True"))
    # Bitmask-tiled family: its win case is block-structured data (dense
    # (8, 128) tiles, fill -> 1.0); the scattered powerlaw row above it
    # shows the loss case (fill -> 0, every tile mostly padding).  Oracle
    # path on both, Pallas scalar-prefetch walk (interpret) on the win.
    B = blocked_band(2048, 215 * 2048, seed=0)
    xb = jnp.asarray(rng.standard_normal(B.ncols), jnp.float32)
    for name, Q, xq in (("blocked2048", B, xb), ("powerlaw2048", P, xp)):
        tm = ops.tile_from_csr(Q)
        t = us(lambda: ops.tile_spmv(tm, xq).block_until_ready())
        rows.append((f"tile_ref/{name}/nnz{Q.nnz}", round(t, 1),
                     f"tiles={tm.num_tiles};fill={tm.fill_ratio:.2f}"))
    tm = ops.tile_from_csr(B)
    t = us(lambda: ops.tile_spmv(tm, xb, use_kernel=True,
                                 interpret=True).block_until_ready())
    rows.append((f"tile_pallas/blocked2048/nnz{B.nnz}", round(t, 1),
                 "interpret=True"))
    emit(rows, ("name", "us_per_call", "derived"))


if __name__ == "__main__":
    run()
