"""Batched serving engine: prefill + decode over the distributed runtime,
plus the sparse-matrix serving path (:class:`SparseMatrixEngine`).

Small-scale runnable on CPU (examples/serve_lm.py); the same step functions
lower on the production mesh for the dry-run's decode cells.  The sparse
engine autotunes an :class:`~repro.core.spmv.SpmvPlan` for every ingested
matrix at load time (``core/plan.py``), serves single-vector and
multi-RHS-batched SpMV requests through the plan-built slabs, and — when
rebalancing is enabled — watches the live request mix for sustained
hot-spots and re-plans online (``serve/rebalance.py``), so no caller ever
picks layouts/kernels by hand, not even after the workload drifts.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PlanChoice, autotune, feature_key
from repro.core.sparse_matrix import CSRMatrix
from repro.core.spmv import DistributedSpmv, SpmvPlan, build_distributed, \
    local_spmv
from repro.models import model as mm
from repro.models.config import ModelConfig
from repro.serve.rebalance import LoadMonitor, RebalanceConfig, \
    RebalanceEvent, replan


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0      # 0 = greedy


@dataclasses.dataclass
class IngestedMatrix:
    """One served matrix: its autotuned choice + device-ready program.

    ``csr`` keeps the original (caller-order) matrix so the rebalancer can
    re-derive plans against it; ``monitor``/``rebalance_log`` exist only
    when the engine was built with rebalancing enabled.  ``plan_cache_hit``
    records that ingest skipped the autotune grid via the feature-keyed
    plan cache.
    """

    name: str
    choice: PlanChoice
    dist: DistributedSpmv
    # Original caller-order matrix, kept only when rebalancing is enabled
    # (the re-planner re-derives plans from it); None otherwise so a
    # plain serving engine doesn't pin a second copy of every matrix.
    csr: CSRMatrix | None = None
    spmv_count: int = 0
    plan_cache_hit: bool = False
    monitor: LoadMonitor | None = None
    rebalance_log: List[RebalanceEvent] = dataclasses.field(
        default_factory=list)
    replan_thread: threading.Thread | None = None
    replan_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock)


class SparseMatrixEngine:
    """Serving front-end for SpMV: ingest once, autotune, serve many.

    ``ingest`` runs the cost-model autotuner (with Emu-simulator probe
    re-ranking by default — the vectorized tick engine makes a probe cost
    milliseconds, so serving ingestion gets measured rankings, not just
    analytic ones; pass ``probe=0`` to opt out) and builds the
    distributed program for the winning plan;
    ``spmv`` answers y = A @ x requests — ``x`` either a single (N,)
    vector or a multi-RHS block (N, B) — in the caller's original index
    order via the plan's slabs.  ``plans()`` exposes every decision as
    JSON (the :class:`~repro.core.plan.PlanChoice` round-trips), so an
    operator can audit *why* a matrix got its layout/kernel.

    Two serving-scale behaviours are new since the drift-aware PR:

    * **Feature-keyed plan cache** (on by default): structurally similar
      re-ingests (same :func:`~repro.core.plan.feature_key`) reuse the
      previously autotuned plan instead of re-running the grid.
    * **Online rebalancing** (opt-in via ``rebalance=``): every request
      feeds a :class:`~repro.serve.rebalance.LoadMonitor`; sustained
      hot-spots trigger a budgeted traffic-weighted re-plan whose program
      is built and validated double-buffered before the swap
      (``serve/rebalance.py`` has the full story).
    """

    def __init__(self, *, num_shards: int = 8, probe: int | None = None,
                 seed: int = 0,
                 rebalance: RebalanceConfig | bool | None = None,
                 plan_cache: bool = True):
        self.num_shards = num_shards
        self.probe = probe
        self.seed = seed
        if rebalance is True:
            rebalance = RebalanceConfig()
        self.rebalance_cfg: RebalanceConfig | None = rebalance or None
        self._matrices: Dict[str, IngestedMatrix] = {}
        self._plan_cache: Dict[tuple, SpmvPlan] | None = \
            {} if plan_cache else None
        self.plan_cache_hits = 0

    def ingest(self, name: str, csr: CSRMatrix,
               plan: SpmvPlan | None = None) -> PlanChoice:
        """Register ``csr`` under ``name`` with a load-time-tuned plan.

        Pass an explicit ``plan`` to bypass the autotuner (the choice is
        then recorded as a single-candidate ranking with its model cost).
        The engine's shard count is authoritative: an explicit plan is
        re-targeted to ``self.num_shards`` so the built program, its cost,
        and the recorded features all describe the same deployment.
        Re-ingesting a name replaces the previous matrix.

        When the plan cache is enabled and a structurally similar matrix
        (equal :func:`~repro.core.plan.feature_key`) was autotuned before,
        the cached plan is reused as a single-candidate choice — the full
        grid + probe is skipped, which is what makes re-ingesting many
        look-alike matrices cheap.
        """
        from repro.core.plan import estimate_cost, RankedPlan, \
            extract_features
        features = extract_features(csr, num_shards=self.num_shards)
        cache_key = (feature_key(features), self.num_shards)
        cache_hit = False
        if plan is None and self._plan_cache is not None and \
                cache_key in self._plan_cache:
            plan = self._plan_cache[cache_key]
            cache_hit = True
            self.plan_cache_hits += 1
        if plan is None:
            choice = autotune(csr, num_shards=self.num_shards,
                              seed=self.seed, probe=self.probe)
            if self._plan_cache is not None:
                self._plan_cache[cache_key] = choice.plan
        else:
            # retarget (not replace): a per-shard kernel tuple tuned for a
            # different shard count is dropped rather than kept unlowerable.
            plan = plan.retarget(self.num_shards)
            choice = PlanChoice(
                features=features,
                ranking=(RankedPlan(plan=plan,
                                    cost=estimate_cost(csr, plan)),),
                probed=0)
        dist = build_distributed(csr, choice.plan)
        monitor = LoadMonitor(dist, self.rebalance_cfg) \
            if self.rebalance_cfg is not None else None
        self._matrices[name] = IngestedMatrix(
            name=name, choice=choice, dist=dist,
            csr=csr if monitor is not None else None,
            plan_cache_hit=cache_hit, monitor=monitor)
        return choice

    def _lookup(self, name: str) -> IngestedMatrix:
        m = self._matrices.get(name)
        if m is None:
            raise KeyError(
                f"no matrix ingested under {name!r}; ingested names: "
                f"{sorted(self._matrices) or '(none)'} — call "
                f"engine.ingest({name!r}, csr) first")
        return m

    def spmv(self, name: str, x: np.ndarray) -> np.ndarray:
        """y = A @ x for the ingested matrix ``name`` (original order).

        ``x``: (N,) or multi-RHS (N, B) → (M,) or (M, B); batched columns
        are bitwise-equal to per-vector calls.  Unknown names raise an
        actionable :class:`KeyError` *before* any stats are touched, so
        ``stats()`` counts successful calls only.
        """
        m = self._lookup(name)
        y = local_spmv(m.dist, x)
        m.spmv_count += 1
        if m.monitor is not None and m.monitor.observe(x):
            self._try_rebalance(m)
        return y

    def _try_rebalance(self, m: IngestedMatrix) -> None:
        """Detector tripped: budgeted re-plan, validated double-buffered swap.

        Callers keep reading ``m.dist`` (the old program) until the
        candidate is built and validated; the swap itself is one attribute
        rebind (atomic under the GIL).  Rejected candidates only start the
        monitor's cooldown — serving never degrades on a failed re-plan.

        With ``async_replan`` the whole re-plan runs on a daemon worker
        thread and this method returns immediately — requests served in
        the meantime use the old program, and at most one worker per
        matrix is in flight.  The default is inline (deterministic, but
        the triggering request absorbs the re-plan latency).
        """
        if self.rebalance_cfg.async_replan:
            # check-then-spawn under the per-matrix lock: two request
            # threads closing hot windows near-simultaneously must not
            # both launch workers.
            with m.replan_lock:
                if m.replan_thread is not None and m.replan_thread.is_alive():
                    return             # a re-plan is already in flight
                m.replan_thread = threading.Thread(
                    target=self._replan_and_swap, args=(m,), daemon=True)
                m.replan_thread.start()
        else:
            self._replan_and_swap(m)

    def _replan_and_swap(self, m: IngestedMatrix) -> None:
        new_dist, new_choice, event = replan(
            m.csr, m.monitor, m.choice, num_shards=self.num_shards,
            seed=self.seed, cfg=self.rebalance_cfg,
            request_index=m.spmv_count, program=m.dist)
        m.rebalance_log.append(event)
        if new_dist is not None:
            m.dist = new_dist          # the double-buffer swing
            m.choice = new_choice
            m.monitor.attach(new_dist)
        m.monitor.cooldown()

    def plan(self, name: str) -> SpmvPlan:
        """The plan serving ``name``."""
        return self._lookup(name).choice.plan

    def plans(self) -> Dict[str, str]:
        """name -> PlanChoice JSON for every ingested matrix."""
        return {n: m.choice.to_json() for n, m in self._matrices.items()}

    def rebalance_log(self, name: str) -> List[RebalanceEvent]:
        """Every detector trip for ``name`` (swapped or rejected)."""
        return list(self._lookup(name).rebalance_log)

    def stats(self) -> Dict[str, dict]:
        """Lightweight per-matrix serving stats (JSON-serializable)."""
        out = {}
        for n, m in self._matrices.items():
            s = {"plan": dataclasses.asdict(m.choice.plan),
                 "shard_kernels": list(m.dist.shard_kernels()),
                 "shard_exchanges":
                     list(m.choice.plan.resolved_shard_exchanges()),
                 "nnz": m.dist.matrix.nnz,
                 "migrations": m.dist.traffic.migrations,
                 "hotspot_share": m.dist.traffic.hotspot_share,
                 "spmv_count": m.spmv_count,
                 "plan_cache_hit": m.plan_cache_hit}
            if m.monitor is not None:
                s["rebalance"] = {
                    **m.monitor.stats(),
                    "replans": sum(e.swapped for e in m.rebalance_log),
                    "rejected": sum(not e.swapped for e in m.rebalance_log)}
            out[n] = s
        return out


class Engine:
    """Single-host batched generation (KV/recurrent caches threaded)."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self._decode = jax.jit(
            lambda p, t, c, pos: mm.decode_step(p, cfg, t, c, pos))

    def generate(self, prompts: np.ndarray, steps: int,
                 key: Optional[jax.Array] = None) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + steps) tokens.

        Edge semantics (regression-tested in tests/test_serve_engine.py):

        * ``steps == 0`` returns the prompts unchanged (no decode work);
        * ``S0 == 0`` with ``steps > 0`` raises ``ValueError`` — decoding
          needs at least one prefilled token to produce logits, so callers
          must seed the prompt (e.g. with BOS) explicitly rather than
          having the engine invent one (the old code crashed with a
          ``NameError`` here);
        * sampling (``temperature > 0``) requires an explicit PRNG key —
          silently falling back to greedy decoding was a correctness trap
          for anyone measuring sampled generations.
        """
        B, S0 = prompts.shape
        if steps == 0:
            return np.asarray(prompts, np.int32).copy()
        if self.serve_cfg.temperature > 0 and self.cfg.num_codebooks <= 1 \
                and key is None:
            raise ValueError(
                f"temperature={self.serve_cfg.temperature} requires a PRNG "
                f"key: pass key=jax.random.PRNGKey(seed) to generate(), or "
                f"set temperature=0 for greedy decoding")
        if S0 == 0:
            raise ValueError(
                "cannot decode from an empty prompt (S0 == 0): there are "
                "no logits to sample the first token from; seed each "
                "prompt with at least one token (e.g. BOS)")
        caches = mm.init_cache(self.cfg, B, self.serve_cfg.max_len)
        # Prefill by stepping tokens through the decode path (keeps one
        # compiled program; bulk-prefill lowering is exercised by dryrun).
        for t in range(S0):
            tok = prompts[:, t: t + 1]
            logits, caches = self._decode(self.params, jnp.asarray(tok),
                                          caches, jnp.int32(t))
        out = [prompts]
        pos = S0
        for _ in range(steps):
            if self.cfg.num_codebooks > 1:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, :1]   # head 0
            elif self.serve_cfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, 0] / self.serve_cfg.temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0], axis=-1)[:, None]
            out.append(np.asarray(nxt, np.int32))
            logits, caches = self._decode(self.params, nxt, caches,
                                          jnp.int32(pos))
            pos += 1
        return np.concatenate(out, axis=1)
