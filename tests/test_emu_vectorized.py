"""Engine equivalence for the Emu tick simulator + halo padding fixes.

The vectorized engines (pure numpy and, where a C toolchain exists, the
compiled tick kernel) must be **tick-for-tick identical** to the legacy
per-thread Python loop (``simulate_reference``): same tick counts, same
migration totals, same per-nodelet instruction counts, same residency
traces.  The suite sweeps the synthetic archetypes (power-law, banded,
uniform) across both vector layouts and both work distributions, plus
congestion-heavy machine configs that exercise queue throttling,
destination backpressure and the trickle-credit floor.

Also pins the `build_halo` padded-slot fix: zero-value ELL slots (padding
or stored explicit zeros) must not widen the halo.
"""
import numpy as np
import pytest

from repro.core import _emu_cext
from repro.core.emu import (EmuConfig, build_thread_traces, run_spmv,
                            simulate, simulate_reference)
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.sparse_matrix import csr_from_coo
from repro.core.spmv import SpmvPlan, build_distributed, build_halo
from repro.data.matrices import banded, powerlaw

# Small machine so the O(threads) reference loop stays affordable, with a
# queue small enough that the congestion/throttling paths actually fire.
CFG = EmuConfig(nodelets=4, threads_per_nodelet=16, migration_queue_cap=8,
                me_rate=3, ingress_rate=3, resident_cap=20,
                latency_hide_threads=8)

ENGINES = ["numpy"]
if _emu_cext.load_kernel() is not None:
    ENGINES.append("cext")


def uniform(M: int, nnz: int, *, seed: int = 0):
    """Uniformly scattered random pattern (the suite's third archetype)."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, M, nnz)
    cols = rng.integers(0, M, nnz)
    vals = rng.standard_normal(nnz)
    return csr_from_coo(np.concatenate([rows, np.arange(M)]),
                        np.concatenate([cols, np.arange(M)]),
                        np.concatenate([vals, np.ones(M)]), (M, M))


MATRICES = {
    "powerlaw": lambda: powerlaw(192, 1800, seed=1),
    "banded": lambda: banded(192, 1500, 6, seed=2),
    "uniform": lambda: uniform(192, 1500, seed=3),
}


def assert_equivalent(a, b):
    assert a.ticks == b.ticks
    assert a.migrations == b.migrations
    assert a.seconds == b.seconds
    assert a.sample_every == b.sample_every
    np.testing.assert_array_equal(a.instr_per_nodelet, b.instr_per_nodelet)
    np.testing.assert_array_equal(a.residency, b.residency)


def workload(matrix_key, layout, distribution, cfg=CFG):
    A = MATRICES[matrix_key]()
    part = make_partition(A, cfg.nodelets, distribution)
    lay = make_layout(layout, A.ncols, cfg.nodelets)
    return build_thread_traces(A, part, lay, cfg.threads_per_nodelet)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("matrix_key", list(MATRICES))
@pytest.mark.parametrize("layout", ["block", "cyclic"])
@pytest.mark.parametrize("distribution", ["row", "nnz"])
def test_engine_matches_reference(engine, matrix_key, layout, distribution):
    nodes, weights, homes = workload(matrix_key, layout, distribution)
    ref = simulate_reference(nodes, weights, homes, CFG, 1e6)
    fast = simulate(nodes, weights, homes, CFG, 1e6, engine=engine)
    assert ref.ticks < CFG.max_ticks          # the workload terminates
    assert_equivalent(fast, ref)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_matches_reference_under_heavy_congestion(engine):
    """Tiny queues + slow Migration Engine: throttle cap, congestion floor,
    rate floor and destination-credit floor all bind."""
    cfg = EmuConfig(nodelets=4, threads_per_nodelet=16,
                    migration_queue_cap=4, me_rate=1, ingress_rate=1,
                    resident_cap=17, latency_hide_threads=16,
                    congestion_floor=0.5)
    A = MATRICES["powerlaw"]()
    part = make_partition(A, cfg.nodelets, "row")
    lay = make_layout("cyclic", A.ncols, cfg.nodelets)
    nodes, weights, homes = build_thread_traces(A, part, lay,
                                                cfg.threads_per_nodelet)
    ref = simulate_reference(nodes, weights, homes, cfg, 1e6)
    fast = simulate(nodes, weights, homes, cfg, 1e6, engine=engine)
    assert ref.migrations > 0
    assert_equivalent(fast, ref)


@pytest.mark.parametrize("engine", ENGINES)
def test_residency_sampling_stride_is_honored(engine):
    """target_samples bounds the stored trace in *both* engines: the
    stride is derived from the workload, not hardcoded to 1."""
    cfg = EmuConfig(nodelets=4, threads_per_nodelet=16,
                    migration_queue_cap=8, me_rate=3, ingress_rate=3,
                    resident_cap=20, latency_hide_threads=8,
                    target_samples=8)
    nodes, weights, homes = workload("banded", "block", "row", cfg)
    ref = simulate_reference(nodes, weights, homes, cfg, 1e6)
    fast = simulate(nodes, weights, homes, cfg, 1e6, engine=engine)
    assert ref.sample_every > 1
    assert ref.residency.shape[0] == -(-ref.ticks // ref.sample_every)
    assert ref.residency.shape[0] < ref.ticks
    assert_equivalent(fast, ref)


def test_run_spmv_default_engine_matches_reference():
    """The public entry point's default engine is pinned too."""
    A = MATRICES["banded"]()
    part = make_partition(A, CFG.nodelets, "nnz")
    lay = make_layout("block", A.ncols, CFG.nodelets)
    ref = run_spmv(A, part, lay, CFG, engine="reference")
    fast = run_spmv(A, part, lay, CFG)
    assert_equivalent(fast, ref)
    assert fast.bandwidth_mbs == ref.bandwidth_mbs


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("kernel", ["ell", "seg", "hyb", "split", "tile"])
def test_engine_matches_reference_on_format_streams(engine, kernel):
    """Format-shaped home streams (``shard_kernels=``) stay tick-for-tick
    identical across all three engines: the per-format instruction
    weights only change the trace the engines consume, never the tick
    semantics."""
    A = MATRICES["powerlaw"]()
    part = make_partition(A, CFG.nodelets, "nnz")
    lay = make_layout("block", A.ncols, CFG.nodelets)
    sk = (kernel,) * CFG.nodelets
    nodes, weights, homes = build_thread_traces(
        A, part, lay, CFG.threads_per_nodelet, shard_kernels=sk)
    ref = simulate_reference(nodes, weights, homes, CFG, 1e6)
    fast = simulate(nodes, weights, homes, CFG, 1e6, engine=engine)
    assert ref.ticks < CFG.max_ticks
    assert_equivalent(fast, ref)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_matches_reference_on_mixed_format_streams(engine):
    """A genuinely heterogeneous kernel tuple (one format per shard) is
    also engine-equivalent — the per-shard program probe path."""
    A = MATRICES["powerlaw"]()
    part = make_partition(A, CFG.nodelets, "nnz")
    lay = make_layout("cyclic", A.ncols, CFG.nodelets)
    sk = ("tile", "seg", "hyb", "split")
    nodes, weights, homes = build_thread_traces(
        A, part, lay, CFG.threads_per_nodelet, shard_kernels=sk)
    ref = simulate_reference(nodes, weights, homes, CFG, 1e6)
    fast = simulate(nodes, weights, homes, CFG, 1e6, engine=engine)
    assert_equivalent(fast, ref)


def test_format_streams_differ_from_default():
    """The per-format weights actually reshape the trace (a seg stream
    pays carry instructions the raw-CSR default does not), while the
    ``shard_kernels=None`` default stays byte-identical to the legacy
    builder output."""
    A = MATRICES["powerlaw"]()
    part = make_partition(A, CFG.nodelets, "nnz")
    lay = make_layout("block", A.ncols, CFG.nodelets)
    base = build_thread_traces(A, part, lay, CFG.threads_per_nodelet)
    again = build_thread_traces(A, part, lay, CFG.threads_per_nodelet,
                                shard_kernels=None)
    for a, b in zip(base, again):
        for ta, tb in zip(a, b):
            np.testing.assert_array_equal(ta, tb)
    seg = build_thread_traces(A, part, lay, CFG.threads_per_nodelet,
                              shard_kernels=("seg",) * CFG.nodelets)
    base_total = sum(w.sum() for w in base[1])
    seg_total = sum(w.sum() for w in seg[1])
    assert seg_total != base_total
    with pytest.raises(ValueError, match="shard_kernels"):
        build_thread_traces(A, part, lay, CFG.threads_per_nodelet,
                            shard_kernels=("seg",))


def test_cv_metrics_are_distinct():
    """instr_cv is the Fig. 7 balance metric; residency_cv reads the
    trace.  (residency_cv used to silently alias instr_cv.)"""
    A = MATRICES["powerlaw"]()
    part = make_partition(A, CFG.nodelets, "row")
    res = run_spmv(A, part, make_layout("block", A.ncols, CFG.nodelets), CFG)
    m = res.instr_per_nodelet
    assert res.instr_cv == pytest.approx(float(m.std() / m.mean()))
    r = res.residency.astype(np.float64).mean(axis=0)
    assert res.residency_cv == pytest.approx(float(r.std() / r.mean()))
    assert res.instr_cv != res.residency_cv


# ---------------------------------------------------------------------------
# build_halo: zero-value slots must not widen the halo
# ---------------------------------------------------------------------------

def expected_halo(dist):
    """Brute-force H from the built slabs, counting value!=0 slots only."""
    S = dist.plan.num_shards
    lay = dist.x_layout
    H = 0
    for p in range(S):
        cols = dist.cols[p].reshape(-1)
        vals = dist.data[p].reshape(-1)
        own = lay.owner_of(cols)
        for q in range(S):
            if q == p:
                continue
            ids = np.unique(cols[(own == q) & (vals != 0)])
            H = max(H, ids.size)
    return max(H, 1)


def test_halo_ignores_padded_ell_slots():
    """Padded ELL slots point at (col 0, value 0); before the fix every
    shard p != 0 counted global id 0 as a remote read from shard 0, so a
    shard whose widest exchange is with shard 0 reported H one too large.
    """
    M, S, k = 256, 4, 5
    # shard 1 (rows 64..127 under the block row split) reads remote
    # columns 1..k, all owned by shard 0; column 0 itself is never read.
    rows = [64] * k + list(range(M))
    cols = list(range(1, k + 1)) + list(range(M))
    vals = np.ones(len(rows))
    A = csr_from_coo(np.array(rows), np.array(cols), vals, (M, M))
    dist = build_distributed(A, SpmvPlan(layout="block", distribution="row",
                                         exchange="halo", num_shards=S))
    # row 64 has k+1 entries, everything else 1 -> the slabs are padded
    assert (dist.data == 0).any()
    halo = build_halo(dist)
    assert halo.halo == k                       # k+1 under the old bug
    assert halo.comm_elems_per_shard == S * k
    assert halo.halo == expected_halo(dist)


def test_halo_matches_brute_force_on_random_matrix():
    A = uniform(256, 1200, seed=5)
    dist = build_distributed(A, SpmvPlan(layout="block", distribution="row",
                                         exchange="halo", num_shards=4))
    halo = build_halo(dist)
    assert halo.halo == expected_halo(dist)
    assert halo.comm_elems_per_shard == 4 * halo.halo


def test_halo_unchanged_by_rows_of_explicit_zeros():
    """Appending rows of stored explicit zeros (same dims, empty rows gain
    zero-valued entries) must not change the halo exchange."""
    M, S = 256, 4
    rng = np.random.default_rng(7)
    # entries only in the first 200 rows; rows 200.. are empty
    rows = rng.integers(0, 200, 900)
    cols = rng.integers(0, M, 900)
    vals = rng.standard_normal(900)
    A = csr_from_coo(rows, cols, vals, (M, M))
    # the same matrix, but the empty tail rows now hold explicit zeros
    # pointing at remote columns
    zr = np.repeat(np.arange(200, M), 4)
    zc = rng.integers(0, M, zr.size)
    B = csr_from_coo(np.concatenate([rows, zr]),
                     np.concatenate([cols, zc]),
                     np.concatenate([vals, np.zeros(zr.size)]), (M, M))
    plan = SpmvPlan(layout="block", distribution="row", exchange="halo",
                    num_shards=S)
    ha = build_halo(build_distributed(A, plan))
    hb = build_halo(build_distributed(B, plan))
    assert hb.halo == ha.halo
    assert hb.comm_elems_per_shard == ha.comm_elems_per_shard
    np.testing.assert_array_equal(ha.send_idx, hb.send_idx)
