"""Model assembly: forward / loss / prefill / decode for all 10 archs.

One code path serves every family; heterogeneous stacks run as scanned
super-blocks (pattern units) with optional unscanned tail/prefix layers.
Decode threads a per-layer state pytree (KV caches for attention kinds,
recurrent states for ssm/hybrid kinds) through the same block dispatch.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .griffin import rglru_block
from .layers import attention_block, ffn_block, rms_norm
from .moe import moe_ffn, shared_ffn
from .xlstm import mlstm_block, slstm_block

F32 = jnp.float32
Tree = Any


def _maybe_constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """with_sharding_constraint iff tracing inside a non-trivial mesh.

    ``axes`` gives one mesh axis, tuple of axes, or None per dim of x; axes
    not in the active mesh (or not dividing the dim) are dropped.  No-op
    outside a mesh context, so smoke tests / single-device runs are
    unaffected.
    """
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        def resolve(dim, a):
            cand = a if isinstance(a, tuple) else ((a,) if a else ())
            cand = tuple(c for c in cand if c in m.axis_names)
            size = 1
            for c in cand:
                size *= m.shape[c]
            if not cand or x.shape[dim] % size or x.shape[dim] < size:
                return None
            return cand if len(cand) > 1 else cand[0]

        spec = PartitionSpec(*[resolve(i, a) for i, a in enumerate(axes)])
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
    except Exception:
        return x


_BATCH = ("pod", "data")


# --------------------------------------------------------------------------
# single block
# --------------------------------------------------------------------------

def _ffn_params(p):
    return {k: p[k] for k in ("w_gate", "w_up", "w_down")}


def block_apply(kind: str, p: Tree, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray, *, cache=None, cache_len=None,
                decode: bool = False, prefix_len: int = 0,
                rng: Optional[jax.Array] = None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), F32)
    if kind in ("attn", "local_attn", "moe"):
        window = cfg.attn_window if kind == "local_attn" else None
        h, new_cache = attention_block(
            p, rms_norm(x, p["norm1"]), cfg, positions, window=window,
            prefix_len=prefix_len, kv_cache=cache, cache_len=cache_len)
        x = x + h
        if kind == "moe" and "router" in p:
            y, aux = moe_ffn(p, rms_norm(x, p["norm2"]), cfg.moe,
                             cfg.activation, rng=rng)
            if "s_gate" in p:
                y = y + shared_ffn(
                    {"w_gate": p["s_gate"], "w_up": p["s_up"],
                     "w_down": p["s_down"]},
                    rms_norm(x, p["norm2"]), cfg.activation)
            x = x + y
        elif "w_gate" in p:
            x = x + ffn_block(_ffn_params(p), rms_norm(x, p["norm2"]),
                              cfg.activation)
    elif kind == "mlstm":
        h, new_cache = mlstm_block(p, rms_norm(x, p["norm1"]), cfg,
                                   state=cache, decode=decode)
        x = x + h
    elif kind == "slstm":
        h, new_cache = slstm_block(p, rms_norm(x, p["norm1"]), cfg,
                                   state=cache, decode=decode)
        x = x + h
    elif kind == "rglru":
        h, new_cache = rglru_block(p, rms_norm(x, p["norm1"]), cfg,
                                   state=cache, decode=decode)
        x = x + h
        if "w_gate" in p and "norm2" in p:
            x = x + ffn_block(_ffn_params(p), rms_norm(x, p["norm2"]),
                              cfg.activation)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def _block_cache_shape(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    """Abstract cache for one block (no leading unit axis)."""
    bf = jnp.bfloat16
    if kind in ("attn", "moe"):
        c = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return (jax.ShapeDtypeStruct(c, bf), jax.ShapeDtypeStruct(c, bf))
    if kind == "local_attn":
        w = min(cfg.attn_window or max_len, max_len)
        c = (batch, w, cfg.num_kv_heads, cfg.head_dim)
        return (jax.ShapeDtypeStruct(c, bf), jax.ShapeDtypeStruct(c, bf))
    if kind == "mlstm":
        inner = int(cfg.d_model * cfg.lstm_proj_factor)
        Dk = inner // cfg.num_heads
        return (jax.ShapeDtypeStruct((batch, cfg.num_heads, Dk, Dk), F32),
                jax.ShapeDtypeStruct((batch, cfg.num_heads, Dk), F32))
    if kind == "slstm":
        from .params import slstm_inner
        inner = slstm_inner(cfg)
        Dh = inner // cfg.num_heads
        s = jax.ShapeDtypeStruct((batch, cfg.num_heads, Dh), F32)
        return (s, s, s, s)
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return (jax.ShapeDtypeStruct((batch, w), F32),
                jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), jnp.bfloat16))
    raise ValueError(kind)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    unit = cfg.pattern()
    n_scan = cfg.num_layers - cfg.dense_first_layers
    n_units = n_scan // len(unit)
    tail_kinds = unit[: n_scan % len(unit)]

    def stack(sds, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), sds)

    cache = {
        "stack": {f"u{j}_{k}": stack(_block_cache_shape(cfg, k, batch, max_len),
                                     n_units)
                  for j, k in enumerate(unit)},
        "tail": {f"t{j}_{k}": _block_cache_shape(cfg, k, batch, max_len)
                 for j, k in enumerate(tail_kinds)},
        "prefix": {f"p{j}_{unit[0]}": _block_cache_shape(cfg, unit[0], batch,
                                                         max_len)
                   for j in range(cfg.dense_first_layers)},
    }
    return cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Tree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_len))


# --------------------------------------------------------------------------
# stack traversal
# --------------------------------------------------------------------------

def _apply_stack(params: Tree, x: jnp.ndarray, cfg: ModelConfig,
                 positions, *, caches=None, cache_len=None, decode=False,
                 prefix_len=0, rng=None, remat=False, scan_unroll=1):
    """Run prefix layers, the scanned super-block stack, then tail layers."""
    unit = cfg.pattern()
    aux_total = jnp.zeros((), F32)
    new_caches: Dict[str, Any] = {"stack": {}, "tail": {}, "prefix": {}}

    def get_cache(group, name):
        return None if caches is None else caches[group][name]

    for j in range(cfg.dense_first_layers):
        name = f"p{j}_{unit[0]}"
        x, nc, aux = block_apply(unit[0], params["prefix"][name], x, cfg,
                                 positions, cache=get_cache("prefix", name),
                                 cache_len=cache_len, decode=decode,
                                 prefix_len=prefix_len, rng=rng)
        new_caches["prefix"][name] = nc
        aux_total += aux

    # scanned units
    n_units = jax.tree.leaves(params["stack"])[0].shape[0] \
        if params["stack"] else 0
    if n_units:
        stack_params = params["stack"]
        stack_caches = None if caches is None else caches["stack"]

        def body(carry, per_unit):
            x, aux_acc = carry
            x = _maybe_constrain(x, _BATCH, None, None)  # batch stays DP
            p_j, c_j = per_unit
            ncs = {}
            for j, kind in enumerate(unit):
                name = f"u{j}_{kind}"
                c = None if c_j is None else c_j[name]
                x, nc, aux = block_apply(kind, p_j[name], x, cfg, positions,
                                         cache=c, cache_len=cache_len,
                                         decode=decode, prefix_len=prefix_len,
                                         rng=rng)
                if c_j is not None:
                    ncs[name] = nc      # train mode: no cache ys to stack
            return (x, aux_acc + aux), ncs

        if stack_caches is None:
            unit_fn = (lambda c, p: body(c, (p, None)))
            if remat:
                # Per-unit activation checkpointing: the scan recomputes a
                # super-block on the backward pass instead of saving it.
                unit_fn = jax.checkpoint(unit_fn,
                                         prevent_cse=False)
            (x, aux_total), out_caches = jax.lax.scan(
                unit_fn, (x, aux_total), stack_params, unroll=scan_unroll)
        else:
            (x, aux_total), out_caches = jax.lax.scan(
                body, (x, aux_total), (stack_params, stack_caches),
                unroll=scan_unroll)
        new_caches["stack"] = out_caches

    tail_kinds = unit[: (cfg.num_layers - cfg.dense_first_layers) % len(unit)]
    for j, kind in enumerate(tail_kinds):
        name = f"t{j}_{kind}"
        x, nc, aux = block_apply(kind, params["tail"][name], x, cfg,
                                 positions, cache=get_cache("tail", name),
                                 cache_len=cache_len, decode=decode,
                                 prefix_len=prefix_len, rng=rng)
        new_caches["tail"][name] = nc
        aux_total += aux
    return x, new_caches, aux_total


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def embed_inputs(params: Tree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Family-specific input embedding. Returns (x, positions, prefix_len)."""
    if cfg.frontend == "encodec_stub":
        x = batch["frames"].astype(jnp.bfloat16)            # (B, S, d)
        B, S, _ = x.shape
        return x, jnp.arange(S)[None].repeat(B, 0), 0
    if cfg.frontend == "siglip_stub":
        img = batch["image_embeds"].astype(jnp.bfloat16)    # (B, P, d)
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = jnp.concatenate([img, tok.astype(jnp.bfloat16)], axis=1)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        B, S, _ = x.shape
        return x, jnp.arange(S)[None].repeat(B, 0), cfg.prefix_len
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = tok.astype(jnp.bfloat16) * jnp.asarray(cfg.d_model ** 0.5, jnp.bfloat16)
    x = _maybe_constrain(x, _BATCH, None, None)
    B, S = batch["tokens"].shape
    return x, jnp.arange(S)[None].repeat(B, 0), 0


def logits_from_hidden(params: Tree, cfg: ModelConfig, x: jnp.ndarray):
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(F32)
    logits = _maybe_constrain(logits, _BATCH, None, "model")  # keep V sharded
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.num_codebooks > 1:
        B, S, _ = logits.shape
        logits = logits.reshape(B, S, cfg.num_codebooks, cfg.vocab_size)
        logits = _maybe_constrain(logits, _BATCH, None, None, "model")
    return logits


def forward(params: Tree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, rng: Optional[jax.Array] = None, remat: bool = False,
            scan_unroll=1):
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    x, _, aux = _apply_stack(params, x, cfg, positions,
                             prefix_len=prefix_len, rng=rng, remat=remat,
                             scan_unroll=scan_unroll)
    return logits_from_hidden(params, cfg, x), aux


def loss_fn(params: Tree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, rng: Optional[jax.Array] = None, remat: bool = False,
            scan_unroll=1):
    logits, aux = forward(params, cfg, batch, rng=rng, remat=remat,
                          scan_unroll=scan_unroll)
    labels = batch["labels"]
    if cfg.frontend == "siglip_stub":
        logits = logits[:, cfg.prefix_len:]
    # Vocab-sharded cross entropy: logsumexp reduces over the sharded axis
    # (a psum under GSPMD) and the label logit is picked with an iota
    # compare instead of a gather, so the (tokens x vocab) tensor never
    # materializes unsharded.
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot_pick = jnp.sum(
        jnp.where(jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1)
                  == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - onehot_pick
    mask = (labels >= 0).astype(F32)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss + aux, {"ce": loss, "aux": aux}


def prefill(params: Tree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            *, scan_unroll=1):
    """Prefill forward: logits for the LAST position only (the next-token
    sample) — materializing (B, S, V) at 32k x 256k vocab would dwarf the
    KV cache itself."""
    x, positions, prefix_len = embed_inputs(params, cfg, batch)
    x, _, _ = _apply_stack(params, x, cfg, positions, prefix_len=prefix_len,
                           scan_unroll=scan_unroll)
    return logits_from_hidden(params, cfg, x[:, -1:])


def decode_step(params: Tree, cfg: ModelConfig, tokens: jnp.ndarray,
                caches: Tree, pos: jnp.ndarray, *, scan_unroll=1):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 (current
    length).  Returns (logits (B, 1, V[*K]), new caches)."""
    tok = jnp.take(params["embed"], tokens, axis=0)
    x = tok.astype(jnp.bfloat16) * jnp.asarray(cfg.d_model ** 0.5, jnp.bfloat16)
    B = tokens.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    # local_attn ring buffers index at pos % window
    cache_len = pos
    x, new_caches, _ = _apply_stack(params, x, cfg, positions,
                                    caches=caches, cache_len=cache_len,
                                    decode=True, scan_unroll=scan_unroll)
    return logits_from_hidden(params, cfg, x), new_caches
