"""Partition / reorder invariants (satellite of the segmented-SpMV PR).

Every ``make_partition`` mode must cover all rows exactly once; every
``reorder`` permutation must be a bijection that conserves nnz and keeps
``A @ x`` equal under the symmetric permutation; the element-level chunking
behind the segmented kernel must tile the nnz stream exactly.
"""
import numpy as np
import pytest

from repro.core.partition import (DISTRIBUTIONS, make_partition,
                                  nnz_chunk_starts, partition_nonzeros)
from repro.core.reorder import REORDERINGS, reorder, reordering_permutation
from repro.core.sparse_matrix import csr_from_coo, csr_to_dense, csr_row_nnz
from repro.data.matrices import make_matrix, powerlaw


def rand_csr(M=300, N=300, nnz=3000, seed=0):
    rng = np.random.default_rng(seed)
    return csr_from_coo(rng.integers(0, M, nnz), rng.integers(0, N, nnz),
                        rng.standard_normal(nnz), (M, N))


class TestPartitionCoverage:
    @pytest.mark.parametrize("strategy", DISTRIBUTIONS)
    @pytest.mark.parametrize("num_shards", [1, 4, 8])
    def test_rows_covered_exactly_once(self, strategy, num_shards):
        A = powerlaw(512, 4000, seed=2)
        p = make_partition(A, num_shards, strategy)
        assert p.starts[0] == 0 and p.starts[-1] == A.nrows
        assert (np.diff(p.starts) >= 0).all()
        owners = p.owner_of_rows(A.nrows)
        counts = np.zeros(num_shards, np.int64)
        np.add.at(counts, owners, 1)
        assert counts.sum() == A.nrows
        # each shard's claimed rows are exactly the rows it owns
        for s in range(num_shards):
            assert counts[s] == len(p.rows_of(s))

    def test_nnz_is_alias_of_nonzero(self):
        A = rand_csr()
        np.testing.assert_array_equal(make_partition(A, 8, "nnz").starts,
                                      make_partition(A, 8, "nonzero").starts)

    def test_unknown_strategy_raises(self):
        with pytest.raises(ValueError, match="work-distribution"):
            make_partition(rand_csr(), 8, "zigzag")

    @pytest.mark.parametrize("strategy", DISTRIBUTIONS)
    def test_thread_splits_cover_each_shard(self, strategy):
        A = make_matrix("cop20k_A", scale=0.005)
        p = make_partition(A, 4, strategy)
        splits = p.thread_splits(A, 8)
        for s in range(4):
            t = splits[s]
            assert t[0] == p.starts[s] and t[-1] == p.starts[s + 1]
            assert (np.diff(t) >= 0).all()

    def test_nonzero_balances_on_skew(self):
        A = powerlaw(2048, 20000, seed=1)
        pn = partition_nonzeros(A, 8)
        nnz = pn.nnz_per_shard(A).astype(float)
        assert nnz.std() / nnz.mean() < 0.1


class TestNnzChunking:
    @pytest.mark.parametrize("nnz,chunk", [(0, 128), (1, 128), (127, 128),
                                           (128, 128), (129, 128),
                                           (10_000, 512)])
    def test_chunks_tile_stream_exactly(self, nnz, chunk):
        starts = nnz_chunk_starts(nnz, chunk)
        sizes = np.diff(starts)
        assert starts[0] == 0 and starts[-1] == nnz
        assert (sizes >= 0).all()
        if nnz > chunk:
            assert (sizes[:-1] == chunk).all()
        assert sizes.sum() == nnz

    def test_bad_chunk_raises(self):
        with pytest.raises(ValueError):
            nnz_chunk_starts(100, 0)


class TestReorderInvariants:
    @pytest.mark.parametrize("method", REORDERINGS)
    def test_permutation_is_bijection(self, method):
        A = make_matrix("ford1", scale=0.03)
        perm = reordering_permutation(A, method, seed=4)
        assert perm.shape == (A.nrows,)
        assert np.array_equal(np.sort(perm), np.arange(A.nrows))

    @pytest.mark.parametrize("method", REORDERINGS)
    def test_conserves_nnz_and_values(self, method):
        A = make_matrix("cop20k_A", scale=0.005)
        B = reorder(A, method, seed=4)
        assert B.nnz == A.nnz
        np.testing.assert_allclose(np.sort(B.values), np.sort(A.values))

    @pytest.mark.parametrize("method", REORDERINGS)
    def test_spmv_equal_under_permutation(self, method):
        """B = P A P^T with B[perm[i], perm[j]] = A[i, j]; then
        (B @ xp)[perm] == A @ x where xp[perm] = x."""
        A = make_matrix("ford1", scale=0.03)
        perm = reordering_permutation(A, method, seed=4)
        B = A.permuted(perm, perm)
        rng = np.random.default_rng(0)
        x = rng.standard_normal(A.ncols)
        xp = np.empty_like(x)
        xp[perm] = x
        np.testing.assert_allclose((csr_to_dense(B) @ xp)[perm],
                                   csr_to_dense(A) @ x, atol=1e-9)

    def test_degree_orders_by_row_nnz(self):
        A = powerlaw(512, 5000, seed=3)
        B = reorder(A, "degree")
        nnz = csr_row_nnz(B)
        # heaviest rows first (stable sort on descending degree)
        assert nnz[0] == csr_row_nnz(A).max()
        assert (np.diff(nnz) <= 0).all()
