"""Mixed-structure benchmark: per-shard heterogeneous program vs best
global plan.

The matrix is ``data.matrices.mixed_structure`` — a dense FEM-style band
(regular ~lane-width rows, ELL-friendly) glued to a short-row scattered
sparse block with zipf row lengths (webbase-like, where the 128-lane
ELL/HYB slab floor wastes >90% of its slots and the nonzero-balanced
segmented format wins) — so under a contiguous row partition the two
regimes land on *different shards*.  One global (kernel) choice must
either pay the lane floor on the sparse shards (ell/hyb) or pay
scan/scatter overhead on the regular band (seg); the per-shard autotuner
pays ``sum_p min_k`` instead of ``min_k sum_p``.

Reported (and recorded in ``BENCH_emu.json`` via ``perf_probe --hetero``):

* modeled total cycles of the best **global** (uniform-kernel) candidate
  vs the best **per-shard** candidate — the acceptance gate is the
  per-shard program strictly beating the best global plan;
* the kernel-execution-slot term alone (the axis the per-shard choice
  actually moves), worst shard;
* host wall-clock per served SpMV for both lowered programs through the
  numpy executor backend, for reference;
* an oracle check: both programs reproduce ``csr_matvec``.

Usage::

    PYTHONPATH=src python -m benchmarks.hetero_bench              # full
    PYTHONPATH=src python -m benchmarks.hetero_bench --fast \\
        --budget-seconds 120                                      # CI smoke
    PYTHONPATH=src python -m benchmarks.perf_probe --hetero       # + record
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.plan import autotune
from repro.core.program import execute, lower
from repro.core.sparse_matrix import csr_matvec
from repro.data.matrices import mixed_structure


def _plan_str(p) -> str:
    s = f"{p.reordering}/{p.layout}/{p.distribution}/{p.exchange}"
    if p.shard_kernels is not None:
        return f"{s}/[{'+'.join(p.shard_kernels)}]"
    return f"{s}/{p.kernel}"


def _host_us_per_spmv(prog, x, repeats: int = 10) -> float:
    """Median-of-repeats wall clock of the serving (numpy) executor."""
    execute(prog, x)                      # warm-up
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        execute(prog, x)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def run_hetero_bench(*, M: int = 4096, nnz_per_row: int = 33,
                     shards: int = 8, probe: int = 20, seed: int = 0,
                     fast: bool = False) -> dict:
    """Run the scenario; returns the headline dict (printed by main).

    ``probe=20`` probes *every* (reordering, layout, distribution) base —
    the structure-preserving bases this matrix rewards rank poorly on the
    analytic issue term (the dense band is locality-rich but
    load-imbalanced), so a small probe budget would never measure them.
    """
    if fast:
        M, shards = 1024, 4
    A = mixed_structure(M, M * nnz_per_row, seed=seed)
    choice = autotune(A, num_shards=shards, seed=seed, probe=probe)
    # The ranking is probe-aware (measured bases first), so "best" is the
    # first candidate of each class in ranking order — not min by the
    # analytic total, which would compare across unprobed bases.
    uniform = [r for r in choice.ranking if r.plan.shard_kernels is None]
    hetero = [r for r in choice.ranking if r.plan.shard_kernels is not None]
    best_uni = uniform[0]
    best_het = hetero[0] if hetero else None

    entry = {
        "workload": "hetero/mixed_structure", "M": A.nrows, "nnz": A.nnz,
        "shards": shards, "probe": probe,
        "chosen_plan": _plan_str(choice.plan),
        "chosen_is_per_shard": choice.plan.shard_kernels is not None,
        "best_global_plan": _plan_str(best_uni.plan),
        "per_shard_plan": None if best_het is None else
        _plan_str(best_het.plan),
        "shard_kernels": None if best_het is None else
        list(best_het.plan.shard_kernels),
    }
    if best_het is None:
        entry["model_total_cycles"] = {
            "best_global": round(best_uni.cost.total, 1),
            "per_shard": None, "speedup": 0.0}
        entry["oracle_ok"] = False
        return entry

    entry["model_total_cycles"] = {
        "best_global": round(best_uni.cost.total, 1),
        "per_shard": round(best_het.cost.total, 1),
        "speedup": round(best_uni.cost.total /
                         max(best_het.cost.total, 1e-12), 3)}
    entry["model_kernel_cycles"] = {
        "best_global": round(best_uni.cost.padding_cycles, 1),
        "per_shard": round(best_het.cost.padding_cycles, 1),
        "speedup": round(best_uni.cost.padding_cycles /
                         max(best_het.cost.padding_cycles, 1e-12), 3)}

    prog_uni = lower(A, best_uni.plan)
    prog_het = lower(A, best_het.plan)
    x = np.random.default_rng(seed).standard_normal(A.ncols)
    ref = csr_matvec(A, x)
    entry["oracle_ok"] = bool(
        np.allclose(execute(prog_uni, x), ref, atol=1e-4, rtol=1e-5) and
        np.allclose(execute(prog_het, x), ref, atol=1e-4, rtol=1e-5))
    entry["host_us_per_spmv"] = {
        "best_global": round(_host_us_per_spmv(prog_uni, x), 1),
        "per_shard": round(_host_us_per_spmv(prog_het, x), 1)}
    return entry


def check(entry: dict) -> bool:
    """Acceptance gates CI smoke-tests: the autotuner's winner is a
    genuinely heterogeneous per-shard program, it strictly beats the best
    global (uniform-kernel) plan on the analytic model, and both programs
    reproduce the exact oracle."""
    return (entry.get("shard_kernels") is not None and
            len(set(entry["shard_kernels"])) > 1 and
            entry["chosen_is_per_shard"] and
            entry["model_total_cycles"]["speedup"] > 1.0 and
            entry["oracle_ok"])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=4096, help="matrix dimension")
    ap.add_argument("--nnz-per-row", type=int, default=33)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--probe", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller matrix, analytic-only ranking, "
                         "same gates")
    ap.add_argument("--budget-seconds", type=float, default=None,
                    help="fail if the whole run exceeds this wall-clock "
                         "budget (CI tripwire)")
    ap.add_argument("--json", action="store_true",
                    help="print the entry as JSON only")
    args = ap.parse_args()

    t0 = time.perf_counter()
    entry = run_hetero_bench(M=args.m, nnz_per_row=args.nnz_per_row,
                             shards=args.shards, probe=args.probe,
                             seed=args.seed, fast=args.fast)
    wall = time.perf_counter() - t0
    entry["wall_seconds"] = round(wall, 2)
    ok = check(entry)
    if args.budget_seconds is not None and wall > args.budget_seconds:
        ok = False
        entry["budget_exceeded"] = True

    if args.json:
        print(json.dumps(entry, indent=2))
    else:
        print(f"hetero bench: {entry['workload']} M={entry['M']} "
              f"nnz={entry['nnz']} shards={entry['shards']}")
        print(f"  best global : {entry['best_global_plan']}")
        print(f"  per-shard   : {entry['per_shard_plan']}")
        mt = entry["model_total_cycles"]
        print(f"  model total : {mt['best_global']} -> {mt['per_shard']} "
              f"cycles ({mt['speedup']}x, bar > 1.0)")
        if "model_kernel_cycles" in entry:
            mk = entry["model_kernel_cycles"]
            print(f"  kernel term : {mk['best_global']} -> "
                  f"{mk['per_shard']} cycles ({mk['speedup']}x)")
        if "host_us_per_spmv" in entry:
            h = entry["host_us_per_spmv"]
            print(f"  host        : {h['best_global']} -> {h['per_shard']} "
                  f"us/SpMV (numpy executor; reference only)")
        budget = f", wall {wall:.1f}s <= {args.budget_seconds:.0f}s" \
            if args.budget_seconds is not None else f", wall {wall:.1f}s"
        print(f"  -> {'PASS' if ok else 'FAIL'} "
              f"(oracle_ok={entry['oracle_ok']}{budget})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
