"""Doctest guard: the runnable ``>>>`` examples in the documented modules
stay correct under tier-1 (CI additionally runs ``pytest --doctest-modules``
on the same set).
"""
import doctest

import repro.core.plan
import repro.core.reorder
import repro.kernels

MODULES = (repro.core.plan, repro.core.reorder, repro.kernels)


def test_doctests_pass_and_exist():
    for mod in MODULES:
        result = doctest.testmod(mod, verbose=False)
        assert result.failed == 0, f"{mod.__name__}: {result.failed} failed"
        assert result.attempted > 0, f"{mod.__name__}: no doctests found"
