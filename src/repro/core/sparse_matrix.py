"""Sparse matrix containers and format conversions.

The paper stores A in CSR and distributes *rows* across nodelets (each
nodelet holds a "mini CSR" with relative row offsets — Fig. 2).  On TPU we
keep CSR as the canonical host-side format and add two device formats:

* ELL (+ COO overflow tail, i.e. HYB): rows padded to a uniform width that
  is lane-aligned (multiple of 128).  The VPU-friendly SpMV format.
* BCSR with MXU-aligned dense blocks (default 128x128) for block-sparse
  matmuls (SpMM) — how structured sparsity actually pays on a systolic
  array.
* SEG: the flat nnz stream cut into equal-size lane-aligned chunks plus
  per-(chunk, row) "piece" metadata.  The nonzero-balanced format behind
  ``kernels/spmv_seg.py`` — every kernel grid step owns the same number of
  non-zeros, so power-law rows cannot converge work on one tile the way
  they converge threads on one nodelet in the paper's §IV-D.
* TILE: the two-level bitmask-tiled layout — a coarse CSR-like pointer
  grid over dense ``(8, 128)`` tiles plus a per-tile occupancy bitmask.
  Occupied tiles are stored dense (zero-filled) and streamed with whole
  lane-aligned FMAs and *no per-element column indices*; the pointer
  level skips empty tiles entirely.  The blocked format behind
  ``kernels/spmv_tile.py`` — banded / block-structured matrices, where
  ELL pads and seg wastes scan work, are its target.

All host-side structures are numpy; device kernels take jnp views.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "ELL_LANE",
    "ELL_SUBLANE",
    "CSRMatrix",
    "EllMatrix",
    "BcsrMatrix",
    "SegMatrix",
    "SplitMatrix",
    "TileMatrix",
    "csr_from_coo",
    "csr_matvec",
    "csr_to_dense",
    "csr_to_ell",
    "csr_to_bcsr",
    "csr_to_tile",
    "csr_row_nnz",
    "hyb_cap_width",
]

#: TPU tiling of the padded ELL slab: width is rounded to a multiple of
#: ``ELL_LANE``, rows to a multiple of ``ELL_SUBLANE``.  Single source of
#: truth — the plan cost model (``core/plan.py``) imports these so its
#: padding arithmetic always matches what :func:`csr_to_ell` builds.
ELL_LANE = 128
ELL_SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Standard CSR: values / col_index / row_ptr (host, numpy)."""

    shape: Tuple[int, int]
    values: np.ndarray      # (nnz,) float
    col_index: np.ndarray   # (nnz,) int32
    row_ptr: np.ndarray     # (M+1,) int64

    @property
    def nnz(self) -> int:
        return int(self.values.shape[0])

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def row_slice(self, r0: int, r1: int) -> "CSRMatrix":
        """Mini-CSR for rows [r0, r1) with *relative* row offsets (Fig. 2)."""
        lo, hi = int(self.row_ptr[r0]), int(self.row_ptr[r1])
        return CSRMatrix(
            shape=(r1 - r0, self.shape[1]),
            values=self.values[lo:hi],
            col_index=self.col_index[lo:hi],
            row_ptr=(self.row_ptr[r0 : r1 + 1] - lo).astype(np.int64),
        )

    def permuted(self, row_perm: np.ndarray, col_perm: np.ndarray) -> "CSRMatrix":
        """Return P_r A P_c^T as CSR.  perm[i] = new index of old row/col i."""
        M, N = self.shape
        old_rows = np.repeat(np.arange(M), np.diff(self.row_ptr))
        new_rows = row_perm[old_rows]
        new_cols = col_perm[self.col_index]
        order = np.lexsort((new_cols, new_rows))
        nr, nc, nv = new_rows[order], new_cols[order], self.values[order]
        row_ptr = np.zeros(M + 1, dtype=np.int64)
        np.add.at(row_ptr, nr + 1, 1)
        np.cumsum(row_ptr, out=row_ptr)
        return CSRMatrix(shape=self.shape, values=nv,
                         col_index=nc.astype(np.int32), row_ptr=row_ptr)


@dataclasses.dataclass(frozen=True)
class EllMatrix:
    """Padded ELL slab + COO overflow tail (HYB).

    ``data``/``cols`` are (M_pad, W) with W a multiple of ``lane`` and rows
    padded with zeros / ``col=0`` (the zero value makes the padded product a
    no-op).  Rows with more than W non-zeros spill the tail into the COO
    arrays.  ``padding_ratio`` reports the wasted-FLOP fraction so format
    choices are measurable, mirroring the paper's migration accounting.
    """

    shape: Tuple[int, int]
    data: np.ndarray        # (M_pad, W) float
    cols: np.ndarray        # (M_pad, W) int32
    overflow_rows: np.ndarray  # (nnz_ovf,) int32
    overflow_cols: np.ndarray  # (nnz_ovf,) int32
    overflow_vals: np.ndarray  # (nnz_ovf,) float
    nnz: int

    @property
    def width(self) -> int:
        return int(self.data.shape[1])

    @property
    def padding_ratio(self) -> float:
        dense_slots = self.data.shape[0] * self.data.shape[1]
        ell_nnz = self.nnz - self.overflow_vals.shape[0]
        return 1.0 - ell_nnz / max(dense_slots, 1)


@dataclasses.dataclass(frozen=True)
class BcsrMatrix:
    """Block CSR with dense (bm, bn) blocks (MXU tiles by default)."""

    shape: Tuple[int, int]          # unpadded logical shape
    block_shape: Tuple[int, int]
    blocks: np.ndarray              # (nblocks, bm, bn) float
    block_cols: np.ndarray          # (nblocks,) int32
    block_row_ptr: np.ndarray       # (Mb+1,) int64
    nnz: int                        # scalar non-zeros represented

    @property
    def nblocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density_in_blocks(self) -> float:
        bm, bn = self.block_shape
        return self.nnz / max(self.nblocks * bm * bn, 1)


@dataclasses.dataclass(frozen=True)
class SegMatrix:
    """Nonzero-balanced segmented format (merge-path-style SpMV).

    The CSR nnz stream is reshaped to a (C, L) slab of equal-size chunks
    (L lane-aligned, C sublane-padded; padded slots hold val 0 / col 0 /
    row 0, a no-op contribution).  A *piece* is a maximal run of elements
    in one chunk that belong to one row; rows longer than a chunk span
    several pieces, short rows share a chunk with their neighbours.  The
    kernel produces within-chunk prefix sums; the piece arrays drive the
    cross-chunk carry fix-up ``y[row] += psum[chunk, hi] - psum[chunk, lo-1]``.
    """

    shape: Tuple[int, int]
    chunk: int                 # L, elements per chunk (multiple of ``lane``)
    vals: np.ndarray           # (C, L) float32
    cols: np.ndarray           # (C, L) int32
    rows: np.ndarray           # (C, L) int32 row id per slot (0 on padding)
    piece_chunk: np.ndarray    # (n_pieces,) int32
    piece_lo: np.ndarray       # (n_pieces,) int32 first in-chunk offset
    piece_hi: np.ndarray       # (n_pieces,) int32 last in-chunk offset
    piece_row: np.ndarray      # (n_pieces,) int32 destination row
    nnz: int

    @property
    def num_chunks(self) -> int:
        return int(self.vals.shape[0])

    @property
    def n_pieces(self) -> int:
        return int(self.piece_row.shape[0])

    @property
    def padding_ratio(self) -> float:
        slots = self.vals.shape[0] * self.vals.shape[1]
        return 1.0 - self.nnz / max(slots, 1)


@dataclasses.dataclass(frozen=True)
class SplitMatrix:
    """Split-nnz two-stage segmented format (split-K SpMV).

    A SegMatrix slab whose chunk axis is further cut into ``num_splits``
    equal groups: vals/cols/rows are (NS, Cs, L) so stage 1 can fill a
    (NS, Cs) grid even when the shard is a single monster row.  Stage 1
    scatters each split's piece contributions into a *partial* row-sum
    buffer (NS, rows); stage 2 is a tiny combine reducing over the split
    axis — the aiter split-K decode shape (partial accumulators per
    split + cheap second stage).  Pieces never cross a split boundary:
    they are the SegMatrix pieces with the owning chunk re-indexed as
    (piece_split, piece_chunk-within-split).
    """

    shape: Tuple[int, int]
    chunk: int                 # L, elements per chunk (multiple of ``lane``)
    num_splits: int            # NS
    vals: np.ndarray           # (NS, Cs, L) float32
    cols: np.ndarray           # (NS, Cs, L) int32
    rows: np.ndarray           # (NS, Cs, L) int32 row id per slot (0 on pad)
    piece_split: np.ndarray    # (n_pieces,) int32 owning split
    piece_chunk: np.ndarray    # (n_pieces,) int32 chunk *within* its split
    piece_lo: np.ndarray       # (n_pieces,) int32 first in-chunk offset
    piece_hi: np.ndarray       # (n_pieces,) int32 last in-chunk offset
    piece_row: np.ndarray      # (n_pieces,) int32 destination row
    nnz: int

    @property
    def chunks_per_split(self) -> int:
        return int(self.vals.shape[1])

    @property
    def n_pieces(self) -> int:
        return int(self.piece_row.shape[0])

    @property
    def padding_ratio(self) -> float:
        slots = self.vals.shape[0] * self.vals.shape[1] * self.vals.shape[2]
        return 1.0 - self.nnz / max(slots, 1)


@dataclasses.dataclass(frozen=True)
class TileMatrix:
    """Two-level bitmask-tiled layout (pointer grid + dense tiles).

    The matrix is cut into a ``(Mb, Nb)`` grid of ``(bm, bn)`` tiles;
    only *occupied* tiles (holding at least one stored entry) are kept.
    ``tile_ptr`` is the coarse CSR-like pointer level over block rows —
    tiles of block row ``mb`` are ``tile_ptr[mb]:tile_ptr[mb+1]``, sorted
    by block column — so empty tiles are skipped without ever touching
    them.  Each kept tile stores its ``(bm, bn)`` payload dense and
    zero-filled; ``mask`` is the packed per-tile occupancy bitmask
    (``np.packbits`` over the lane axis) that records which cells hold a
    stored entry, distinguishing structural zeros from stored zeros.
    The SpMV kernel streams whole tiles with dense FMAs and needs **no
    per-element column indices** — one ``tile_cols`` id per tile replaces
    ``bm*bn`` ELL column slots.
    """

    shape: Tuple[int, int]
    bm: int                    # tile rows (sublane-aligned)
    bn: int                    # tile cols (lane-aligned)
    tile_ptr: np.ndarray       # (Mb+1,) int32 pointer grid over block rows
    tile_rows: np.ndarray      # (T,) int32 block-row id per tile
    tile_cols: np.ndarray      # (T,) int32 block-col id per tile
    data: np.ndarray           # (T, bm, bn) float32, zero-filled
    mask: np.ndarray           # (T, bm, bn//8) uint8 packed occupancy bits
    nnz: int

    @property
    def num_tiles(self) -> int:
        return int(self.data.shape[0])

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.bm, self.bn)

    @property
    def fill_ratio(self) -> float:
        """Occupied-cell fraction of the kept tiles (1.0 = perfectly
        dense tiles, -> 0 = one stray nonzero per tile)."""
        return self.nnz / max(self.num_tiles * self.bm * self.bn, 1)

    @property
    def max_tiles_per_block_row(self) -> int:
        counts = np.diff(self.tile_ptr)
        return int(counts.max()) if counts.size else 0

    def occupancy(self) -> np.ndarray:
        """Unpacked (T, bm, bn) boolean occupancy from the bitmask."""
        bits = np.unpackbits(self.mask, axis=2, count=self.bn)
        return bits.astype(bool)


def csr_to_tile(csr: CSRMatrix, bm: int = ELL_SUBLANE,
                bn: int = ELL_LANE) -> TileMatrix:
    """Convert CSR -> two-level bitmask-tiled layout.

    Tiles default to the fp32 TPU native tile ``(ELL_SUBLANE, ELL_LANE)``
    = (8, 128) so each streamed tile is exactly one VMEM-resident vector
    tile.  Only occupied tiles are materialized; duplicates cannot occur
    (CSR is canonical).  ``bn`` must be a multiple of 8 so the occupancy
    bitmask packs along the lane axis without padding ambiguity.
    """
    if bn % 8:
        raise ValueError(f"bn must be a multiple of 8, got {bn}")
    M, N = csr.shape
    Mb = max(-(-M // bm), 1)
    Nb = max(-(-N // bn), 1)
    rows = np.repeat(np.arange(M, dtype=np.int64), csr_row_nnz(csr))
    brow = rows // bm
    bcol = csr.col_index.astype(np.int64) // bn
    key = brow * Nb + bcol
    uniq, inverse = np.unique(key, return_inverse=True)
    T = int(uniq.shape[0])
    data = np.zeros((T, bm, bn), dtype=np.float32)
    occ = np.zeros((T, bm, bn), dtype=bool)
    if T:
        lr = (rows % bm).astype(np.int64)
        lc = (csr.col_index.astype(np.int64) % bn)
        np.add.at(data, (inverse, lr, lc), csr.values.astype(np.float32))
        occ[inverse, lr, lc] = True
    tile_rows = (uniq // Nb).astype(np.int32)
    tile_cols = (uniq % Nb).astype(np.int32)
    tile_ptr = np.zeros(Mb + 1, dtype=np.int32)
    np.add.at(tile_ptr, tile_rows + 1, 1)
    np.cumsum(tile_ptr, out=tile_ptr)
    return TileMatrix(shape=csr.shape, bm=bm, bn=bn, tile_ptr=tile_ptr,
                      tile_rows=tile_rows, tile_cols=tile_cols, data=data,
                      mask=np.packbits(occ, axis=2), nnz=csr.nnz)


def csr_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 shape: Tuple[int, int], sum_duplicates: bool = True) -> CSRMatrix:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and rows.size:
        key_change = np.empty(rows.size, dtype=bool)
        key_change[0] = True
        key_change[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        group = np.cumsum(key_change) - 1
        uvals = np.zeros(group[-1] + 1, dtype=vals.dtype)
        np.add.at(uvals, group, vals)
        rows, cols, vals = rows[key_change], cols[key_change], uvals
    M = shape[0]
    row_ptr = np.zeros(M + 1, dtype=np.int64)
    np.add.at(row_ptr, rows + 1, 1)
    np.cumsum(row_ptr, out=row_ptr)
    return CSRMatrix(shape=shape, values=vals.astype(np.float64),
                     col_index=cols.astype(np.int32), row_ptr=row_ptr)


def csr_row_nnz(csr: CSRMatrix) -> np.ndarray:
    return np.diff(csr.row_ptr)


def csr_to_dense(csr: CSRMatrix) -> np.ndarray:
    out = np.zeros(csr.shape, dtype=csr.values.dtype)
    rows = np.repeat(np.arange(csr.nrows), csr_row_nnz(csr))
    out[rows, csr.col_index] = csr.values
    return out


def csr_matvec(csr: CSRMatrix, x: np.ndarray) -> np.ndarray:
    """Exact host y = A @ x straight off the CSR arrays (float64 numpy).

    ``x`` is (ncols,) or (ncols, B); the result matches shape.  This never
    densifies the matrix, so it is the validation oracle serving-scale
    code can afford — the rebalancer checks every candidate program
    against it before swapping it in.
    """
    rows = np.repeat(np.arange(csr.nrows), csr_row_nnz(csr))
    contrib = csr.values.astype(np.float64)
    xs = np.asarray(x, dtype=np.float64)[csr.col_index]
    if xs.ndim == 2:
        contrib = contrib[:, None] * xs
        y = np.zeros((csr.nrows, xs.shape[1]), dtype=np.float64)
    else:
        contrib = contrib * xs
        y = np.zeros(csr.nrows, dtype=np.float64)
    np.add.at(y, rows, contrib)
    return y


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def csr_to_ell(csr: CSRMatrix, lane: int = ELL_LANE, sublane: int = ELL_SUBLANE,
               max_width: int | None = None) -> EllMatrix:
    """Convert to padded ELL (+ COO overflow).

    ``lane``/``sublane`` give the TPU tiling: W is rounded to a multiple of
    ``lane`` and M to a multiple of ``sublane``.  ``max_width`` caps W; rows
    longer than the cap spill to the COO tail (HYB), which bounds the padding
    blow-up on power-law matrices (webbase/rmat in the paper's suite).
    """
    M = csr.nrows
    nnz_per_row = csr_row_nnz(csr)
    natural = int(nnz_per_row.max()) if M else 0
    W = _round_up(max(natural, 1), lane)
    if max_width is not None:
        W = min(W, _round_up(max_width, lane))
    M_pad = _round_up(max(M, 1), sublane)

    data = np.zeros((M_pad, W), dtype=np.float32)
    cols = np.zeros((M_pad, W), dtype=np.int32)
    rows_of_nnz = np.repeat(np.arange(M), nnz_per_row)
    pos_in_row = np.arange(csr.nnz, dtype=np.int64) - csr.row_ptr[rows_of_nnz]
    fits = pos_in_row < W
    data[rows_of_nnz[fits], pos_in_row[fits]] = csr.values[fits]
    cols[rows_of_nnz[fits], pos_in_row[fits]] = csr.col_index[fits]
    spill = ~fits
    orows = rows_of_nnz[spill].astype(np.int32)
    ocols = csr.col_index[spill].astype(np.int32)
    ovals = csr.values[spill].astype(np.float32)
    return EllMatrix(shape=csr.shape, data=data, cols=cols,
                     overflow_rows=orows, overflow_cols=ocols,
                     overflow_vals=ovals, nnz=csr.nnz)


def hyb_cap_width(row_nnz: np.ndarray, lane: int = ELL_LANE) -> int:
    """Lane-aligned ELL width cap for the HYB format of one (sub)matrix.

    The cap is the 95th percentile of row lengths rounded up to a ``lane``
    multiple, so only the heaviest ~5% of rows spill into the COO overflow
    tail.  This is the *single* definition of the HYB split point — the
    plan cost model (``core/plan.py``) and the program lowering
    (``core/program.py``) both call it, so the analytic overflow
    accounting always matches the slabs actually built.  On a matrix whose
    p95 row rounds up to the natural max width, HYB degenerates to plain
    ELL (empty overflow), which is why the kernel selector prefers ``ell``
    on ties.
    """
    row_nnz = np.asarray(row_nnz)
    if row_nnz.size == 0:
        return lane
    p95 = float(np.percentile(row_nnz, 95))
    return _round_up(max(int(np.ceil(p95)), 1), lane)


def csr_to_bcsr(csr: CSRMatrix, block_shape: Tuple[int, int] = (128, 128)) -> BcsrMatrix:
    bm, bn = block_shape
    M, N = csr.shape
    Mb = (M + bm - 1) // bm
    rows = np.repeat(np.arange(M), csr_row_nnz(csr))
    brow = rows // bm
    bcol = csr.col_index // bn
    key = brow.astype(np.int64) * ((N + bn - 1) // bn) + bcol
    uniq, inverse = np.unique(key, return_inverse=True)
    nblocks = uniq.shape[0]
    blocks = np.zeros((max(nblocks, 1), bm, bn), dtype=np.float32)
    if nblocks:
        lr = (rows % bm).astype(np.int64)
        lc = (csr.col_index % bn).astype(np.int64)
        np.add.at(blocks, (inverse, lr, lc), csr.values.astype(np.float32))
    ub_row = (uniq // ((N + bn - 1) // bn)).astype(np.int64)
    ub_col = (uniq % ((N + bn - 1) // bn)).astype(np.int32)
    block_row_ptr = np.zeros(Mb + 1, dtype=np.int64)
    np.add.at(block_row_ptr, ub_row + 1, 1)
    np.cumsum(block_row_ptr, out=block_row_ptr)
    return BcsrMatrix(shape=csr.shape, block_shape=block_shape, blocks=blocks,
                      block_cols=ub_col, block_row_ptr=block_row_ptr, nnz=csr.nnz)
