"""Autotuner benchmark: chosen plan vs best static plan (Emu model).

For every synthetic-suite matrix (pattern-preserving scaled, see
``common.SIM_SCALES``) this enumerates the full static grid (reordering x
layout x distribution) on the Emu timeline simulator, asks
``SpmvPlan.auto`` (the ``core/plan.py`` cost-model autotuner, Emu-sim
probe enabled) for its pick, and reports the regret:

    chosen_time / best_static_time   (acceptance bar: <= 1.25)

Run it standalone (CSV to stdout; ~3-5 min, the timeline simulator is
Python) or via ``python -m benchmarks.run``:

    PYTHONPATH=src python -m benchmarks.autotune_bench --probe 8
    PYTHONPATH=src python -m benchmarks.autotune_bench --matrices rmat ford1
"""
from __future__ import annotations

import argparse

from repro.core.emu import EmuConfig, run_spmv
from repro.core.layout import make_layout
from repro.core.partition import make_partition
from repro.core.reorder import REORDERINGS, reorder
from repro.core.spmv import SpmvPlan
from repro.data.matrices import make_matrix
from .common import SIM_SCALES, emit

GRID_LAYOUTS = ("block", "cyclic")
GRID_DISTS = ("row", "nonzero")


def run(matrices=None, probe: int = 8, shards: int = 8):
    names = matrices or list(SIM_SCALES)
    cfg = EmuConfig(nodelets=shards)
    rows = []
    worst = 0.0
    for name in names:
        A = make_matrix(name, scale=SIM_SCALES[name])
        sim = {}
        for reo in REORDERINGS:
            B = reorder(A, reo, parts=shards)
            for lay in GRID_LAYOUTS:
                for dist in GRID_DISTS:
                    part = make_partition(B, shards, dist)
                    res = run_spmv(B, part,
                                   make_layout(lay, B.ncols, shards), cfg)
                    sim[(reo, lay, dist)] = res
        best_key = min(sim, key=lambda k: sim[k].seconds)
        best = sim[best_key]

        plan = SpmvPlan.auto(A, num_shards=shards, probe=probe)
        chosen = sim[(plan.reordering, plan.layout, plan.distribution)]
        regret = chosen.seconds / max(best.seconds, 1e-12)
        worst = max(worst, regret)
        rows.append((f"autotune/{name}",
                     f"{plan.reordering}/{plan.layout}/{plan.distribution}"
                     f"/{plan.kernel}",
                     round(chosen.bandwidth_mbs, 1),
                     "/".join(best_key), round(best.bandwidth_mbs, 1),
                     round(regret, 3)))
    emit(rows, ("name", "chosen_plan", "chosen_mbs", "best_static",
                "best_mbs", "regret"))
    status = "PASS" if worst <= 1.25 else "FAIL"
    print(f"# max regret {worst:.3f} (bar 1.25) -> {status}")
    return worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrices", nargs="*", default=None,
                    help=f"suite names (default: all of {list(SIM_SCALES)})")
    ap.add_argument("--probe", type=int, default=8,
                    help="distinct bases the autotuner probes on the Emu "
                         "simulator (0 = analytic cost model only)")
    ap.add_argument("--shards", type=int, default=8)
    args = ap.parse_args()
    run(matrices=args.matrices, probe=args.probe, shards=args.shards)


if __name__ == "__main__":
    main()
