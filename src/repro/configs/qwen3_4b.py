"""qwen3-4b [dense] — hf:Qwen/Qwen3-8B family card.  GQA kv=8, qk_norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=9728,
    vocab_size=151_936, activation="swiglu", qk_norm=True,
    rope_theta=1_000_000.0)

def smoke_config():
    return ModelConfig(
        name="qwen3-4b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, activation="swiglu", qk_norm=True)
