"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracle vs dense.

Shape/dtype sweeps + hypothesis property tests, per the assignment brief.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sparse_matrix import csr_from_coo, csr_to_bcsr, csr_to_dense, csr_to_ell
from repro.kernels import ops, ref


def rand_problem(M, N, nnz, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    A = csr_from_coo(rng.integers(0, M, nnz), rng.integers(0, N, nnz),
                     rng.standard_normal(nnz), (M, N))
    x = rng.standard_normal(N).astype(dtype)
    return A, x


class TestEllKernel:
    @pytest.mark.parametrize("M,N,nnz", [(8, 128, 50), (64, 256, 900),
                                         (256, 512, 5000)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_oracle_and_dense(self, M, N, nnz, dtype):
        A, x = rand_problem(M, N, nnz)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data, dtype), jnp.asarray(e.cols)
        xj = jnp.asarray(x, dtype)
        y_ref = ref.ell_spmv_ref(data, cols, xj)
        y_pal = ops.ell_spmv(data, cols, xj, interpret=True,
                             tile_m=8, tile_w=128)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y_pal)[:M],
                                   csr_to_dense(A) @ x, rtol=1e-3, atol=1e-3)

    def test_tile_sweep(self):
        A, x = rand_problem(64, 256, 1500, seed=3)
        e = csr_to_ell(A)
        data, cols, xj = map(jnp.asarray, (e.data, e.cols, x))
        base = None
        for tm in (8, 16, 32, 64):
            for tw in (128, e.data.shape[1]):
                y = np.asarray(ops.ell_spmv(data, cols, xj, interpret=True,
                                            tile_m=tm, tile_w=tw))
                if base is None:
                    base = y
                np.testing.assert_allclose(y, base, rtol=1e-5)

    def test_hyb_overflow_path(self):
        A, x = rand_problem(128, 128, 4000, seed=5)
        e = csr_to_ell(A, lane=8, max_width=8)
        assert e.overflow_vals.size > 0
        y = ops.hyb_spmv(*map(jnp.asarray, (e.data, e.cols, e.overflow_rows,
                                            e.overflow_cols, e.overflow_vals,
                                            x)))
        np.testing.assert_allclose(np.asarray(y)[:128], csr_to_dense(A) @ x,
                                   rtol=1e-3, atol=1e-3)


class TestBellKernel:
    @pytest.mark.parametrize("bm,bn", [(8, 128), (16, 128)])
    def test_spmv_matches(self, bm, bn):
        A, x = rand_problem(256, 256, 3000, seed=1)
        blocks, bcols = ops.bell_from_bcsr(csr_to_bcsr(A, (bm, bn)))
        y_ref = ref.bell_spmv_ref(*map(jnp.asarray, (blocks, bcols, x)))
        y_pal = ops.bell_spmv(*map(jnp.asarray, (blocks, bcols, x)),
                              use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_pal)[:256],
                                   csr_to_dense(A) @ x, rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("B,tb", [(128, 128), (256, 128)])
    def test_spmm_matches(self, B, tb):
        A, _ = rand_problem(256, 256, 2000, seed=2)
        rng = np.random.default_rng(7)
        X = rng.standard_normal((256, B)).astype(np.float32)
        blocks, bcols = ops.bell_from_bcsr(csr_to_bcsr(A, (8, 128)))
        Y = ops.bell_spmm(*map(jnp.asarray, (blocks, bcols, X)),
                          use_kernel=True, interpret=True, tile_b=tb)
        np.testing.assert_allclose(np.asarray(Y)[:256], csr_to_dense(A) @ X,
                                   rtol=1e-3, atol=1e-3)


class TestKernelProperties:
    @settings(max_examples=20, deadline=None)
    @given(M=st.sampled_from([8, 24, 64]),
           N=st.sampled_from([128, 256]),
           nnz=st.integers(10, 800),
           seed=st.integers(0, 2**16))
    def test_ell_linearity(self, M, N, nnz, seed):
        """SpMV is linear: A(ax + by) == a*Ax + b*Ay."""
        A, x = rand_problem(M, N, nnz, seed=seed)
        y2 = np.random.default_rng(seed + 1).standard_normal(N).astype(np.float32)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data), jnp.asarray(e.cols)
        f = lambda v: np.asarray(ref.ell_spmv_ref(data, cols, jnp.asarray(v)))
        lhs = f(2.0 * x + 3.0 * y2)
        np.testing.assert_allclose(lhs, 2.0 * f(x) + 3.0 * f(y2),
                                   rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(nnz=st.integers(16, 600), seed=st.integers(0, 2**16))
    def test_bell_zero_padding_is_noop(self, nnz, seed):
        """Padded (zero) blocks contribute nothing regardless of bcol."""
        A, x = rand_problem(128, 128, nnz, seed=seed)
        blocks, bcols = ops.bell_from_bcsr(csr_to_bcsr(A, (8, 128)))
        # scramble the bcol of padded slots — result must not change
        mask = np.abs(blocks).sum(axis=(2, 3)) == 0
        bcols2 = np.where(mask, (bcols + 1) % blocks.shape[0] // 128, bcols)
        r1 = ref.bell_spmv_ref(*map(jnp.asarray, (blocks, bcols, x)))
        r2 = ref.bell_spmv_ref(*map(jnp.asarray, (blocks, bcols2, x)))
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2))
