"""Dense-vector data layouts (paper §III-B).

* ``block``  — contiguous chunks of ceil(len/P) elements per shard; one
               "migration" per B consecutive remote accesses.
* ``cyclic`` — element round-robin (Emu's ``mw_malloc1dlong``); every
               consecutive remote access changes owner.

On TPU a block layout is the native contiguous ``NamedSharding``; a cyclic
layout is realized by viewing the vector as (P, len/P) with the *leading*
axis sharded — i.e. element i lives on shard i % P.  Both expose the same
``owner_of``/``local_index`` maps that the migration accounting and the Emu
model consume, so the analogue is exact, not approximate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["VectorLayout", "block_layout", "cyclic_layout", "make_layout"]


@dataclasses.dataclass(frozen=True)
class VectorLayout:
    kind: str           # "block" | "cyclic"
    length: int
    num_shards: int
    block: int          # block layout: chunk size; cyclic: 1

    def owner_of(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        if self.kind == "block":
            return np.minimum(idx // self.block, self.num_shards - 1)
        return idx % self.num_shards

    def local_index(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        if self.kind == "block":
            return idx - self.owner_of(idx) * self.block
        return idx // self.num_shards

    def padded_length(self) -> int:
        if self.kind == "block":
            return self.block * self.num_shards
        per = -(-self.length // self.num_shards)
        return per * self.num_shards

    def to_sharded(self, v: np.ndarray) -> np.ndarray:
        """Host-side reshape to (P, per_shard[, B]) in layout order (pad 0).

        ``v`` is (length,) or a multi-RHS block (length, B); any trailing
        axes ride along untouched."""
        per = self.padded_length() // self.num_shards
        buf = np.zeros((self.padded_length(),) + v.shape[1:], dtype=v.dtype)
        buf[: self.length] = v
        if self.kind == "block":
            return buf.reshape((self.num_shards, per) + v.shape[1:])
        cyc = buf.reshape((per, self.num_shards) + v.shape[1:])
        return np.ascontiguousarray(np.swapaxes(cyc, 0, 1))

    def from_sharded(self, shards: np.ndarray) -> np.ndarray:
        if self.kind == "block":
            return shards.reshape((-1,) + shards.shape[2:])[: self.length]
        cyc = np.swapaxes(shards, 0, 1)
        return cyc.reshape((-1,) + shards.shape[2:])[: self.length]


def block_layout(length: int, num_shards: int) -> VectorLayout:
    block = -(-length // num_shards)
    return VectorLayout("block", length, num_shards, block)


def cyclic_layout(length: int, num_shards: int) -> VectorLayout:
    return VectorLayout("cyclic", length, num_shards, 1)


def make_layout(kind: str, length: int, num_shards: int) -> VectorLayout:
    if kind == "block":
        return block_layout(length, num_shards)
    if kind == "cyclic":
        return cyclic_layout(length, num_shards)
    raise ValueError(f"unknown vector layout: {kind!r}")
