# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Module map (see docs/ARCHITECTURE.md for the full picture):
#   sparse_matrix / partition / layout / reorder — formats + the study axes
#   migration / emu / cache_model               — exact counts + machine models
#   spmv                                        — SpmvPlan, distributed programs
#   plan                                        — the cost-model plan autotuner
#
# Submodules import numpy only, except spmv/plan (jax); import them
# directly (e.g. `from repro.core.partition import make_partition`) so the
# numpy-only layers stay importable without jax.
