"""Per-cell perf probe for the §Perf hillclimb + the Emu engine probe.

TPU mode compiles one (arch, shape) cell with RunConfig overrides and
prints the roofline terms + the top-N collective ops — the "profile" the
iteration loop reads (no real TPU, so the lowered IR is the profiler).

    PYTHONPATH=src python -m benchmarks.perf_probe gemma_7b train_4k \
        --fsdp 1 --grad-accum 8 --top 8

Emu mode times the tick engines on the Fig. 8 residency workload and
appends a ticks/sec trajectory entry to ``BENCH_emu.json`` (repo root):

    PYTHONPATH=src python -m benchmarks.perf_probe --emu
    PYTHONPATH=src python -m benchmarks.perf_probe --emu --smoke \
        --budget-seconds 60       # CI: fail if the vectorized path is slow

Drift mode runs the serving rebalancer benchmark
(``benchmarks/drift_bench.py``) and records its headline numbers (load-CV
restoration + modeled throughput uplift) as a ``BENCH_emu.json`` entry:

    PYTHONPATH=src python -m benchmarks.perf_probe --drift

Hetero mode runs the mixed-structure per-shard-program benchmark
(``benchmarks/hetero_bench.py``) and records the per-shard-vs-best-global
headline (model cycles + host serving wall-clock):

    PYTHONPATH=src python -m benchmarks.perf_probe --hetero

Split mode runs the power-law-tail (monster-row) scenario of the same
bench and records the split-vs-best-non-split kernel-slot headline
(acceptance bar: >= 1.1x):

    PYTHONPATH=src python -m benchmarks.perf_probe --split

Tile mode runs the blocked-band scenario of the same bench and records
the bitmask-tiled-vs-best-non-tile kernel-slot headline (acceptance bar:
>= 1.2x on the full run; ``--fast`` runs the CI-smoke size, which only
requires a strict win):

    PYTHONPATH=src python -m benchmarks.perf_probe --tile
    PYTHONPATH=src python -m benchmarks.perf_probe --tile --fast

Pipeline mode runs the exchange-bound halo_spikes scenario and records
the serial-vs-pipelined device-path headline (acceptance bar: >= 1.15x);
the forced 512-device host platform lets the real shard_map executor
verify the two schedules bitwise-equal as part of the same run:

    PYTHONPATH=src python -m benchmarks.perf_probe --pipeline
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import sys
import time

import numpy as np


def top_collectives(hlo: str, n: int = 10):
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    BY = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1,
          "f64": 8, "s64": 8}
    rows = []
    for line in hlo.splitlines():
        m = re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)[-\w]*\(", line)
        if not m or "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        seg = rhs[: rhs.find(m.group(1))]
        nb = 0
        for dt, dims in shape_re.findall(seg):
            k = 1
            for d in dims.split(","):
                if d:
                    k *= int(d)
            nb += k * BY.get(dt, 4)
        rows.append((nb, line.strip()[:160]))
    rows.sort(reverse=True)
    return rows[:n]


from benchmarks.common import append_bench_entry


def _time_engine(engine: str, scale: float):
    """Wall-clock the Fig. 8 workload (cop20k_A, original order) once."""
    from repro.core.emu import EmuConfig, build_thread_traces, simulate, \
        useful_bytes
    from repro.core.layout import make_layout
    from repro.core.partition import make_partition
    from repro.data.matrices import make_matrix

    cfg = EmuConfig()
    A = make_matrix("cop20k_A", scale=scale)
    part = make_partition(A, 8, "nonzero")
    lay = make_layout("block", A.ncols, 8)
    t0 = time.perf_counter()
    nodes, weights, homes = build_thread_traces(A, part, lay,
                                                cfg.threads_per_nodelet)
    t1 = time.perf_counter()
    res = simulate(nodes, weights, homes, cfg, useful_bytes(A),
                   engine=engine)
    t2 = time.perf_counter()
    return {"trace_seconds": round(t1 - t0, 4),
            "sim_seconds": round(t2 - t1, 4),
            "ticks": res.ticks,
            "ticks_per_sec": round(res.ticks / max(t2 - t1, 1e-9)),
            "residency_rows": int(res.residency.shape[0]),
            "sample_every": res.sample_every}


def _emu_backend() -> str:
    from repro.core import _emu_cext
    return "cext" if _emu_cext.load_kernel() is not None else "numpy"


def run_emu_probe(scale: float, ref_scale: float, smoke: bool,
                  budget_seconds: float, out: str | None) -> int:
    """Time the Fig. 8 workload; record a BENCH_emu.json trajectory entry.

    Full mode measures the vectorized engine at ``scale`` and the
    reference engine at ``ref_scale`` (the legacy fig8 size — the Python
    loop cannot run the full matrix in reasonable time), and appends the
    entry.  Smoke mode runs the vectorized engine only and fails (exit 1)
    when it misses ``budget_seconds`` — the CI tripwire against the
    Python-loop path regressing back into the default.
    """
    entry = {"workload": "fig8/cop20k_A", "backend": _emu_backend(),
             "scale": scale, "vectorized": _time_engine("vectorized", scale)}
    vec_wall = entry["vectorized"]["trace_seconds"] + \
        entry["vectorized"]["sim_seconds"]
    if smoke:
        ok = vec_wall <= budget_seconds
        print(f"emu smoke: backend={entry['backend']} scale={scale} "
              f"wall={vec_wall:.2f}s budget={budget_seconds:.0f}s "
              f"ticks/sec={entry['vectorized']['ticks_per_sec']} "
              f"-> {'PASS' if ok else 'FAIL'}")
        return 0 if ok else 1
    ref = _time_engine("reference", ref_scale)
    vec_at_ref = _time_engine("vectorized", ref_scale)
    speedup = ref["sim_seconds"] / max(vec_at_ref["sim_seconds"], 1e-9)
    entry.update({"ref_scale": ref_scale, "reference": ref,
                  "vectorized_at_ref_scale": vec_at_ref,
                  "sim_speedup_at_ref_scale": round(speedup, 1)})
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    print(f"# speedup {speedup:.1f}x (bar 20x) -> "
          f"{'PASS' if speedup >= 20 else 'FAIL'}; recorded in {path}")
    return 0 if speedup >= 20 else 1


def run_drift_probe(out: str | None) -> int:
    """Record the drift-bench headline numbers in ``BENCH_emu.json``.

    Runs the full serving-rebalancer scenario (see
    ``benchmarks/drift_bench.py``) and appends its entry; exit status is
    the bench's own acceptance gate (swap happened, load CV within 2x of
    the fresh-autotune reference, modeled throughput up).
    """
    from benchmarks.drift_bench import check, run_drift_bench
    entry = run_drift_bench()
    ok = check(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    print(f"# drift: load-CV ratio "
          f"{entry['load_cv']['ratio_vs_fresh']} (bar 2.0), modeled "
          f"speedup {entry['modeled_spmv_seconds']['speedup']}x -> "
          f"{'PASS' if ok else 'FAIL'}; recorded in {path}")
    return 0 if ok else 1


def run_hetero_probe(out: str | None) -> int:
    """Record the hetero-bench headline numbers in ``BENCH_emu.json``.

    Runs the full mixed-structure scenario (see
    ``benchmarks/hetero_bench.py``) and appends its entry; exit status is
    the bench's own acceptance gate (the autotuned per-shard program
    exists, is genuinely heterogeneous, beats the best global plan on the
    analytic model, and reproduces the exact oracle).
    """
    from benchmarks.hetero_bench import check, run_hetero_bench
    # probe="auto" spends probes until the measured-vs-analytic inversion
    # rate stabilizes; the recorded full run must not depend on the small
    # default probe budget, and adaptive probing gets there without the
    # old fixed probe=20 full sweep.
    entry = run_hetero_bench(probe="auto")
    ok = check(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    mt = entry["model_total_cycles"]
    print(f"# hetero: per-shard {entry.get('shard_kernels')} vs best global "
          f"{entry['best_global_plan']}; model speedup {mt['speedup']}x "
          f"(bar > 1.0) -> {'PASS' if ok else 'FAIL'}; recorded in {path}")
    return 0 if ok else 1


def run_split_probe(out: str | None) -> int:
    """Record the split-SpMV (powerlaw_tail) headline in ``BENCH_emu.json``.

    Runs the full monster-row scenario (see ``benchmarks/hetero_bench.py
    --workload powerlaw_tail``) and appends its entry; exit status is the
    bench's acceptance gate (the autotuner reaches ``split`` on its own,
    the best split-using program beats the best non-split program by
    >= 1.1x on the kernel-slot term, and both reproduce the oracle).
    ``append_bench_entry`` verifies the entry actually landed on disk.
    """
    from benchmarks.hetero_bench import check_split, run_split_bench
    entry = run_split_bench(probe="auto")
    ok = check_split(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    mk = entry["model_kernel_cycles"]
    print(f"# split: {entry.get('split_kernels')} "
          f"(counts {entry.get('split_counts')}) vs best non-split "
          f"{entry['best_nonsplit_plan']}; kernel-term speedup "
          f"{mk['speedup']}x (bar >= 1.1) -> {'PASS' if ok else 'FAIL'}; "
          f"recorded in {path}")
    return 0 if ok else 1


def run_tile_probe(out: str | None, fast: bool) -> int:
    """Record the bitmask-tiled (blocked_band) headline in ``BENCH_emu.json``.

    Runs the blocked-band scenario (see ``benchmarks/hetero_bench.py
    --workload blocked``) and appends its entry; exit status is the
    bench's acceptance gate (the autotuner's grid reaches ``tile`` on
    its own, the best tile-using program beats the best tile-free
    program by >= 1.2x on the kernel-slot term — a strict win at the
    ``--fast`` CI-smoke size — and both reproduce the oracle).
    ``append_bench_entry`` verifies the entry actually landed on disk.
    """
    from benchmarks.hetero_bench import check_tile, run_tile_bench
    entry = run_tile_bench(probe="auto", fast=fast)
    ok = check_tile(entry, fast=fast)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    mk = entry["model_kernel_cycles"]
    print(f"# tile: {entry.get('tile_kernels')} "
          f"(occupied tiles {entry.get('tile_counts')}) vs best non-tile "
          f"{entry['best_nontile_plan']}; kernel-term speedup "
          f"{mk['speedup']}x (bar {'> 1.0' if fast else '>= 1.2'}) -> "
          f"{'PASS' if ok else 'FAIL'}; recorded in {path}")
    return 0 if ok else 1


def run_pipeline_probe(out: str | None) -> int:
    """Record the pipelined-executor headline in ``BENCH_emu.json``.

    Runs the full exchange-bound scenario (see ``benchmarks/hetero_bench
    .py --workload pipeline``) and appends its entry; exit status is the
    bench's acceptance gate (best-achievable pipelined device-path
    latency >= 1.15x better than best-achievable serial, oracle
    reproduced, shard_map pipelined == serial bitwise).  Because this
    module forces a many-device host platform, the real shard_map
    bitwise check always runs here.
    """
    from benchmarks.hetero_bench import check_pipeline, run_pipeline_bench
    entry = run_pipeline_bench()
    ok = check_pipeline(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    md = entry["model_device_cycles"]
    print(f"# pipeline: {entry['serial_plan']} serial vs "
          f"{entry['pipelined_plan']} pipelined; device-path speedup "
          f"{md['speedup']}x (bar >= 1.15), bitwise "
          f"{entry.get('device_bitwise_ok')} -> "
          f"{'PASS' if ok else 'FAIL'}; recorded in {path}")
    return 0 if ok else 1


def run_bottleneck_probe(out: str | None, fast: bool) -> int:
    """Record the bottleneck-oracle gating headline in ``BENCH_emu.json``.

    Runs both scenarios of ``benchmarks/bottleneck_bench.py`` (amortized
    eager-vs-gated trace cost on the stepped drift, low-traffic
    amortization refusal) and appends the entry; exit status is the
    bench's acceptance gate (gated matches or beats always-re-plan on
    amortized cost with strictly fewer swaps; the volume-blind run swaps
    on the low-share tenant while the gated run refuses it at the
    amortization gate).
    """
    from benchmarks.bottleneck_bench import check, run_bottleneck_bench
    kw = dict(scale=0.003, window=16) if fast else {}
    entry = run_bottleneck_bench(**kw)
    ok = check(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    g = entry["gating"]
    lt = entry["low_traffic"]
    print(f"# bottleneck: eager {g['eager']['swaps']} swap(s) vs gated "
          f"{g['gated']['swaps']} swap(s), amortized trace-cost ratio "
          f"{g['amortized_trace_cost']['ratio_eager_vs_gated']}x "
          f"(bar >= 0.98); low-traffic volume-blind "
          f"{lt['volume_blind']['swaps']} swap(s) vs gated "
          f"{lt['gated']['swaps']} ({lt['gated']['amortization_refusals']} "
          f"amortization refusal(s)) -> {'PASS' if ok else 'FAIL'}; "
          f"recorded in {path}")
    return 0 if ok else 1


def run_serve_probe(out: str | None) -> int:
    """Record the multi-tenant warm-restart serving headline.

    Replays the mixed-tenant bursty trace (``benchmarks/trace_replay.py``)
    against a cold engine and a warm-restarted one sharing its artifact
    store, and appends cold-vs-warm requests/sec, p99 latency and the
    warm-restart ingest speedup; exit status is the bench's acceptance
    gate (>= 2 tenants all warm-started, ingest speedup >= 5x, bitwise
    identical outputs).
    """
    from benchmarks.trace_replay import check, run_trace_replay
    entry = run_trace_replay()
    ok = check(entry)
    path = append_bench_entry(entry, out)
    print(json.dumps(entry, indent=2))
    print(f"# serve: {len(entry['tenants'])} tenants, cold "
          f"{entry['cold']['rps']} req/s p99 {entry['cold']['p99_ms']}ms "
          f"vs warm {entry['warm']['rps']} req/s p99 "
          f"{entry['warm']['p99_ms']}ms; warm-restart ingest speedup "
          f"{entry['ingest_speedup']}x (bar >= 5), bitwise "
          f"{entry['bitwise_equal']} -> "
          f"{'PASS' if ok else 'FAIL'}; recorded in {path}")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch", nargs="?")
    ap.add_argument("shape", nargs="?")
    ap.add_argument("--emu", action="store_true",
                    help="probe the Emu tick engines instead of a TPU cell")
    ap.add_argument("--drift", action="store_true",
                    help="run the serving drift bench and record headline "
                         "numbers (benchmarks/drift_bench.py)")
    ap.add_argument("--hetero", action="store_true",
                    help="run the mixed-structure per-shard-program bench "
                         "and record headline numbers "
                         "(benchmarks/hetero_bench.py)")
    ap.add_argument("--split", action="store_true",
                    help="run the power-law-tail split-SpMV bench and "
                         "record headline numbers (benchmarks/hetero_bench"
                         ".py --workload powerlaw_tail)")
    ap.add_argument("--tile", action="store_true",
                    help="run the blocked-band bitmask-tiled SpMV bench and "
                         "record headline numbers (benchmarks/hetero_bench"
                         ".py --workload blocked)")
    ap.add_argument("--pipeline", action="store_true",
                    help="run the exchange-bound pipelined-executor bench "
                         "and record headline numbers (benchmarks/"
                         "hetero_bench.py --workload pipeline)")
    ap.add_argument("--serve", action="store_true",
                    help="run the multi-tenant cold-vs-warm trace-replay "
                         "bench and record headline numbers (benchmarks/"
                         "trace_replay.py)")
    ap.add_argument("--bottleneck", action="store_true",
                    help="run the bottleneck-oracle amortization-gate "
                         "bench and record headline numbers (benchmarks/"
                         "bottleneck_bench.py)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller matrix/stream for the --bottleneck and "
                         "--tile benches (CI smoke setting)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="fig8 matrix scale for the vectorized timing")
    ap.add_argument("--ref-scale", type=float, default=0.02,
                    help="scale for the reference-vs-vectorized speedup")
    ap.add_argument("--smoke", action="store_true",
                    help="vectorized-only wall-clock budget check (CI)")
    ap.add_argument("--budget-seconds", type=float, default=60.0)
    ap.add_argument("--out", default=None,
                    help="BENCH_emu.json path (default: repo root)")
    ap.add_argument("--fsdp", type=int, default=-1)
    ap.add_argument("--grad-accum", type=int, default=-1)
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--unroll", type=int, default=0)
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.emu:
        sys.exit(run_emu_probe(args.scale, args.ref_scale, args.smoke,
                               args.budget_seconds, args.out))
    if args.drift:
        sys.exit(run_drift_probe(args.out))
    if args.hetero:
        sys.exit(run_hetero_probe(args.out))
    if args.split:
        sys.exit(run_split_probe(args.out))
    if args.tile:
        sys.exit(run_tile_probe(args.out, args.fast))
    if args.pipeline:
        sys.exit(run_pipeline_probe(args.out))
    if args.serve:
        sys.exit(run_serve_probe(args.out))
    if args.bottleneck:
        sys.exit(run_bottleneck_probe(args.out, args.fast))
    if args.arch is None or args.shape is None:
        ap.error("arch and shape are required unless --emu is given")

    from repro.configs.registry import get_config
    from repro.launch.dryrun import analyze, lower_cell, _partial_unroll
    from repro.launch.mesh import make_production_mesh
    from repro.models import layers as L
    from repro.models.config import SHAPES
    from repro.train.loop import RunConfig

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    fsdp = cfg.param_count() > 8e9 if args.fsdp < 0 else bool(args.fsdp)
    ga = (8 if shape.kind == "train" else 1) if args.grad_accum < 0 \
        else args.grad_accum
    u = _partial_unroll(cfg) if args.unroll else 0
    run = RunConfig(fsdp=fsdp, remat=bool(args.remat), donate=True,
                    scan_unroll=u or False, grad_accum=ga)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    if u:
        L.ANALYSIS_UNROLL = True
    lo, co, _, _ = lower_cell(args.arch, args.shape, mesh, run=run)
    L.ANALYSIS_UNROLL = False
    res = analyze(lo, co, cfg, shape, mesh, grad_accum=ga)
    print(f"compute={res['t_compute_s']:.3e}s memory={res['t_memory_s']:.3e}s "
          f"collective={res['t_collective_s']:.3e}s -> {res['bottleneck']}")
    if u:
        print(f"NOTE: partial-unroll RAW module costs (~{u} of "
              f"{_partial_unroll(cfg) and 'n'} layer-units; NOT trip-count "
              f"extrapolated) — use repro.launch.dryrun --unroll for "
              f"step-accurate totals; this view is for comparing variants "
              f"and reading the top collectives.")
    print(f"peak/device={res['bytes_per_device']['peak']/2**30:.1f} GiB "
          f"useful_flops_ratio={res['useful_flops_ratio']:.3f} "
          f"(cost counts ~1 unit of the layer scan unless --unroll)")
    print(f"\ntop collectives (per appearance in HLO; scan bodies run "
          f"n_units x per step):")
    for nb, line in top_collectives(co.as_text(), args.top):
        print(f"  {nb/2**20:9.1f} MiB | {line[:130]}")


if __name__ == "__main__":
    main()
