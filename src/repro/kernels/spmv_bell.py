"""Pallas TPU kernel: Block-ELL SpMV / SpMM with scalar-prefetched x tiles.

The MXU-native sparse format (DESIGN.md §2): 128x128 dense blocks in an
ELL-of-blocks layout — (Mb, K, bm, bn) with K block slots per block row.
The block-column indices are *scalar-prefetched* so the BlockSpec index_map
can stream exactly the x (or X) tile each block needs from HBM into VMEM:

    y[mb*bm : (mb+1)*bm] += blocks[mb, k] @ x[bcols[mb, k]*bn : ...]

This is the systolic-array answer to the Emu migratory gather: instead of
moving a thread to the data, the index map moves exactly one x tile per
non-zero block across the memory hierarchy, and each such move feeds an
entire (bm x bn) MXU matmul — arithmetic intensity bm*bn/(bn) = bm flops
per loaded element instead of 1 for scalar CSR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bell_spmv", "bell_spmm"]


def _bell_spmv_kernel(bcols_ref, blocks_ref, xb_ref, y_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    block = blocks_ref[0, 0]                   # (bm, bn)
    xtile = xb_ref[0]                          # (bn,)
    y_ref[...] += jnp.dot(block, xtile, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bell_spmv(blocks: jnp.ndarray, bcols: jnp.ndarray, x: jnp.ndarray,
              *, interpret: bool = False) -> jnp.ndarray:
    """y = A @ x, A in Block-ELL form.

    blocks: (Mb, K, bm, bn); bcols: (Mb, K) int32; x: (Nb*bn,).
    Padded slots must carry zero blocks (bcols value then irrelevant).
    """
    Mb, K, bm, bn = blocks.shape
    xb = x.reshape(-1, bn)
    grid = (Mb, K)
    return pl.pallas_call(
        _bell_spmv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn), lambda mb, k, bc: (mb, k, 0, 0)),
                # Stream exactly the x tile this block needs.
                pl.BlockSpec((1, bn), lambda mb, k, bc: (bc[mb, k], 0)),
            ],
            out_specs=pl.BlockSpec((1, bm), lambda mb, k, bc: (mb, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mb, bm), x.dtype),
        interpret=interpret,
    )(bcols, blocks, xb).reshape(Mb * bm)


def _bell_spmm_kernel(bcols_ref, blocks_ref, Xb_ref, Y_ref):
    k = pl.program_id(2)          # grid is (Mb, B/TB, K): K innermost

    @pl.when(k == 0)
    def _init():
        Y_ref[...] = jnp.zeros_like(Y_ref)

    block = blocks_ref[0, 0]                   # (bm, bn)
    Xtile = Xb_ref[0]                          # (bn, TB)
    Y_ref[0] += jnp.dot(block, Xtile, preferred_element_type=Y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def bell_spmm(blocks: jnp.ndarray, bcols: jnp.ndarray, X: jnp.ndarray,
              *, tile_b: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Y = A @ X, A in Block-ELL form, X dense (N, B).

    Grid (Mb, B/TB, K): K innermost so each Y tile is revisited across the
    reduction with a single VMEM-resident accumulator.
    """
    Mb, K, bm, bn = blocks.shape
    N, B = X.shape
    tb = min(tile_b, B)
    if B % tb:
        raise ValueError(f"tile_b {tb} must divide B {B}")
    Xb = X.reshape(-1, bn, B)
    grid = (Mb, B // tb, K)
    return pl.pallas_call(
        _bell_spmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn), lambda mb, b, k, bc: (mb, k, 0, 0)),
                pl.BlockSpec((1, bn, tb), lambda mb, b, k, bc: (bc[mb, k], 0, b)),
            ],
            out_specs=pl.BlockSpec((1, bm, tb), lambda mb, b, k, bc: (mb, 0, b)),
        ),
        out_shape=jax.ShapeDtypeStruct((Mb, bm, B), X.dtype),
        interpret=interpret,
    )(bcols, blocks, Xb).reshape(Mb * bm, B)
