"""Serving layer: the batched LM engine and the sparse-matrix serving
engine (autotuned ingest, batched multi-RHS SpMV, feature-keyed plan cache)
plus the online rebalancing subsystem that keeps serving plans matched to
the live request mix (``rebalance.py``)."""
from .engine import Engine, ServeConfig, SparseMatrixEngine
from .rebalance import LoadMonitor, RebalanceConfig, RebalanceEvent

__all__ = ["Engine", "ServeConfig", "SparseMatrixEngine", "LoadMonitor",
           "RebalanceConfig", "RebalanceEvent"]
