"""Cache-hierarchy baseline (paper §IV-E, Fig. 12).

The paper contrasts Emu against a dual-socket Broadwell Xeon (45 MB LLC):
reorderings buy at most 12-16% there, and random *never* helps.  Two
baselines are provided:

1. ``measure_cpu_spmv`` — a *real measurement* on this container's CPU
   (a genuine cache-memory machine): CSR SpMV wall-time via numpy vectorized
   gather+segment-sum, averaged over trials, exactly the paper's metric
   (effective MB/s).
2. ``analytic_cache_model`` — the reasoning the paper gives: performance is
   governed by cache-line reuse of x; a miss costs ~100-200x an L1 hit, so
   locality (banding) helps modestly and random destroys it.
3. ``analytic_tile_cache_model`` — the same hierarchy walked by the
   bitmask-tiled format instead of the scalar CSR gather: x moves in
   lane-aligned ``bn``-element tiles (whole contiguous lines per
   occupied tile, reuse measured at tile granularity over the block-row-
   major walk) and the data stream carries **no colIndex companion** —
   at the price of walking every cell of every occupied tile, padding
   included.  On a banded matrix the tile walk's effective bandwidth
   beats the scalar gather's; on a scattered one the padded cells sink
   it — the cache-side mirror of the kernel-slot trade the per-shard
   selector makes.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .sparse_matrix import CSRMatrix, csr_row_nnz

__all__ = ["CpuSpmvResult", "measure_cpu_spmv", "analytic_cache_model",
           "analytic_tile_cache_model"]


@dataclasses.dataclass(frozen=True)
class CpuSpmvResult:
    seconds: float
    bandwidth_mbs: float
    gflops: float


def _csr_spmv_numpy(csr: CSRMatrix, x: np.ndarray) -> np.ndarray:
    """Row-segment CSR SpMV; gathers of x hit the cache hierarchy like the
    paper's C implementation (the access pattern, not the FLOPs, dominates).
    """
    contrib = csr.values * x[csr.col_index]
    # segment sum by row via reduceat (rows with zero nnz handled after)
    starts = csr.row_ptr[:-1]
    out = np.add.reduceat(np.concatenate([contrib, [0.0]]), np.minimum(starts, csr.nnz))
    out[csr_row_nnz(csr) == 0] = 0.0
    return out[: csr.nrows]


def measure_cpu_spmv(csr: CSRMatrix, *, trials: int = 10, warmup: int = 2) -> CpuSpmvResult:
    rng = np.random.default_rng(0)
    x = rng.standard_normal(csr.ncols)
    for _ in range(warmup):
        _csr_spmv_numpy(csr, x)
    t0 = time.perf_counter()
    for _ in range(trials):
        _csr_spmv_numpy(csr, x)
    dt = (time.perf_counter() - t0) / trials
    useful = 8.0 * (3 * csr.nnz + 2 * csr.nrows)
    return CpuSpmvResult(seconds=dt, bandwidth_mbs=useful / dt / 1e6,
                         gflops=2.0 * csr.nnz / dt / 1e9)


def analytic_cache_model(csr: CSRMatrix, *, line_elems: int = 8,
                         llc_bytes: int = 45 * 2**20,
                         hit_cycles: float = 4.0,
                         miss_cycles: float = 400.0,
                         clock_hz: float = 2.4e9) -> float:
    """Estimated bandwidth (MB/s) from x-reuse distance over cache lines.

    A load of x[j] hits if line j//line_elems was touched recently (within
    the LLC working window).  Streaming arrays (values/colIndex/b) are
    prefetch-friendly: 1/line_elems misses per element.
    """
    cols = csr.col_index // line_elems
    window = llc_bytes // 64
    last = {}
    misses = 0
    step = max(csr.nnz // 2_000_000, 1)      # sample for very large matrices
    sampled = cols[::step]
    for i, c in enumerate(sampled):
        prev = last.get(c)
        if prev is None or i - prev > window:
            misses += 1
        last[c] = i
    miss_rate = misses / max(sampled.size, 1)
    per_nnz = (2.0 / line_elems + 1.0) * hit_cycles + \
        miss_rate * miss_cycles + (1 - miss_rate) * hit_cycles
    cycles = csr.nnz * per_nnz
    seconds = cycles / clock_hz
    useful = 8.0 * (3 * csr.nnz + 2 * csr.nrows)
    return useful / seconds / 1e6


def analytic_tile_cache_model(csr: CSRMatrix, *, bm: int = 8, bn: int = 128,
                              line_elems: int = 8,
                              llc_bytes: int = 45 * 2**20,
                              hit_cycles: float = 4.0,
                              miss_cycles: float = 400.0,
                              clock_hz: float = 2.4e9) -> float:
    """Estimated bandwidth (MB/s) of the bitmask-tiled walk on the same
    hierarchy as :func:`analytic_cache_model` (same useful-byte metric,
    so the two numbers compare directly, Fig. 12-style).

    Two differences from the scalar CSR gather: (1) the data stream is
    pure — one value per walked cell, no colIndex element riding along —
    and prefetch-friendly at ``1/line_elems`` misses per cell; (2) x is
    touched one lane-aligned ``bn``-element tile at a time (whole
    contiguous cache lines), with reuse measured at *tile* granularity
    over the block-row-major occupied-tile walk — sequential streaming
    through a band re-touches the same few x tiles, where the scalar
    gather re-pays a reuse-distance check per nonzero.  The price is
    padding: every cell of every occupied tile is walked, so a
    scattered matrix (one nonzero per tile) walks ``bm * bn`` cells per
    nonzero and the effective bandwidth collapses — tile's loss case,
    exactly as in :func:`~repro.core.plan.kernel_shard_costs`.
    """
    rows_of = np.repeat(np.arange(csr.nrows), csr_row_nnz(csr))
    Nb = max(-(-csr.ncols // bn), 1)
    key = (rows_of // bm).astype(np.int64) * Nb + csr.col_index // bn
    tiles = np.unique(key)                    # block-row-major walk order
    bcols = tiles % Nb
    window = llc_bytes // (bn * 8)            # x tiles resident in the LLC
    last: dict[int, int] = {}
    misses = 0
    for i, c in enumerate(bcols):
        prev = last.get(int(c))
        if prev is None or i - prev > window:
            misses += 1
        last[int(c)] = i
    T = max(tiles.size, 1)
    lines_per_tile = max(bn // line_elems, 1)
    x_cycles = lines_per_tile * (misses * miss_cycles
                                 + (T - misses) * hit_cycles)
    data_cycles = T * bm * bn / line_elems * hit_cycles
    b_cycles = 2.0 * csr.nrows / line_elems * hit_cycles
    seconds = (data_cycles + x_cycles + b_cycles) / clock_hz
    useful = 8.0 * (3 * csr.nnz + 2 * csr.nrows)
    return useful / seconds / 1e6
