"""qwen2.5-32b [dense] — hf:Qwen/Qwen2.5-0.5B family card.  GQA kv=8,
QKV bias, SwiGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=27648,
    vocab_size=152_064, activation="swiglu", qkv_bias=True,
    rope_theta=1_000_000.0)

def smoke_config():
    return ModelConfig(
        name="qwen2.5-32b-smoke", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, activation="swiglu", qkv_bias=True)
