"""Synthetic generators matched to the paper's Table I matrix suite.

The UF Sparse Matrix Collection is not available offline, so each matrix is
re-synthesized to match the *structural properties the paper's analysis
depends on*: dimensions, nnz, density, symmetry, and — critically — the spy
pattern (Fig. 4) that drives layout/migration behaviour:

* ford1        18k^2,   100k  — narrow banded FEM mesh
* cop20k_A     120k^2,  2.6M  — banded + a dense column arrowhead: ~25% of
                                all nnz hit columns owned by shard 0, the
                                exact hot-spot condition of §IV-D
* webbase-1M   1M^2,    3.1M  — power-law rows/cols, scattered
* rmat         445k^2,  7.4M  — RMAT(a,b,c) = (0.45, 0.22, 0.22) per paper
* nd24k        72k^2,   28.7M — dense diagonal blocks (3D ND mesh)
* audikw_1     943k^2,  77.6M — wide-band FEM

``scale`` shrinks dims and nnz together (pattern-preserving) so the Emu
timeline simulator stays cheap; migration *counting* runs full-scale.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.sparse_matrix import CSRMatrix, csr_from_coo

__all__ = ["PAPER_SUITE", "make_matrix", "banded", "arrow_fem", "powerlaw",
           "rmat", "dense_blocks", "mixed_structure", "powerlaw_tail",
           "halo_spikes", "blocked_band"]


def _finish(rows, cols, vals, M, symmetric: bool) -> CSRMatrix:
    keep = (rows >= 0) & (rows < M) & (cols >= 0) & (cols < M)
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
        vals = np.concatenate([vals, vals])
    return csr_from_coo(rows, cols, vals, (M, M))


def banded(M: int, nnz: int, bandwidth: int, *, seed: int = 0,
           symmetric: bool = True, scatter_frac: float = 0.12) -> CSRMatrix:
    """Banded FEM-like pattern.  ``scatter_frac`` of entries land off-band
    (real FEM matrices are never perfectly banded — this keeps the
    block-layout migration ratio in the paper's 1.42-6.3x range)."""
    rng = np.random.default_rng(seed)
    n = nnz if not symmetric else nnz // 2 + M
    rows = rng.integers(0, M, n)
    off = rng.integers(-bandwidth, bandwidth + 1, n)
    cols = rows + off
    n_sc = int(n * scatter_frac)
    if n_sc:
        cols[:n_sc] = rng.integers(0, M, n_sc)
    vals = rng.standard_normal(n)
    # Always include the diagonal (FEM matrices have one).
    rows = np.concatenate([rows, np.arange(M)])
    cols = np.concatenate([cols, np.arange(M)])
    vals = np.concatenate([vals, np.ones(M)])
    return _finish(rows, cols, vals, M, symmetric)


def arrow_fem(M: int, nnz: int, *, hot_frac: float = 0.125,
              dense_boost: float = 3.7, seed: int = 0) -> CSRMatrix:
    """cop20k_A-like: FEM mesh whose *original ordering* concentrates ~25%
    of all x-accesses on the first ``hot_frac`` of columns (§IV-D), while the
    underlying graph stays mesh-local so BFS/METIS can re-band it.

    Construction: a 1-D band mesh where vertices in a refined region (the
    first ``hot_frac`` of mesh space) carry ``dense_boost``x edges; the
    refined vertices keep indices [0, hot_frac*M) but *all other vertices are
    scattered randomly* — so in matrix order the refined columns are
    referenced from rows everywhere (hot-spot), yet a BFS recovers the mesh
    band.  This matches the paper's observation that reordering fixes
    cop20k_A: its hot-spot is an ordering artifact, not intrinsic hubness.
    """
    rng = np.random.default_rng(seed)
    stride = max(int(round(1.0 / hot_frac)), 2)          # refined = every 8th
    refined = (np.arange(M) % stride) == 0               # in mesh space
    n_edges = nnz // 2
    boost = dense_boost
    k = max(int(n_edges / (M * (1.0 + (boost - 1.0) / stride))), 1)
    counts = np.where(refined, int(k * boost), k).astype(np.int64)
    window = max(M // 64, 8)
    src = np.repeat(np.arange(M), counts)
    dst = src + rng.integers(1, window + 1, src.shape[0])
    ok = dst < M
    src, dst = src[ok], dst[ok]
    # Renumber: refined vertices take the leading index block (the hot
    # columns), everyone else follows in mesh order.
    perm = np.empty(M, dtype=np.int64)
    perm[refined] = np.arange(int(refined.sum()))
    perm[~refined] = int(refined.sum()) + np.arange(int((~refined).sum()))
    src, dst = perm[src], perm[dst]
    rows = np.concatenate([src, np.arange(M)])
    cols = np.concatenate([dst, np.arange(M)])
    vals = rng.standard_normal(rows.shape[0])
    return _finish(rows, cols, vals, M, symmetric=True)


def halo_spikes(M: int, nnz: int, *, n_broad: int | None = None,
                bandwidth: int = 8, broad_frac: float = 0.55,
                seed: int = 0) -> CSRMatrix:
    """Exchange-bound workload: a tight local band plus *broad-reader* rows.

    The background is a narrow band (offsets within ``bandwidth``), so
    under a contiguous row partition almost every background row reads
    only columns its own shard owns — local-slice work the pipelined
    executor can run while the exchange is in flight.  On top of it,
    ``n_broad`` rows (spread evenly over the row range, so every shard
    owns a few) each gather ``broad_frac`` of the nnz budget from
    uniform-random columns across the whole index range.  Each shard's
    unique remote-column set is then large (the broad rows' gathers)
    while its remote *rows* are few — the regime where the exchange term
    rivals the kernel term and overlap pays, unlike ``mixed_structure``
    (short scattered rows: every row slightly remote, nothing to hide
    the exchange behind) or ``powerlaw_tail`` (uniform scattered
    background, no local slice at all).
    """
    rng = np.random.default_rng(seed)
    if n_broad is None:
        n_broad = max(M // 128, 8)
    n_brd = int(nnz * broad_frac)
    n_bg = max(nnz - n_brd - M, 0)
    bg_rows = rng.integers(0, M, n_bg)
    bg_cols = np.clip(bg_rows + rng.integers(-bandwidth, bandwidth + 1,
                                             n_bg), 0, M - 1)
    broad_ids = (np.arange(n_broad) * M) // n_broad + M // (2 * n_broad)
    brd_rows = np.repeat(broad_ids, n_brd // n_broad)
    brd_cols = rng.integers(0, M, brd_rows.shape[0])
    rows = np.concatenate([bg_rows, brd_rows, np.arange(M)])
    cols = np.concatenate([bg_cols, brd_cols, np.arange(M)])
    vals = np.concatenate([rng.standard_normal(n_bg + brd_rows.shape[0]),
                           np.ones(M)])
    return _finish(rows, cols, vals, M, symmetric=False)


def powerlaw(M: int, nnz: int, *, alpha: float = 1.8, hub_frac: float = 0.4,
             seed: int = 0) -> CSRMatrix:
    """webbase-like scattered power-law: a uniform background plus a
    zipf-weighted hub component on scattered row/col ids (non-symmetric)."""
    rng = np.random.default_rng(seed)
    n_hub = int(nnz * hub_frac)
    n_uni = nnz - n_hub
    perm_r, perm_c = rng.permutation(M), rng.permutation(M)
    rows = np.concatenate([rng.integers(0, M, n_uni),
                           perm_r[rng.zipf(alpha, n_hub) % M]])
    cols = np.concatenate([rng.integers(0, M, n_uni),
                           perm_c[rng.zipf(alpha, n_hub) % M]])
    vals = rng.standard_normal(nnz)
    rows = np.concatenate([rows, np.arange(M)])
    cols = np.concatenate([cols, np.arange(M)])
    vals = np.concatenate([vals, np.ones(M)])
    return _finish(rows, cols, vals, M, symmetric=False)


def rmat(M: int, nnz: int, *, a: float = 0.45, b: float = 0.22, c: float = 0.22,
         seed: int = 0) -> CSRMatrix:
    """RMAT with the paper's (a, b, c) = (0.45, 0.22, 0.22)."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(M, 2))))
    size = 1 << scale
    rows = np.zeros(nnz, dtype=np.int64)
    cols = np.zeros(nnz, dtype=np.int64)
    p = np.array([a, b, c, 1.0 - a - b - c])
    for level in range(scale):
        quad = rng.choice(4, size=nnz, p=p)
        half = size >> (level + 1)
        rows += np.where((quad == 2) | (quad == 3), half, 0)
        cols += np.where((quad == 1) | (quad == 3), half, 0)
    keep = (rows < M) & (cols < M)
    vals = rng.standard_normal(nnz)
    return _finish(rows[keep], cols[keep], vals[keep], M, symmetric=False)


def dense_blocks(M: int, nnz: int, *, nblocks: int = 24, seed: int = 0) -> CSRMatrix:
    """nd24k-like: dense clusters on the diagonal (high density FEM)."""
    rng = np.random.default_rng(seed)
    n = nnz // 2
    starts = np.sort(rng.integers(0, M, nblocks))
    bsize = max(M // nblocks, 8)
    blk = rng.integers(0, nblocks, n)
    r = starts[blk] + rng.integers(0, bsize, n)
    c = starts[blk] + rng.integers(0, bsize, n)
    n_sc = int(n * 0.08)                     # off-block scatter (see banded)
    if n_sc:
        c[:n_sc] = rng.integers(0, M, n_sc)
    vals = rng.standard_normal(n)
    rows = np.concatenate([r, np.arange(M)])
    cols = np.concatenate([c, np.arange(M)])
    vals = np.concatenate([vals, np.ones(M)])
    return _finish(rows, cols, vals, M, symmetric=True)


def _to_coo(csr: CSRMatrix):
    rows = np.repeat(np.arange(csr.nrows), np.diff(csr.row_ptr))
    return rows, csr.col_index.astype(np.int64), csr.values


def mixed_structure(M: int, nnz: int, *, band_frac: float = 0.2,
                    band_nnz_frac: float = 0.8, couple_frac: float = 0.005,
                    zipf_a: float = 2.2, seed: int = 0) -> CSRMatrix:
    """Mixed-structure matrix: dense-banded block ⊕ short-row sparse block.

    Rows [0, band_frac*M) form a *dense* FEM-style band (uniform,
    ~lane-width rows — the regular structure a padded ELL slab executes
    with almost no waste); rows [band_frac*M, M) form a scattered sparse
    block with zipf-skewed **row lengths** (webbase-like short rows, mean
    a few nnz) but *uniform column targets* — the structure where the
    nonzero-balanced segmented format wins and the 128-lane ELL/HYB slab
    floor loses, without introducing the hot *columns* that would make a
    global reordering the dominant fix.  A light random coupling
    (``couple_frac`` of nnz) keeps the matrix connected.  Under a
    contiguous row partition the two regimes land on *different shards*,
    which is exactly the case where one global kernel choice provably
    loses to per-shard selection (``benchmarks/hetero_bench.py``).
    """
    rng = np.random.default_rng(seed)
    hb = min(max(int(M * band_frac), 8), M - 8)
    n_band = int(nnz * band_nnz_frac)
    n_sp = max(nnz - n_band, 8)
    # Dense band: bandwidth sized so each row carries ~n_band/hb entries.
    bw = max(n_band // (2 * hb), 4)
    B1 = banded(hb, n_band, bw, seed=seed, scatter_frac=0.03)
    r1, c1, v1 = _to_coo(B1)
    # Sparse block: zipf row lengths (skewed), uniform scattered columns.
    m_sp = M - hb
    counts = np.minimum(rng.zipf(zipf_a, m_sp), m_sp)
    counts = np.maximum((counts * (n_sp / max(counts.sum(), 1))), 1.0)
    counts = counts.astype(np.int64)
    r2 = hb + np.repeat(np.arange(m_sp), counts)
    c2 = hb + rng.integers(0, m_sp, r2.shape[0])
    v2 = rng.standard_normal(r2.shape[0])
    n_cp = int(nnz * couple_frac)
    rows = np.concatenate([r1, r2, rng.integers(0, M, n_cp),
                           np.arange(M)])
    cols = np.concatenate([c1, c2, rng.integers(0, M, n_cp),
                           np.arange(M)])
    vals = np.concatenate([v1, v2, rng.standard_normal(n_cp), np.ones(M)])
    return csr_from_coo(rows, cols, vals, (M, M))


def blocked_band(M: int, nnz: int, *, band_frac: float = 0.75,
                 tiles_min: int = 1, tiles_max: int = 4, bm: int = 8,
                 bn: int = 128, seed: int = 0) -> CSRMatrix:
    """Blocked-band matrix: (8, 128)-aligned dense tiles ⊕ scattered rows.

    Rows [0, hb) are a *tile-aligned* band: each 8-row block carries
    between ``tiles_min`` and ``tiles_max`` fully dense (bm, bn) tiles
    placed along the diagonal — the structure the bitmask-tiled format
    stores with zero waste.  The per-block tile count *varies*, so the
    padded ELL slab pays the shard-wide max width (a 4-tile block widens
    every row's slab to 512) while tile pays only the occupied tiles;
    the nnz-balanced seg stream pays its scan/bookkeeping tax on rows
    that are perfectly regular.  Rows [hb, M) are a short-row scattered
    block (columns within the scattered range, so the two regimes land
    on different shards under a contiguous partition) where a stray
    nonzero would drag a whole 1024-cell tile in — the shards the
    per-shard selector must steer *away* from tile.  This is the
    ``hetero_bench --workload blocked`` headline matrix: the best
    tile-using per-shard program beats every tile-free program on the
    kernel-slot term.
    """
    rng = np.random.default_rng(seed)
    n_band = int(nnz * band_frac)
    per_tile = bm * bn
    avg_tiles = (tiles_min + tiles_max) / 2.0
    n_blk = int(min(max(n_band / (per_tile * avg_tiles), 1), M // bm))
    hb = n_blk * bm
    Nb = max(M // bn, 1)
    k = rng.integers(tiles_min, tiles_max + 1, n_blk)
    tb_row = np.repeat(np.arange(n_blk), k)
    offs = np.concatenate([np.arange(ki) for ki in k]) if n_blk else \
        np.zeros(0, np.int64)
    tb_col = np.clip((tb_row * bm) // bn + offs, 0, Nb - 1)
    T = tb_row.size
    lr = np.tile(np.repeat(np.arange(bm), bn), T)
    lc = np.tile(np.arange(bn), T * bm)
    r1 = np.repeat(tb_row * bm, per_tile) + lr
    c1 = np.repeat(tb_col * bn, per_tile) + lc
    v1 = rng.standard_normal(r1.size)
    m_sp = M - hb
    if m_sp > 0:
        kk = max((nnz - n_band) // m_sp, 1)
        r2 = hb + np.repeat(np.arange(m_sp), kk)
        c2 = hb + rng.integers(0, m_sp, r2.shape[0])
        v2 = rng.standard_normal(r2.shape[0])
    else:
        r2 = c2 = np.zeros(0, np.int64)
        v2 = np.zeros(0)
    rows = np.concatenate([r1, r2, np.arange(M)])
    cols = np.concatenate([c1, c2, np.arange(M)])
    vals = np.concatenate([v1, v2, np.ones(M)])
    return csr_from_coo(rows, cols, vals, (M, M))


def powerlaw_tail(M: int, nnz: int, *, n_monster: int = 8,
                  monster_frac: float = 0.5, seed: int = 0) -> CSRMatrix:
    """Power-law-tail matrix: a handful of *monster rows* ⊕ a uniform
    short-row background — the paper's §IV-D hot-spot distilled.

    Rows [0, n_monster) are fully dense (distinct columns across the
    whole width, so duplicate-summing cannot thin them) and together hold
    ~``monster_frac`` of the nnz budget; the remaining rows carry a
    uniform ~``(1-monster_frac)*nnz/(M-n_monster)`` nnz each.  Under a
    nonzero-balanced partition a shard ends up owning only a couple of
    monster rows — the degenerate case where the seg carry chain
    serializes and the split-nnz two-stage kernel is the cure
    (``benchmarks/hetero_bench.py --workload powerlaw_tail``).
    """
    rng = np.random.default_rng(seed)
    n_monster = max(min(n_monster, M // 4), 1)
    r1 = np.repeat(np.arange(n_monster, dtype=np.int64), M)
    c1 = np.tile(np.arange(M, dtype=np.int64), n_monster)
    v1 = rng.standard_normal(r1.shape[0])
    n_sp = max(int(nnz * (1.0 - monster_frac)), M)
    k = max(n_sp // max(M - n_monster, 1), 1)
    r2 = np.repeat(np.arange(n_monster, M, dtype=np.int64), k)
    c2 = rng.integers(0, M, r2.shape[0])
    v2 = rng.standard_normal(r2.shape[0])
    rows = np.concatenate([r1, r2, np.arange(M)])
    cols = np.concatenate([c1, c2, np.arange(M)])
    vals = np.concatenate([v1, v2, np.ones(M)])
    return csr_from_coo(rows, cols, vals, (M, M))


# name -> (M, nnz, builder)
PAPER_SUITE: Dict[str, tuple[int, int, Callable[..., CSRMatrix]]] = {
    "ford1":      (18_000,  100_000,
                   lambda M, nnz, seed: banded(M, nnz, max(M // 400, 4), seed=seed)),
    "cop20k_A":   (120_000, 2_600_000,
                   lambda M, nnz, seed: arrow_fem(M, nnz, seed=seed)),
    "webbase-1M": (1_000_000, 3_100_000,
                   lambda M, nnz, seed: powerlaw(M, nnz, seed=seed)),
    "rmat":       (445_000, 7_400_000,
                   lambda M, nnz, seed: rmat(M, nnz, seed=seed)),
    "nd24k":      (72_000, 28_700_000,
                   lambda M, nnz, seed: dense_blocks(M, nnz, seed=seed)),
    "audikw_1":   (943_000, 77_600_000,
                   lambda M, nnz, seed: banded(M, nnz, max(M // 100, 8), seed=seed)),
}


def make_matrix(name: str, *, scale: float = 1.0, seed: int = 0) -> CSRMatrix:
    """Build a suite matrix, optionally pattern-preserving scaled down."""
    M, nnz, builder = PAPER_SUITE[name]
    M = max(int(M * scale), 64)
    nnz = max(int(nnz * scale), 4 * M)
    return builder(M, nnz, seed)
