"""Per-kernel tests: Pallas (interpret=True) vs pure-jnp oracle vs dense.

Shape/dtype sweeps + hypothesis property tests, per the assignment brief.
``hypothesis`` is an optional extra: without it only the property-test
class is skipped — the sweep tests always collect and run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):            # no-op stand-ins so the decorated
        return lambda f: f           # (skipped) class still defines

    def settings(*_a, **_k):
        return lambda f: f

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.sparse_matrix import csr_from_coo, csr_matvec, csr_to_bcsr, \
    csr_to_dense, csr_to_ell
from repro.data.matrices import powerlaw, powerlaw_tail
from repro.kernels import ops, ref


def _np_slab_oracle(vals, cols, rows, x, num_rows):
    """Float64 numpy ground truth for any seg/split-style (..., L) slab:
    scatter-add every slot into its output row.  Padded slots carry
    ``val == 0`` so they contribute exactly nothing."""
    y = np.zeros(num_rows, np.float64)
    np.add.at(y, np.asarray(rows).reshape(-1),
              (np.asarray(vals, np.float64) *
               np.asarray(x, np.float64)[np.asarray(cols)]).reshape(-1))
    return y


def rand_problem(M, N, nnz, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    A = csr_from_coo(rng.integers(0, M, nnz), rng.integers(0, N, nnz),
                     rng.standard_normal(nnz), (M, N))
    x = rng.standard_normal(N).astype(dtype)
    return A, x


class TestEllKernel:
    @pytest.mark.parametrize("M,N,nnz", [(8, 128, 50), (64, 256, 900),
                                         (256, 512, 5000)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_oracle_and_dense(self, M, N, nnz, dtype):
        A, x = rand_problem(M, N, nnz)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data, dtype), jnp.asarray(e.cols)
        xj = jnp.asarray(x, dtype)
        y_ref = ref.ell_spmv_ref(data, cols, xj)
        y_pal = ops.ell_spmv(data, cols, xj, interpret=True,
                             tile_m=8, tile_w=128)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y_pal)[:M],
                                   csr_to_dense(A) @ x, rtol=1e-3, atol=1e-3)

    def test_tile_sweep(self):
        A, x = rand_problem(64, 256, 1500, seed=3)
        e = csr_to_ell(A)
        data, cols, xj = map(jnp.asarray, (e.data, e.cols, x))
        base = None
        for tm in (8, 16, 32, 64):
            for tw in (128, e.data.shape[1]):
                y = np.asarray(ops.ell_spmv(data, cols, xj, interpret=True,
                                            tile_m=tm, tile_w=tw))
                if base is None:
                    base = y
                np.testing.assert_allclose(y, base, rtol=1e-5)

    def test_hyb_overflow_path(self):
        A, x = rand_problem(128, 128, 4000, seed=5)
        e = csr_to_ell(A, lane=8, max_width=8)
        assert e.overflow_vals.size > 0
        y = ops.hyb_spmv(*map(jnp.asarray, (e.data, e.cols, e.overflow_rows,
                                            e.overflow_cols, e.overflow_vals,
                                            x)))
        np.testing.assert_allclose(np.asarray(y)[:128], csr_to_dense(A) @ x,
                                   rtol=1e-3, atol=1e-3)

    def test_batched_matches_per_vector(self):
        """Multi-RHS (N, B): every column equals its per-vector run, for
        the oracle and for the (vmapped) Pallas kernel path."""
        A, _ = rand_problem(64, 256, 900, seed=7)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data), jnp.asarray(e.cols)
        X = np.random.default_rng(7).standard_normal((256, 3)) \
            .astype(np.float32)
        Y_ref = np.asarray(ref.ell_spmv_ref(data, cols, jnp.asarray(X)))
        Y_pal = np.asarray(ops.ell_spmv(data, cols, jnp.asarray(X),
                                        interpret=True, tile_m=8,
                                        tile_w=128))
        assert Y_ref.shape == (e.data.shape[0], 3)
        for b in range(3):
            # fp32 XLA reductions may re-associate across batch widths, so
            # the jnp paths are compared at tight tolerance (the *numpy*
            # serving path, local_spmv, is the bitwise-exact one — see
            # tests/test_serve_engine.py).
            np.testing.assert_allclose(
                Y_ref[:, b],
                np.asarray(ref.ell_spmv_ref(data, cols,
                                            jnp.asarray(X[:, b]))),
                rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(
                Y_pal[:, b],
                np.asarray(ops.ell_spmv(data, cols, jnp.asarray(X[:, b]),
                                        interpret=True, tile_m=8,
                                        tile_w=128)),
                rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(Y_ref[:64], csr_to_dense(A) @ X,
                                   rtol=1e-3, atol=1e-3)


class TestTileKernel:
    """Bitmask-tiled SpMV: pointer-grid walk (oracle + Pallas interpret)
    vs dense, the occupancy bitmask, the flat device path, and the
    deprecated Block-ELL shims that now route through it."""

    @pytest.mark.parametrize("bm,bn", [(8, 128), (16, 128)])
    def test_spmv_matches(self, bm, bn):
        A, x = rand_problem(256, 256, 3000, seed=1)
        t = ops.tile_from_csr(A, bm=bm, bn=bn)
        xj = jnp.asarray(x)
        y_ref = ops.tile_spmv(t, xj)
        y_pal = ops.tile_spmv(t, xj, use_kernel=True, interpret=True)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_pal)[:256],
                                   csr_to_dense(A) @ x, rtol=1e-3, atol=1e-3)

    def test_batched_matches_per_vector(self):
        A, _ = rand_problem(256, 256, 2000, seed=2)
        X = np.random.default_rng(7).standard_normal((256, 3)) \
            .astype(np.float32)
        t = ops.tile_from_csr(A)
        Y_ref = np.asarray(ops.tile_spmv(t, jnp.asarray(X)))
        Y_pal = np.asarray(ops.tile_spmv(t, jnp.asarray(X),
                                         use_kernel=True, interpret=True))
        assert Y_ref.shape == (256, 3)
        for b in range(3):
            np.testing.assert_allclose(
                Y_ref[:, b],
                np.asarray(ops.tile_spmv(t, jnp.asarray(X[:, b]))),
                rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(Y_pal, Y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(Y_ref[:256], csr_to_dense(A) @ X,
                                   rtol=1e-3, atol=1e-3)

    def test_bitmask_counts_stored_entries_and_ptr_grid_is_sorted(self):
        """The packed occupancy mask records *stored* entries (stored
        zeros included, structural zeros excluded), and the coarse
        pointer level walks tiles block-row-major, sorted by block col."""
        rng = np.random.default_rng(3)
        n = 1500
        rows, cols = rng.integers(0, 256, n), rng.integers(0, 256, n)
        vals = rng.standard_normal(n)
        vals[:10] = 0.0                       # explicit stored zeros
        A = csr_from_coo(rows, cols, vals, (256, 256))
        t = ops.tile_from_csr(A)
        occ = t.occupancy()
        assert int(occ.sum()) == A.nnz == t.nnz
        # stored zeros occupy cells the dense payload cannot distinguish
        assert int((t.data != 0).sum()) < t.nnz
        assert t.tile_ptr[0] == 0 and t.tile_ptr[-1] == t.num_tiles
        for mb in range(t.tile_ptr.size - 1):
            lo, hi = int(t.tile_ptr[mb]), int(t.tile_ptr[mb + 1])
            assert (t.tile_rows[lo:hi] == mb).all()
            assert (np.diff(t.tile_cols[lo:hi]) > 0).all()

    def test_flat_path_matches_structured(self):
        """``tile_flat_spmv`` (pre-gathered per-lane x positions + block
        rows, the device-path operands) agrees with the structured walk,
        padding tiles dropping past the last block row."""
        A, x = rand_problem(256, 256, 3000, seed=4)
        t = ops.tile_from_csr(A)
        Tn, Rb = t.num_tiles, -(-256 // t.bm)
        Tp = Tn + 3                           # padding tiles must drop
        data = np.zeros((Tp, t.bm, t.bn), np.float32)
        data[:Tn] = t.data
        xcols = np.zeros((Tp, t.bn), np.int32)
        xcols[:Tn] = np.minimum(
            t.tile_cols[:, None] * t.bn + np.arange(t.bn)[None, :], 255)
        trows = np.full(Tp, Rb, np.int32)
        trows[:Tn] = t.tile_rows
        for use_kernel in (False, True):
            y = np.asarray(ops.tile_flat_spmv(
                jnp.asarray(data), jnp.asarray(xcols), jnp.asarray(trows),
                jnp.asarray(x), num_rows=256, use_kernel=use_kernel,
                interpret=use_kernel))
            np.testing.assert_allclose(
                y, np.asarray(ops.tile_spmv(t, jnp.asarray(x))),
                rtol=1e-5, atol=1e-5)

    def test_empty_matrix_is_noop(self):
        E = csr_from_coo(np.zeros(0, int), np.zeros(0, int), np.zeros(0),
                         (16, 16))
        t = ops.tile_from_csr(E)
        assert t.num_tiles == 0
        y = np.asarray(ops.tile_spmv(t, jnp.zeros(16, jnp.float32)))
        assert y.shape == (16,) and not y.any()

    @pytest.mark.parametrize("B,tb", [(128, 128), (256, 128)])
    def test_deprecated_bell_shims_warn_once_and_match(self, B, tb):
        """The retired Block-ELL API stays importable: ``bell_*`` warn
        (once per process) and route through the tile walk, matching the
        kept ``ref.bell_*_ref`` oracles and dense."""
        from repro.core.spmv import _DEPRECATION_WARNED
        A, x = rand_problem(256, 256, 2000, seed=2)
        _DEPRECATION_WARNED.discard("bell_from_bcsr")
        with pytest.warns(DeprecationWarning, match="tile_from_csr"):
            blocks, bcols = ops.bell_from_bcsr(csr_to_bcsr(A, (8, 128)))
        bj, cj = jnp.asarray(blocks), jnp.asarray(bcols)
        _DEPRECATION_WARNED.discard("bell_spmv")
        with pytest.warns(DeprecationWarning, match="tile_spmv"):
            y = ops.bell_spmv(bj, cj, jnp.asarray(x), use_kernel=True,
                              interpret=True)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.bell_spmv_ref(bj, cj,
                                                        jnp.asarray(x))),
            rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y)[:256], csr_to_dense(A) @ x,
                                   rtol=1e-3, atol=1e-3)
        X = np.random.default_rng(7).standard_normal((256, B)) \
            .astype(np.float32)
        _DEPRECATION_WARNED.discard("bell_spmm")
        with pytest.warns(DeprecationWarning, match="tile_spmv"):
            Y = ops.bell_spmm(bj, cj, jnp.asarray(X), use_kernel=True,
                              interpret=True, tile_b=tb)
        np.testing.assert_allclose(np.asarray(Y)[:256], csr_to_dense(A) @ X,
                                   rtol=1e-3, atol=1e-3)


class TestSegKernel:
    """Nonzero-balanced segmented SpMV: kernel vs oracle vs dense."""

    @pytest.mark.parametrize("M,nnz", [(512, 4000), (2048, 16000)])
    def test_matches_oracle_and_dense_on_powerlaw(self, M, nnz):
        """Skewed power-law matrix (max-row-nnz >> mean): the load-balance
        case the row-tiled ELL kernel handles worst."""
        A = powerlaw(M, nnz, seed=3)
        row_nnz = np.diff(A.row_ptr)
        assert row_nnz.max() > 5 * row_nnz.mean()       # genuinely skewed
        x = jnp.asarray(np.random.default_rng(0).standard_normal(M),
                        jnp.float32)
        seg = ops.seg_from_csr(A)
        y_ref = np.asarray(ops.seg_spmv(seg, x))
        y_pal = np.asarray(ops.seg_spmv(seg, x, use_kernel=True,
                                        interpret=True))
        np.testing.assert_allclose(y_pal, y_ref, rtol=1e-5, atol=1e-5)
        dense = csr_to_dense(A) @ np.asarray(x)
        np.testing.assert_allclose(y_ref, dense, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(y_pal, dense, rtol=1e-4, atol=1e-5)

    def test_row_spanning_many_chunks(self):
        """One dense row (nnz >> chunk) must sum one carry per chunk."""
        rng = np.random.default_rng(1)
        M = 512
        r = np.concatenate([np.zeros(5000, int), rng.integers(1, M, 1000)])
        c = rng.integers(0, M, 6000)
        A = csr_from_coo(r, c, rng.standard_normal(6000), (M, M))
        x = jnp.asarray(rng.standard_normal(M), jnp.float32)
        seg = ops.seg_from_csr(A, chunk=128)
        assert np.diff(A.row_ptr)[0] > 3 * seg.chunk    # spans >= 4 chunks
        y = np.asarray(ops.seg_spmv(seg, x, use_kernel=True, interpret=True))
        np.testing.assert_allclose(y, csr_to_dense(A) @ np.asarray(x),
                                   rtol=1e-4, atol=1e-5)

    def test_chunk_and_tile_sweep(self):
        A = powerlaw(1024, 8000, seed=5)
        x = jnp.asarray(np.random.default_rng(2).standard_normal(1024),
                        jnp.float32)
        base = None
        for chunk in (128, 256, 512):
            seg = ops.seg_from_csr(A, chunk=chunk)
            for tc in (1, 2, 8):
                if seg.num_chunks % tc:
                    continue
                y = np.asarray(ops.seg_spmv(seg, x, use_kernel=True,
                                            interpret=True, tile_c=tc))
                if base is None:
                    base = y
                np.testing.assert_allclose(y, base, rtol=1e-5, atol=1e-5)

    def test_empty_rows_and_empty_matrix(self):
        A = csr_from_coo([1, 1, 5], [0, 3, 2], [1.0, 2.0, 3.0], (8, 8))
        x = jnp.asarray(np.arange(8, dtype=np.float32))
        seg = ops.seg_from_csr(A)
        y = np.asarray(ops.seg_spmv(seg, x, use_kernel=True, interpret=True))
        np.testing.assert_allclose(y, csr_to_dense(A) @ np.asarray(x),
                                   atol=1e-6)
        E = csr_from_coo(np.zeros(0, int), np.zeros(0, int), np.zeros(0),
                         (16, 16))
        se = ops.seg_from_csr(E)
        ye = np.asarray(ops.seg_spmv(se, jnp.zeros(16, jnp.float32),
                                     use_kernel=True, interpret=True))
        assert ye.shape == (16,) and not ye.any()

    def test_batched_matches_per_vector(self):
        """Multi-RHS (N, B) through the seg oracle and the vmapped kernel
        path: every column equals its per-vector run."""
        A = powerlaw(512, 4000, seed=9)
        X = np.random.default_rng(9).standard_normal((512, 3)) \
            .astype(np.float32)
        seg = ops.seg_from_csr(A)
        Y_ref = np.asarray(ops.seg_spmv(seg, jnp.asarray(X)))
        Y_pal = np.asarray(ops.seg_spmv(seg, jnp.asarray(X),
                                        use_kernel=True, interpret=True))
        assert Y_ref.shape == (512, 3)
        for b in range(3):
            np.testing.assert_allclose(
                Y_ref[:, b],
                np.asarray(ops.seg_spmv(seg, jnp.asarray(X[:, b]))),
                rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(
                Y_pal[:, b],
                np.asarray(ops.seg_spmv(seg, jnp.asarray(X[:, b]),
                                        use_kernel=True, interpret=True)),
                rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(Y_ref, csr_to_dense(A) @ X,
                                   rtol=1e-4, atol=1e-4)

    def test_monster_row_carry_pinned_vs_csr_matvec(self):
        """Regression pin for the seg carry fix-up when a *single* row
        spans many chunks: one fully dense row (span 16 under chunk=128)
        over a thin background must reproduce ``csr_matvec`` through the
        oracle and the Pallas path, and a float64 scatter over the slab
        must match ``csr_matvec`` on the same (fp32-stored) values to
        fp64 round-off — the carry chain either sums every chunk's carry
        exactly once or drifts visibly."""
        rng = np.random.default_rng(11)
        M = 2048
        r = np.concatenate([np.zeros(M, int), np.arange(1, M)])
        c = np.concatenate([np.arange(M), rng.integers(0, M, M - 1)])
        v = rng.standard_normal(2 * M - 1)
        A = csr_from_coo(r, c, v, (M, M))
        seg = ops.seg_from_csr(A, chunk=128)
        assert np.diff(A.row_ptr)[0] == M          # monster row intact
        assert M // seg.chunk >= 16                # spans >= 16 chunks
        x = rng.standard_normal(M)
        want = csr_matvec(A, x)
        xj = jnp.asarray(x, jnp.float32)
        y_ref = np.asarray(ops.seg_spmv(seg, xj))
        y_pal = np.asarray(ops.seg_spmv(seg, xj, use_kernel=True,
                                        interpret=True))
        # fp32 paths: the monster row sums 2048 terms — scale tolerance
        np.testing.assert_allclose(y_ref, want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(y_pal, want, rtol=1e-4, atol=1e-3)
        A32 = dataclasses.replace(
            A, values=A.values.astype(np.float32).astype(np.float64))
        y64 = _np_slab_oracle(seg.vals, seg.cols, seg.rows, x, M)
        np.testing.assert_allclose(y64, csr_matvec(A32, x),
                                   rtol=1e-12, atol=1e-12)

    def test_grid_is_nnz_balanced(self):
        """Structural invariant: every chunk except the last holds exactly
        ``chunk`` non-zeros, no matter how skewed the rows are — the whole
        point of the format."""
        A = powerlaw(1024, 12000, seed=7)
        seg = ops.seg_from_csr(A, chunk=256)
        per_chunk = np.zeros(seg.num_chunks, np.int64)
        flat_c = np.arange(A.nnz) // seg.chunk
        np.add.at(per_chunk, flat_c, 1)
        full = per_chunk[per_chunk > 0]
        assert (full[:-1] == seg.chunk).all() and full[-1] <= seg.chunk
        # pieces tile the stream exactly once
        assert seg.piece_row.size >= A.shape[0] - (np.diff(A.row_ptr) == 0).sum()
        covered = 0
        for ch, lo, hi in zip(seg.piece_chunk, seg.piece_lo, seg.piece_hi):
            assert 0 <= lo <= hi < seg.chunk
            covered += hi - lo + 1
        assert covered == A.nnz


class TestSplitKernel:
    """Split-nnz two-stage SpMV: stage-1 per-split prefix sums + carry
    fix-up into (NS, rows) partials, stage-2 segmented combine."""

    @pytest.mark.parametrize("ns", [1, 2, 3, 4, 8])
    def test_matches_seg_and_float64_oracle(self, ns):
        A = powerlaw(1024, 12000, seed=4)
        x = np.random.default_rng(4).standard_normal(1024)
        xj = jnp.asarray(x, jnp.float32)
        spl = ops.split_from_csr(A, ns)
        seg = ops.seg_from_csr(A)
        y_spl = np.asarray(ops.split_spmv(spl, xj))
        y_seg = np.asarray(ops.seg_spmv(seg, xj))
        np.testing.assert_allclose(y_spl, y_seg, rtol=1e-5, atol=1e-5)
        y64 = _np_slab_oracle(spl.vals, spl.cols, spl.rows, x, 1024)
        np.testing.assert_allclose(y_spl, y64, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("ns", [2, 4])
    def test_pallas_two_stage_matches_oracle(self, ns):
        """stage-1 ``split_psum`` + fix-up + stage-2 ``split_combine``
        (interpret mode) vs the jnp oracle, on a monster-row matrix."""
        A = powerlaw_tail(1024, 2 * 4 * 1024, n_monster=4, seed=2)
        x = jnp.asarray(np.random.default_rng(2).standard_normal(1024),
                        jnp.float32)
        spl = ops.split_from_csr(A, ns)
        y_ref = np.asarray(ops.split_spmv(spl, x))
        y_pal = np.asarray(ops.split_spmv(spl, x, use_kernel=True,
                                          interpret=True))
        np.testing.assert_allclose(y_pal, y_ref, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(
            y_pal, csr_matvec(A, np.asarray(x, np.float64)),
            rtol=1e-3, atol=1e-2)

    def test_monster_row_split_kills_carry_span(self):
        """The structural point of the format: a row spanning ``span``
        chunks in seg spans at most ``ceil(C/ns)`` chunks of each split's
        slab (the splits cut the flat chunk stream, so the boundaries
        land inside the row once ``ns > C/span``), and the result is
        unchanged."""
        A = powerlaw_tail(512, 2 * 2 * 512, n_monster=2, seed=0)
        seg = ops.seg_from_csr(A, chunk=128)      # monster rows span 4+
        spl = ops.split_from_csr(A, 10, chunk=128)   # 2-chunk splits
        span_seg = max(np.bincount(seg.piece_row,
                                   minlength=A.shape[0]).max(), 1)
        span_spl = 0
        for s in range(spl.num_splits):
            m = spl.piece_split == s
            if m.any():
                span_spl = max(span_spl, np.bincount(
                    spl.piece_row[m], minlength=A.shape[0]).max())
        assert span_spl < span_seg
        x = jnp.asarray(np.random.default_rng(1).standard_normal(512),
                        jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.split_spmv(spl, x)),
            np.asarray(ops.seg_spmv(seg, x)), rtol=1e-5, atol=1e-5)

    def test_batched_matches_per_vector(self):
        """(N, B) batched split SpMV: every column equals its per-vector
        run — exactly for the oracle path, tightly for the vmapped
        Pallas path."""
        A = powerlaw_tail(512, 2 * 2 * 512, n_monster=2, seed=5)
        X = np.random.default_rng(5).standard_normal((512, 3)) \
            .astype(np.float32)
        spl = ops.split_from_csr(A, 4)
        Y_ref = np.asarray(ops.split_spmv(spl, jnp.asarray(X)))
        Y_pal = np.asarray(ops.split_spmv(spl, jnp.asarray(X),
                                          use_kernel=True, interpret=True))
        assert Y_ref.shape == (512, 3)
        for b in range(3):
            np.testing.assert_allclose(
                Y_ref[:, b],
                np.asarray(ops.split_spmv(spl, jnp.asarray(X[:, b]))),
                rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(
                Y_pal[:, b],
                np.asarray(ops.split_spmv(spl, jnp.asarray(X[:, b]),
                                          use_kernel=True, interpret=True)),
                rtol=1e-5, atol=1e-5)

    def test_empty_matrix_and_count_clamp(self):
        """Zero-nnz matrices lower to a valid no-op split slab for every
        requested count, and absurd counts clamp to the chunk count."""
        E = csr_from_coo(np.zeros(0, int), np.zeros(0, int), np.zeros(0),
                         (16, 16))
        for ns in (1, 4, 999):
            spl = ops.split_from_csr(E, ns)
            assert spl.num_splits == 1            # clamped to C == 1
            y = np.asarray(ops.split_spmv(spl, jnp.zeros(16, jnp.float32),
                                          use_kernel=True, interpret=True))
            assert y.shape == (16,) and not y.any()
        A = powerlaw(256, 2000, seed=6)
        spl = ops.split_from_csr(A, 10**6)
        assert 1 <= spl.num_splits <= spl.chunks_per_split * spl.num_splits
        x = jnp.asarray(np.random.default_rng(6).standard_normal(256),
                        jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.split_spmv(spl, x)),
            np.asarray(ops.seg_spmv(ops.seg_from_csr(A), x)),
            rtol=1e-5, atol=1e-5)

    def test_flat_path_matches_structured(self):
        """``split_flat_spmv`` (the device-path flattened slab + widened
        piece table) agrees with the structured ``split_spmv``."""
        A = powerlaw_tail(512, 2 * 2 * 512, n_monster=2, seed=8)
        x = jnp.asarray(np.random.default_rng(8).standard_normal(512),
                        jnp.float32)
        spl = ops.split_from_csr(A, 4)
        ns, Cs = spl.num_splits, spl.chunks_per_split
        pieces = np.stack([spl.piece_split * Cs + spl.piece_chunk,
                           spl.piece_lo, spl.piece_hi, spl.piece_row,
                           spl.piece_split], axis=1).astype(np.int32)
        L = spl.vals.shape[-1]
        y_flat = np.asarray(ops.split_flat_spmv(
            jnp.asarray(spl.vals.reshape(ns * Cs, L)),
            jnp.asarray(spl.cols.reshape(ns * Cs, L)),
            jnp.asarray(spl.rows.reshape(ns * Cs, L)),
            jnp.asarray(pieces), x, num_rows=512, num_splits=ns,
            use_kernel=True, interpret=True))
        np.testing.assert_allclose(y_flat, np.asarray(ops.split_spmv(spl, x)),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestKernelProperties:
    @settings(max_examples=20, deadline=None)
    @given(M=st.sampled_from([8, 24, 64]),
           N=st.sampled_from([128, 256]),
           nnz=st.integers(10, 800),
           seed=st.integers(0, 2**16))
    def test_ell_linearity(self, M, N, nnz, seed):
        """SpMV is linear: A(ax + by) == a*Ax + b*Ay."""
        A, x = rand_problem(M, N, nnz, seed=seed)
        y2 = np.random.default_rng(seed + 1).standard_normal(N).astype(np.float32)
        e = csr_to_ell(A)
        data, cols = jnp.asarray(e.data), jnp.asarray(e.cols)
        f = lambda v: np.asarray(ref.ell_spmv_ref(data, cols, jnp.asarray(v)))
        lhs = f(2.0 * x + 3.0 * y2)
        np.testing.assert_allclose(lhs, 2.0 * f(x) + 3.0 * f(y2),
                                   rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(M=st.sampled_from([64, 256]), nnz=st.integers(16, 2000),
           seed=st.integers(0, 2**16))
    def test_seg_matches_ell_oracle(self, M, nnz, seed):
        """The segmented and ELL formats of one matrix agree on A @ x."""
        A, x = rand_problem(M, M, nnz, seed=seed)
        e = csr_to_ell(A)
        y_ell = np.asarray(ref.ell_spmv_ref(
            jnp.asarray(e.data), jnp.asarray(e.cols), jnp.asarray(x)))[:M]
        seg = ops.seg_from_csr(A)
        y_seg = np.asarray(ops.seg_spmv(seg, jnp.asarray(x)))
        np.testing.assert_allclose(y_seg, y_ell, rtol=1e-4, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(M=st.sampled_from([64, 256]), nnz=st.integers(16, 2000),
           ns=st.integers(1, 12), seed=st.integers(0, 2**16))
    def test_split_matches_float64_oracle(self, M, nnz, ns, seed):
        """Across arbitrary split counts, the two-stage split result
        matches the float64 numpy slab oracle and the seg family."""
        A, x = rand_problem(M, M, nnz, seed=seed)
        spl = ops.split_from_csr(A, ns)
        y = np.asarray(ops.split_spmv(spl, jnp.asarray(x)))
        y64 = _np_slab_oracle(spl.vals, spl.cols, spl.rows, x, M)
        np.testing.assert_allclose(y, y64, rtol=1e-4, atol=1e-4)
        y_seg = np.asarray(ops.seg_spmv(ops.seg_from_csr(A),
                                        jnp.asarray(x)))
        np.testing.assert_allclose(y, y_seg, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(ns=st.integers(1, 8), seed=st.integers(0, 2**16))
    def test_split_batched_columns_independent(self, ns, seed):
        """(N, B) split oracle: each column equals its per-vector run."""
        A, _ = rand_problem(128, 128, 900, seed=seed)
        X = np.random.default_rng(seed).standard_normal((128, 2)) \
            .astype(np.float32)
        spl = ops.split_from_csr(A, ns)
        Y = np.asarray(ops.split_spmv(spl, jnp.asarray(X)))
        for b in range(2):
            np.testing.assert_allclose(
                Y[:, b],
                np.asarray(ops.split_spmv(spl, jnp.asarray(X[:, b]))),
                rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(nnz=st.integers(16, 600), seed=st.integers(0, 2**16))
    def test_tile_matches_ell_oracle(self, nnz, seed):
        """The bitmask-tiled and ELL formats of one matrix agree on
        A @ x across arbitrary sparsity draws."""
        A, x = rand_problem(128, 128, nnz, seed=seed)
        t = ops.tile_from_csr(A)
        y = np.asarray(ops.tile_spmv(t, jnp.asarray(x)))
        e = csr_to_ell(A)
        y_ell = np.asarray(ref.ell_spmv_ref(
            jnp.asarray(e.data), jnp.asarray(e.cols), jnp.asarray(x)))[:128]
        np.testing.assert_allclose(y, y_ell, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(nnz=st.integers(16, 600), seed=st.integers(0, 2**16))
    def test_bell_zero_padding_is_noop(self, nnz, seed):
        """Padded (zero) blocks contribute nothing regardless of bcol."""
        A, x = rand_problem(128, 128, nnz, seed=seed)
        blocks, bcols = ops.bell_from_bcsr(csr_to_bcsr(A, (8, 128)))
        # scramble the bcol of padded slots — result must not change
        mask = np.abs(blocks).sum(axis=(2, 3)) == 0
        bcols2 = np.where(mask, (bcols + 1) % blocks.shape[0] // 128, bcols)
        r1 = ref.bell_spmv_ref(*map(jnp.asarray, (blocks, bcols, x)))
        r2 = ref.bell_spmv_ref(*map(jnp.asarray, (blocks, bcols2, x)))
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2))
