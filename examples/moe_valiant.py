"""The paper's random-reordering insight applied to MoE routing.

Runs the deepseek-family MoE layer with skewed token->expert assignment and
reports the per-expert load CV with and without the Valiant shuffle — the
Fig. 8 vs Fig. 11 comparison on an LM workload.

    PYTHONPATH=src python examples/moe_valiant.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.moe import expert_load, moe_ffn, route


def main():
    cfg = get_smoke_config("deepseek_moe_16b").moe
    d = 64
    key = jax.random.PRNGKey(0)
    # A skewed router: most tokens prefer expert 0 (the cop20k_A hot-spot).
    router = np.asarray(jax.random.normal(key, (d, cfg.num_experts))) * 0.02
    router[:, 0] += 0.5
    params = {
        "router": jnp.asarray(router, jnp.float32),
        "w_gate": jax.random.normal(key, (cfg.num_experts, d, cfg.d_expert), jnp.bfloat16) * 0.05,
        "w_up": jax.random.normal(key, (cfg.num_experts, d, cfg.d_expert), jnp.bfloat16) * 0.05,
        "w_down": jax.random.normal(key, (cfg.num_experts, cfg.d_expert, d), jnp.bfloat16) * 0.05,
    }
    x = jax.random.normal(key, (4, 64, d), jnp.bfloat16)
    _, ids, _ = route(params, x.reshape(-1, d), cfg)
    load = np.asarray(expert_load(ids, cfg.num_experts))
    print(f"expert load (skewed router): {load.astype(int).tolist()}")
    print(f"  hot expert share: {load.max()/load.sum():.2f}  CV: {load.std()/load.mean():.2f}")
    y0, aux0 = moe_ffn(params, x, cfg, "swiglu")
    cfg2 = dataclasses.replace(cfg, valiant_shuffle=True)
    y1, aux1 = moe_ffn(params, x, cfg2, "swiglu", rng=jax.random.PRNGKey(7))
    drop0 = float(jnp.mean((jnp.abs(y0.astype(jnp.float32)).sum(-1) == 0)))
    drop1 = float(jnp.mean((jnp.abs(y1.astype(jnp.float32)).sum(-1) == 0)))
    print(f"capacity-dropped tokens: plain={drop0:.3f} valiant={drop1:.3f}")
    print("(the shuffle spreads correlated token runs across the capacity")
    print(" buffer exactly like the paper's random reordering spreads")
    print(" migratory threads across nodelets)")


if __name__ == "__main__":
    main()
