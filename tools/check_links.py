#!/usr/bin/env python3
"""Markdown link checker: every relative link must resolve to a real file.

Dependency-free so it runs identically in CI and locally:

    python tools/check_links.py README.md docs/*.md

Checks inline links/images ``[text](target)``. External schemes (http/https/
mailto) and pure in-page anchors (``#...``) are skipped; ``path#anchor``
checks only the path part.  Exits non-zero listing every broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — target without whitespace; tolerates image links.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    # Drop fenced code blocks: they hold example output, not links.
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] or sorted(
        p for p in [Path("README.md"), *Path("docs").glob("*.md")]
        if p.exists())
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
