"""Fault tolerance: checkpoint/restart byte-exactness + elastic re-mesh.

Runs on 8 fake CPU devices (set in conftest for this module via env is not
possible per-module — instead we use the devices the session has and skip
if fewer than 4).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import DataConfig, TokenStream
from repro.models import params as pp
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.loop import RunConfig, train_loop
from repro.train import elastic


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = get_smoke_config("qwen3_4b")
    data = DataConfig(seed=0, batch=4, seq_len=16)
    stream = TokenStream(cfg, data)
    return cfg, stream, tmp_path_factory.mktemp("ckpt")


def small_mesh(n_model=1):
    n = len(jax.devices())
    from repro.launch.mesh import auto_axis_types
    return jax.make_mesh(((n // n_model) or 1, n_model), ("data", "model"),
                         **auto_axis_types(2))


class TestCheckpoint:
    def test_save_restore_roundtrip(self, setup):
        cfg, stream, tmp = setup
        params = pp.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        path = ckpt.save(str(tmp / "a"), params, opt, 7, blocking=True)
        assert os.path.isdir(path)
        like = {"params": params, "opt": opt}
        state, step = ckpt.restore(str(tmp / "a"), 7, like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_step(self, setup):
        cfg, stream, tmp = setup
        params = pp.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        ckpt.save(str(tmp / "b"), params, opt, 3, blocking=True)
        ckpt.save(str(tmp / "b"), params, opt, 9, blocking=True)
        assert ckpt.latest_step(str(tmp / "b")) == 9

    def test_atomicity_no_tmp_left(self, setup):
        cfg, stream, tmp = setup
        params = pp.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        ckpt.save(str(tmp / "c"), params, opt, 1, blocking=True)
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp / "c"))


class TestElasticRestart:
    def test_restart_continues_loss_curve(self, setup):
        """Train 4 steps, checkpoint at 2, restart from 2 — steps 2-3 match
        byte-for-byte (deterministic data stream + restored state)."""
        cfg, stream, tmp = setup
        run = RunConfig(fsdp=False, remat=False, donate=False)
        mesh = small_mesh()
        losses_a = {}
        train_loop(cfg, adamw.AdamWConfig(lr=1e-3), mesh, stream, 5, run,
                   checkpoint_dir=str(tmp / "d"), checkpoint_every=2,
                   on_metrics=lambda s, m: losses_a.__setitem__(s, m["loss"]))
        ckpt.wait_for_writes()
        params, opt, step = elastic.resume(cfg, adamw.AdamWConfig(lr=1e-3),
                                           str(tmp / "d"), mesh, run)
        assert step == 4          # saved after steps 2 and 4
        losses_b = {}
        train_loop(cfg, adamw.AdamWConfig(lr=1e-3), mesh, stream, 5, run,
                   start_step=step, params=params, opt_state=opt,
                   on_metrics=lambda s, m: losses_b.__setitem__(s, m["loss"]))
        np.testing.assert_allclose(losses_a[4], losses_b[4], rtol=1e-5)

    def test_shrink_mesh_preserves_tp(self):
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >=2 devices")
        m = elastic.shrink_mesh(devs[: len(devs) - 1], model_parallel=1)
        assert m.shape["model"] == 1
        assert m.shape["data"] == len(devs) - 1

    def test_resume_on_smaller_mesh(self, setup):
        """The elastic path: checkpoint on mesh A, resume on half of it."""
        cfg, stream, tmp = setup
        if len(jax.devices()) < 2:
            pytest.skip("needs >=2 devices")
        run = RunConfig(fsdp=False, remat=False, donate=False)
        mesh = small_mesh()
        train_loop(cfg, adamw.AdamWConfig(), mesh, stream, 2, run,
                   checkpoint_dir=str(tmp / "e"), checkpoint_every=2)
        ckpt.wait_for_writes()
        survivors = jax.devices()[: max(len(jax.devices()) // 2, 1)]
        mesh2 = elastic.shrink_mesh(survivors, model_parallel=1)
        params, opt, step = elastic.resume(cfg, adamw.AdamWConfig(),
                                           str(tmp / "e"), mesh2, run)
        # one more step must run on the shrunken mesh
        p2, o2, metrics = train_loop(cfg, adamw.AdamWConfig(), mesh2, stream,
                                     3, run, start_step=step,
                                     params=params, opt_state=opt)
        assert np.isfinite(metrics["loss"])


class TestGradCompression:
    def test_int8_roundtrip_error_feedback(self):
        from repro.optim.grad_compress import (compress_tree, dequantize_int8,
                                               quantize_int8)
        rng = np.random.default_rng(0)
        g = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
        q, s, resid = compress_tree(g, None)
        deq = dequantize_int8(q["a"], s["a"])
        err = np.abs(np.asarray(deq + resid["a"]) - np.asarray(g["a"])).max()
        assert err < 1e-5       # error feedback captures quantization residual
        assert q["a"].dtype == jnp.int8
