"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified).  8 experts top-2,
GQA kv=8, logit softcap 30."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=32768,
    vocab_size=131_072, activation="geglu", logit_softcap=30.0,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, d_expert=32768,
                  expert_split=2))

def smoke_config():
    return ModelConfig(
        name="grok-1-smoke", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, activation="geglu", logit_softcap=30.0,
        block_pattern=("moe",),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_expert=64))
