"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the right step function is lowered against ShapeDtypeStruct
inputs (no allocation), compiled, and the compiled artifact is mined for:

* ``memory_analysis()``  — bytes/device (proves the sharding fits HBM),
* ``cost_analysis()``    — HLO FLOPs + bytes accessed (roofline terms),
* the stable-HLO / HLO text — collective operand bytes (the ICI term and
  the paper's migration analogue).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_7b \
        --shape train_4k --multi-pod both --json out.json
"""
from __future__ import annotations

# The XLA flag must be set before jax initializes devices — these two lines
# run before ANY other import (including ``from repro...``), since jax locks
# the device count on first init.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import params as pp
from repro.models.config import SHAPES, shape_applicable
from repro.optim import adamw
from repro.train.loop import (RunConfig, make_decode_step, make_prefill_step,
                              make_train_step)

# v5e-class hardware constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (per-chip effective, one link)

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _dtype_bytes(s: str) -> int:
    return {"f64": 8, "f32": 4, "s64": 8, "u64": 8, "bf16": 2, "f16": 2,
            "s32": 4, "u32": 4, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
            "u8": 1, "f8": 1}.get(s, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in compiled HLO text."""
    out: Dict[str, float] = {}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # HLO text: `%name = <output shape(s)> <op>(...)`.  Count the output
        # shapes — the segment between '=' and the op keyword.
        rhs = line.split("=", 1)[1]
        op_pos = rhs.find(kind)
        seg = rhs[:op_pos] if op_pos > 0 else rhs
        nbytes = 0
        for dt, dims in shape_re.findall(seg):
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            nbytes += n * _dtype_bytes(dt)
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, shape) -> float:
    """6*N*D train / 2*N_active*D inference (decode: D = new tokens)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch       # one token per stream


def _n_units(cfg) -> int:
    return (cfg.num_layers - cfg.dense_first_layers) // len(cfg.pattern())


def _partial_unroll(cfg) -> int:
    """Largest small divisor of the unit count (exact extrapolation)."""
    n = _n_units(cfg)
    for u in (4, 3, 2):
        if n % u == 0 and n > u:
            return u
    return 1


def lower_cell(arch: str, shape_name: str, mesh, *, fsdp=None,
               run: RunConfig | None = None, unroll=False):
    """Lower + compile one cell; returns (lowered, compiled, cfg, shape).

    ``unroll`` may be False (production lowering), True (full unroll) or an
    int (partial unroll of the layer scan — used with trip-count
    extrapolation by run_cell)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if run is None:
        if fsdp is None:
            # FSDP for the big archs; pure TP+DP replication is fine <10B.
            fsdp = cfg.param_count() > 8e9
            if shape.kind == "decode":
                # Serving: static weights make per-token FSDP gathers pure
                # waste (§Perf decode iteration) — drop FSDP whenever the
                # TP-sharded weights fit HBM (everything but the 104B/314B).
                fsdp = cfg.param_count() * 2 / 16 > 10e9
        # Train cells accumulate gradients over 8 microbatches (1M-token
        # global batch never lives on-chip at once — production practice).
        run = RunConfig(fsdp=fsdp, remat=True, donate=True, scan_unroll=unroll,
                        grad_accum=8 if shape.kind == "train" else 1)
    specs = input_specs(cfg, shape)
    abstract_p = pp.abstract_params(cfg)

    with mesh:
        if shape.kind == "train":
            step_fn, jit_for, _ = make_train_step(
                cfg, adamw.AdamWConfig(), mesh, run)
            abstract_o = adamw.abstract_state(abstract_p)
            jitted = jit_for(specs)
            lowered = jitted.lower(abstract_p, abstract_o, specs,
                                   jax.ShapeDtypeStruct((2,), jnp.uint32))
        elif shape.kind == "prefill":
            _, jit_for, _ = make_prefill_step(cfg, mesh, shape.global_batch,
                                              run)
            jitted = jit_for(specs)
            lowered = jitted.lower(abstract_p, specs)
        else:  # decode
            _, jitted, _ = make_decode_step(cfg, mesh, shape.global_batch, run)
            lowered = jitted.lower(abstract_p, specs["tokens"],
                                   specs["caches"], specs["pos"])
        compiled = lowered.compile()
    return lowered, compiled, cfg, shape


def analyze(lowered, compiled, cfg, shape, mesh, *, grad_accum: int = 1
            ) -> Dict[str, Any]:
    chips = int(np.prod(list(mesh.shape.values())))
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    # The microbatch scan body is counted once by cost_analysis; one step
    # runs it grad_accum times (slightly overcounts the once-per-step
    # optimizer collectives — conservative).
    coll = {k: v * grad_accum for k, v in coll.items()}
    flops = float(cost.get("flops", 0.0)) * grad_accum
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) * grad_accum
    # cost_analysis is per-device for SPMD-partitioned modules.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = coll["total"] / ICI_BW
    mf = model_flops(cfg, shape)
    res = {
        "chips": chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_collective)], key=lambda kv: kv[1])[0],
        "model_flops_total": mf,
        "useful_flops_ratio": mf / max(flops * chips, 1.0),
        "grad_accum": grad_accum,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            # donation aliases outputs onto arguments; peak ~ args + temp
            "peak": (getattr(mem, "argument_size_in_bytes", 0) or 0) +
                    (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
    }
    return res


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             unroll: bool = False) -> Dict[str, Any]:
    from repro.models import layers as _layers
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # Pass 1 (rolled): the compile-proof + per-device memory picture.
    lowered, compiled, cfg, shape = lower_cell(arch, shape_name, mesh)
    ga = 8 if shape.kind == "train" else 1
    res = analyze(lowered, compiled, cfg, shape, mesh, grad_accum=ga)
    if unroll:
        # Pass 2: XLA counts a while-loop body once, so the rolled pass
        # sees ~1 layer-unit of cost.  Re-lower with the layer scan
        # partially unrolled by a divisor u of the unit count (and inner
        # chunk scans fully unrolled), then extrapolate linearly in trip
        # count: cost_total = cost_rolled + (n_units - 1)/(u - 1) *
        # (cost_u - cost_rolled).  Exact for per-unit costs; the one-unit
        # chunk-scan undercount in the rolled term is <~3% (noted in
        # EXPERIMENTS.md).  Memory is reported from the production pass.
        mem_rolled = res["bytes_per_device"]
        u = _partial_unroll(cfg)
        n = _n_units(cfg)
        try:
            if u > 1:
                _layers.ANALYSIS_UNROLL = True
                lo2, co2, _, _ = lower_cell(arch, shape_name, mesh, unroll=u)
                res_u = analyze(lo2, co2, cfg, shape, mesh, grad_accum=ga)
                scale = (n - 1) / (u - 1)
                for key in ("hlo_flops_per_chip", "hlo_bytes_per_chip",
                            "collective_bytes_per_chip"):
                    res_u[key] = res[key] + scale * (res_u[key] - res[key])
                res_u["collectives"] = {
                    k: res["collectives"].get(k, 0.0) + scale *
                    (v - res["collectives"].get(k, 0.0))
                    for k, v in res_u["collectives"].items()}
                res_u["t_compute_s"] = res_u["hlo_flops_per_chip"] / PEAK_FLOPS
                res_u["t_memory_s"] = res_u["hlo_bytes_per_chip"] / HBM_BW
                res_u["t_collective_s"] =                     res_u["collective_bytes_per_chip"] / ICI_BW
                res_u["bottleneck"] = max(
                    [("compute", res_u["t_compute_s"]),
                     ("memory", res_u["t_memory_s"]),
                     ("collective", res_u["t_collective_s"])],
                    key=lambda kv: kv[1])[0]
                res_u["useful_flops_ratio"] = res_u["model_flops_total"] /                     max(res_u["hlo_flops_per_chip"] * res_u["chips"], 1.0)
                res = res_u
            res["bytes_per_device"] = mem_rolled
            res["cost_pass"] = f"extrapolated(u={u},n={n})"
        except Exception as e:  # fall back to rolled costs, note it
            res["cost_pass"] = f"rolled (unroll failed: {str(e)[:120]})"
        finally:
            _layers.ANALYSIS_UNROLL = False
    res.update(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               compile_s=round(time.time() - t0, 1), status="ok")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"), default="no")
    ap.add_argument("--json", default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan for analysis-grade "
                         "cost_analysis (slower compiles)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    results = []

    def emit(r):
        results.append(r)
        if args.json:
            with open(args.json + "l", "a") as f:   # incremental JSONL
                f.write(json.dumps(r) + "\n")

    for arch in archs:
        cfg = get_config(arch)
        for sname in shapes:
            if not shape_applicable(cfg, SHAPES[sname]):
                emit({"arch": arch, "shape": sname, "status": "skip",
                      "reason": "quadratic attention @500k "
                                "(docs/ARCHITECTURE.md#design-5)"})
                print(f"SKIP  {arch:22s} {sname}")
                continue
            for mp in pods:
                try:
                    r = run_cell(arch, sname, multi_pod=mp,
                                 unroll=args.unroll)
                    emit(r)
                    print(f"OK    {arch:22s} {sname:12s} {r['mesh']:8s} "
                          f"compute={r['t_compute_s']:.3e}s "
                          f"mem={r['t_memory_s']:.3e}s "
                          f"coll={r['t_collective_s']:.3e}s "
                          f"-> {r['bottleneck']:10s} "
                          f"peak={r['bytes_per_device']['peak']/2**30:.1f}GiB "
                          f"[{r['compile_s']}s]")
                except Exception as e:
                    emit({"arch": arch, "shape": sname,
                          "mesh": "2x16x16" if mp else "16x16",
                          "status": "fail", "error": str(e)[:2000]})
                    print(f"FAIL  {arch:22s} {sname:12s} "
                          f"{'2x16x16' if mp else '16x16'}: "
                          f"{type(e).__name__}: {str(e)[:200]}")
                    traceback.print_exc(limit=3)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    fail = sum(1 for r in results if r["status"] == "fail")
    skip = sum(1 for r in results if r["status"] == "skip")
    print(f"\n{ok} ok / {fail} fail / {skip} skip")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
