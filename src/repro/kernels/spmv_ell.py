"""Pallas TPU kernel: ELL-format SpMV.

TPU adaptation of the paper's CSR row loop (docs/ARCHITECTURE.md#design-2):
a scalar
CSR walk cannot feed the VPU, so rows are padded to a lane-aligned width W
and the kernel processes (TM, TW) tiles of the ELL slab against an x vector
resident in VMEM:

    y[i] += sum_w data[i, w] * x[cols[i, w]]

Grid is (M/TM, W/TW); the W-axis is the reduction, accumulated in the output
tile (revisited across the w grid dimension, initialised at w == 0).  The
gather from x is a VMEM dynamic-gather — the TPU analogue of the Emu
migratory load: x is the *block-layout local shard*, so every gather that
would have been a migration on Emu is a VMEM hit here, which is exactly why
the distributed layer (core/spmv.py) reproduces the paper's block-layout
win on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ell_spmv"]


def _ell_kernel(data_ref, cols_ref, x_ref, y_ref):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    data = data_ref[...]                       # (TM, TW)
    cols = cols_ref[...]                       # (TM, TW)
    x = x_ref[...]                             # (N,) resident in VMEM
    gathered = jnp.take(x, cols, axis=0)       # VMEM dynamic gather
    y_ref[...] += jnp.sum(data * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_w", "interpret"))
def ell_spmv(data: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray,
             *, tile_m: int = 256, tile_w: int = 512,
             interpret: bool = False) -> jnp.ndarray:
    """y = A @ x with A in padded-ELL form.

    data/cols: (M, W) with W % 128 == 0 (lane aligned), M % 8 == 0.
    x: (N,) — must fit VMEM alongside the tiles (the distributed layer
    shards x so each local slab sees only its block).
    """
    M, W = data.shape
    tm = min(tile_m, M)
    tw = min(tile_w, W)
    if M % tm or W % tw:
        raise ValueError(f"tiles must divide slab: {(M, W)} vs {(tm, tw)}")
    grid = (M // tm, W // tw)
    return pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tw), lambda m, w: (m, w)),       # data tile
            pl.BlockSpec((tm, tw), lambda m, w: (m, w)),       # cols tile
            pl.BlockSpec((x.shape[0],), lambda m, w: (0,)),    # full x in VMEM
        ],
        out_specs=pl.BlockSpec((tm,), lambda m, w: (m,)),
        out_shape=jax.ShapeDtypeStruct((M,), x.dtype),
        interpret=interpret,
    )(data, cols, x)
