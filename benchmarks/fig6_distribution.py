"""Fig. 6 — SpMV bandwidth: row vs non-zero work distribution (Emu model).
Paper: nonzero up to 3.34x better despite ~1.69x more migrations."""
from repro.core.layout import make_layout
from repro.core.migration import count_migrations
from repro.core.partition import make_partition
from repro.data.matrices import make_matrix
from .common import COUNT_SCALES, SIM_SCALES, emit, sim_bandwidth


def run():
    rows = []
    for name in SIM_SCALES:
        bws, migs = {}, {}
        for strat in ("row", "nonzero"):
            _, res = sim_bandwidth(name, strategy=strat)
            bws[strat] = res.bandwidth_mbs
        A = make_matrix(name, scale=COUNT_SCALES[name])
        for strat in ("row", "nonzero"):
            p = make_partition(A, 8, strat)
            migs[strat] = count_migrations(
                A, p, make_layout("block", A.ncols, 8),
                make_layout("block", A.nrows, 8)).migrations
        rows.append((f"fig6/{name}", round(bws["row"], 1),
                     round(bws["nonzero"], 1),
                     round(bws["nonzero"] / max(bws["row"], 1e-9), 2),
                     round(migs["nonzero"] / max(migs["row"], 1), 2)))
    emit(rows, ("name", "row_mbs", "nonzero_mbs", "nonzero_speedup",
                "mig_ratio_nnz_over_row"))


if __name__ == "__main__":
    run()
