"""Exact migration / remote-traffic accounting (the paper's core metric).

Thread walk model (paper §II-A, §III): a worker thread lives on its parent
nodelet (which owns its rows' mini-CSR).  Reading the next row's metadata
happens at the parent; every x[j] load happens wherever the layout placed
x[j]; b[i] is accumulated in a register and written once per row as a local
store or *remote update* (never a migration).  A migration is counted every
time the walk's current nodelet changes:

    home, x_own(j1), x_own(j2), ..., home, x_own(...), ...
          row r                      row r+1

This reproduces the paper's observations by construction: a cyclic layout
changes owner on (almost) every consecutive access; a block layout costs one
migration per run of accesses into the same remote block.

On TPU the same counts convert to collective bytes: each remote x access
moves 8 bytes over ICI (gather) instead of a 200-byte thread context, and the
per-device *skew* of remote traffic is the hot-spot analogue.  Everything
here is vectorized numpy over the full-scale matrices.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .layout import VectorLayout
from .partition import Partition
from .sparse_matrix import CSRMatrix, csr_row_nnz

__all__ = ["TrafficReport", "count_migrations", "remote_access_matrix",
           "migration_arrivals", "shard_load_map"]


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    migrations: int                 # owner changes in the thread walk
    remote_x_loads: int             # x loads not on the home nodelet
    remote_b_updates: int           # b stores issued to a remote nodelet
    mem_instr_per_nodelet: np.ndarray   # (P,) memory instructions executed
    inbound_x_loads: np.ndarray     # (P,) x loads *served by* each nodelet
    nnz_per_nodelet: np.ndarray     # (P,) work assigned to each nodelet

    @property
    def mem_instr_cv(self) -> float:
        m = self.mem_instr_per_nodelet
        mu = m.mean()
        return float(m.std() / mu) if mu else 0.0

    @property
    def inbound_cv(self) -> float:
        m = self.inbound_x_loads
        mu = m.mean()
        return float(m.std() / mu) if mu else 0.0

    @property
    def hotspot_share(self) -> float:
        """Fraction of all x loads served by the single hottest nodelet."""
        tot = self.inbound_x_loads.sum()
        return float(self.inbound_x_loads.max() / tot) if tot else 0.0


def count_migrations(csr: CSRMatrix, part: Partition, x_layout: VectorLayout,
                     b_layout: VectorLayout) -> TrafficReport:
    """Count migrations for SpMV under a partition + vector layouts."""
    P = part.num_shards
    M = csr.nrows
    nnz_per_row = csr_row_nnz(csr)
    rows = np.repeat(np.arange(M), nnz_per_row)           # (nnz,)
    home = part.owner_of_rows(M)                          # (M,) row -> nodelet
    home_of_nnz = home[rows]                              # (nnz,)
    owners = x_layout.owner_of(csr.col_index)             # (nnz,)

    # --- migrations: owner changes along the walk --------------------------
    # Within-row transitions between consecutive x owners.
    same_row = np.empty(csr.nnz, dtype=bool)
    if csr.nnz:
        same_row[0] = False
        same_row[1:] = rows[1:] == rows[:-1]
    inner = int(np.count_nonzero(same_row[1:] & (owners[1:] != owners[:-1]))) if csr.nnz > 1 else 0
    # Row starts: home -> first owner.
    starts = csr.row_ptr[:-1][nnz_per_row > 0]
    enter = int(np.count_nonzero(owners[starts] != home_of_nnz[starts]))
    # Row ends: last owner -> home (to fetch the next row's metadata).
    ends = (csr.row_ptr[1:] - 1)[nnz_per_row > 0]
    leave = int(np.count_nonzero(owners[ends] != home_of_nnz[ends]))
    migrations = inner + enter + leave

    remote_x = int(np.count_nonzero(owners != home_of_nnz))
    b_owner = b_layout.owner_of(np.arange(M))
    remote_b = int(np.count_nonzero(b_owner != home))

    # --- per-nodelet instruction/work accounting ---------------------------
    # At home: 2 loads per nnz (value + colIndex) + 2 per row (rowPtr, b acc).
    mem = np.zeros(P, dtype=np.int64)
    np.add.at(mem, home_of_nnz, 2)
    np.add.at(mem, home, 2)
    # x loads execute on the owner nodelet.
    np.add.at(mem, owners, 1)
    # Remote b updates execute on the b-owner's memory-side processor.
    np.add.at(mem, b_owner, 1)

    inbound = np.zeros(P, dtype=np.int64)
    np.add.at(inbound, owners, 1)

    nnz_per_nodelet = np.zeros(P, dtype=np.int64)
    np.add.at(nnz_per_nodelet, home_of_nnz, 1)

    return TrafficReport(
        migrations=migrations,
        remote_x_loads=remote_x,
        remote_b_updates=remote_b,
        mem_instr_per_nodelet=mem,
        inbound_x_loads=inbound,
        nnz_per_nodelet=nnz_per_nodelet,
    )


def migration_arrivals(csr: CSRMatrix, part: Partition,
                       x_layout: VectorLayout,
                       col_weight: np.ndarray | None = None) -> np.ndarray:
    """(P,) migrations *arriving at* each nodelet under the thread walk.

    Same walk as :func:`count_migrations` (home, x owners..., home per row),
    but attributed to the *destination* nodelet of each owner change.  This
    is the ingress pressure the Nodelet Queue Manager must absorb — the
    quantity that saturates on cop20k_A's nodelet 0 (§IV-D) and that the
    plan cost model (``core/plan.py``) uses as its hot-spot term.

    ``col_weight`` (optional, (ncols,) float, in *this matrix's* index
    order) weights each arrival event by the activity of the x column that
    triggered it — the first-order model of a serving workload where only
    some columns of x are hot (a load at an inactive column never happens,
    so neither does the migration it would have caused).  The return event
    back to the home nodelet is weighted by the row's last column, the
    access that stranded the thread remotely.  Weighted results are float64
    expected counts; ``col_weight=None`` keeps the exact integer counts.
    """
    P = part.num_shards
    M = csr.nrows
    nnz_per_row = csr_row_nnz(csr)
    rows = np.repeat(np.arange(M), nnz_per_row)
    home = part.owner_of_rows(M)
    home_of_nnz = home[rows]
    owners = x_layout.owner_of(csr.col_index)
    if col_weight is None:
        w = None
        arrivals = np.zeros(P, dtype=np.int64)
    else:
        w = np.asarray(col_weight, dtype=np.float64)[csr.col_index]
        arrivals = np.zeros(P, dtype=np.float64)

    if csr.nnz > 1:
        same_row = rows[1:] == rows[:-1]
        moved = same_row & (owners[1:] != owners[:-1])
        np.add.at(arrivals, owners[1:][moved],
                  1 if w is None else w[1:][moved])
    starts = csr.row_ptr[:-1][nnz_per_row > 0]
    enter = owners[starts] != home_of_nnz[starts]
    np.add.at(arrivals, owners[starts][enter],
              1 if w is None else w[starts][enter])
    ends = (csr.row_ptr[1:] - 1)[nnz_per_row > 0]
    leave = owners[ends] != home_of_nnz[ends]
    np.add.at(arrivals, home_of_nnz[ends][leave],
              1 if w is None else w[ends][leave])
    return arrivals


def remote_access_matrix(csr: CSRMatrix, part: Partition,
                         x_layout: VectorLayout,
                         col_weight: np.ndarray | None = None) -> np.ndarray:
    """(P, P) matrix T where T[p, q] = x loads issued by shard p into shard q.

    The TPU collective analogue: off-diagonal mass is ICI traffic; column
    skew is the hot-spot (all-to-one convergence the paper observes on
    cop20k_A's nodelet 0).  With ``col_weight`` (per-column activity, this
    matrix's index order) each load counts its column's weight instead of
    1, giving the *observed-traffic* access matrix the serving rebalancer
    monitors (float64; unweighted stays exact int64).
    """
    P = part.num_shards
    M = csr.nrows
    rows = np.repeat(np.arange(M), csr_row_nnz(csr))
    home_of_nnz = part.owner_of_rows(M)[rows]
    owners = x_layout.owner_of(csr.col_index)
    if col_weight is None:
        T = np.zeros((P, P), dtype=np.int64)
        np.add.at(T, (home_of_nnz, owners), 1)
    else:
        T = np.zeros((P, P), dtype=np.float64)
        np.add.at(T, (home_of_nnz, owners),
                  np.asarray(col_weight, dtype=np.float64)[csr.col_index])
    return T


def shard_load_map(csr: CSRMatrix, part: Partition,
                   x_layout: VectorLayout,
                   b_layout: VectorLayout | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Precomputed column→shard load attribution for cheap online monitoring.

    Returns ``(load_map, base)`` where ``load_map`` is (P, ncols) float64
    and ``base`` is (P,) float64, such that for any per-column activity
    vector ``w`` (this matrix's index order) the expected per-nodelet
    memory-instruction load of one served SpMV is::

        load = load_map @ w + base

    Attribution matches :func:`count_migrations`'s per-nodelet accounting:
    each stored (i, j) costs 2 instructions at row i's home (value +
    colIndex load) and 1 at x[j]'s owner, both gated by column j's
    activity; the per-row overhead (rowPtr read + b accumulate at home,
    plus the b-owner update) is activity-independent and lands in
    ``base``.  With ``w = 1`` the sum reproduces
    ``count_migrations(...).mem_instr_per_nodelet`` exactly — the serving
    monitor's load metric degrades gracefully to the static one under
    uniform traffic.

    The map costs O(P * ncols) memory once per built plan; after that a
    monitoring window is a single matvec, which is what lets the
    rebalancer watch every request without re-walking the matrix.
    """
    P = part.num_shards
    M = csr.nrows
    rows = np.repeat(np.arange(M), csr_row_nnz(csr))
    home = part.owner_of_rows(M)
    home_of_nnz = home[rows]
    owners = x_layout.owner_of(csr.col_index)
    cols = csr.col_index

    load_map = np.zeros((P, csr.ncols), dtype=np.float64)
    np.add.at(load_map, (home_of_nnz, cols), 2.0)
    np.add.at(load_map, (owners, cols), 1.0)

    base = np.zeros(P, dtype=np.float64)
    np.add.at(base, home, 2.0)
    b_owner = (b_layout or x_layout).owner_of(np.arange(M))
    np.add.at(base, b_owner, 1.0)
    return load_map, base
