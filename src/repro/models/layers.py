"""Shared neural-net layers: norms, rope, attention, FFN.

All functions are pure (params explicit), bf16 activations with f32
reductions, and shaped for GSPMD: batch leads, heads/ffn are the natural
"model"-axis shard dims.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

F32 = jnp.float32

# Analysis-mode flag: when True, inner reduction scans (attention chunks,
# mLSTM chunks) unroll so XLA cost_analysis counts every iteration.  Set by
# repro.launch.dryrun only; never in production paths.
ANALYSIS_UNROLL = False


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., :, None].astype(F32) * freq          # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(logits: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, causal: bool = True, window: Optional[int] = None,
                      prefix_len: int = 0, chunk: int = 1024,
                      softcap: Optional[float] = None) -> jnp.ndarray:
    """Memory-safe flash-style attention (lax.scan over KV chunks).

    q: (B, S, H, D); k/v: (B, T, Hkv, D) with H % Hkv == 0.
    Never materialises the (S, T) score matrix — the online-softmax state is
    (m, l, acc) per query. ``window`` masks to a local band; ``prefix_len``
    makes the first P keys bidirectional (PaliGemma-style prefix-LM).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = D ** -0.5
    q = q.astype(F32) * scale
    nchunks = -(-T // chunk)
    Tp = nchunks * chunk
    pad = Tp - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, D)
    vc = v.reshape(B, nchunks, chunk, Hkv, D)
    q_pos = jnp.arange(S)[:, None]                       # query positions

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, cidx = inp
        kv_pos = cidx * chunk + jnp.arange(chunk)[None, :]
        kb = jnp.repeat(kb, rep, axis=2)                # (B, chunk, H, D)
        vb = jnp.repeat(vb, rep, axis=2)
        s = jnp.einsum("bshd,bthd->bhst", q, kb.astype(F32))
        s = _softcap(s, softcap)
        mask = jnp.ones((S, chunk), dtype=bool)
        if causal:
            c = q_pos >= kv_pos
            if prefix_len:
                c = c | (kv_pos < prefix_len)
            mask &= c
        if window is not None:
            mask &= (q_pos - kv_pos) < window
        mask &= kv_pos < T                               # padding
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # Guard fully-masked rows (m_new == -inf).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, vb.astype(F32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), -jnp.inf, dtype=F32)
    l0 = jnp.zeros((B, H, S), dtype=F32)
    a0 = jnp.zeros((B, H, S, D), dtype=F32)
    kcs = jnp.moveaxis(kc, 1, 0)
    vcs = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kcs, vcs, jnp.arange(nchunks)),
        unroll=nchunks if ANALYSIS_UNROLL else 1)
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(jnp.bfloat16)  # (B, S, H, D)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray, *, softcap: Optional[float] = None,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention over a cache — sequence-parallel form.

    q: (B, 1, H, D); caches: (B, T, Hkv, D); length: () or (B,) valid len.
    The cache's T dim stays sharded over "model": scores and the masked
    softmax are elementwise/reducible over T, so the only collectives are
    the (B, H) logsumexp terms and the (B, H, D) partial outputs — without
    the constraints GSPMD all-gathers the whole cache in f32 per step
    (1 GB/layer at qwen25 decode_32k — §Perf log).
    """
    from repro.models.model import _maybe_constrain, _BATCH
    B, _, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    qf = q.astype(F32) * D ** -0.5
    # scores in the kv-head layout (no head repeat: q grouped per kv head)
    qg = qf.reshape(B, 1, Hkv, rep, D)
    s = jnp.einsum("bqhrd,bthd->bhrqt", qg, k_cache.astype(F32))
    s = _maybe_constrain(s, _BATCH, None, None, None, "model")
    s = _softcap(s, softcap)
    pos = jnp.arange(T)[None, None, None, None]
    valid = pos < jnp.reshape(length, (-1, 1, 1, 1, 1))
    if window is not None:
        valid &= pos >= (jnp.reshape(length, (-1, 1, 1, 1, 1)) - window)
    s = jnp.where(valid[:, :, :, 0][:, :, None], s, -jnp.inf)
    # streaming softmax: the reductions over T lower to psum over "model"
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    l = jnp.sum(e, axis=-1, keepdims=True)
    p = (e / l).astype(jnp.bfloat16)
    out = jnp.einsum("bhrqt,bthd->bqhrd", p.astype(F32),
                     v_cache.astype(F32))
    return out.reshape(B, 1, H, D).astype(jnp.bfloat16)


def attention_block(params, x, cfg: ModelConfig, positions, *,
                    window=None, prefix_len=0, kv_cache=None, cache_len=None):
    """Full attention block.  Returns (out, new_kv) — new_kv is (k, v) for
    prefill (to build a cache) or the updated cache for decode."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x,
                   params["wq"].reshape(cfg.d_model, cfg.num_heads, cfg.head_dim))
    k = jnp.einsum("bsd,dhk->bshk", x,
                   params["wk"].reshape(cfg.d_model, cfg.num_kv_heads, cfg.head_dim))
    v = jnp.einsum("bsd,dhk->bshk", x,
                   params["wv"].reshape(cfg.d_model, cfg.num_kv_heads, cfg.head_dim))
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(1, 1, cfg.num_heads, cfg.head_dim)
        k = k + params["bk"].reshape(1, 1, cfg.num_kv_heads, cfg.head_dim)
        v = v + params["bv"].reshape(1, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        T = k_cache.shape[1]
        ring = window is not None and T <= window
        # Ring buffer for local attention: slot = pos % T; every resident
        # entry is in-window by construction, so no extra window mask.
        idx = cache_len % T if ring else cache_len
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), idx, axis=1)
        length = jnp.minimum(cache_len + S, T) if ring else cache_len + S
        out = decode_attention(q, k_cache, v_cache, length,
                               softcap=None, window=None if ring else window)
        new_kv = (k_cache, v_cache)
    else:
        out = chunked_attention(q, k, v, causal=True, window=window,
                                prefix_len=prefix_len)
        new_kv = (k, v)
    out = jnp.einsum("bshk,hkd->bsd",
                     out, params["wo"].reshape(cfg.num_heads, cfg.head_dim,
                                               cfg.d_model))
    return out.astype(x.dtype), new_kv


def ffn_block(params, x, activation: str):
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if activation == "geglu":
        h = jax.nn.gelu(gate.astype(F32)).astype(x.dtype) * up
    else:  # swiglu
        h = (jax.nn.silu(gate.astype(F32)).astype(x.dtype)) * up
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
