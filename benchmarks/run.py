"""Benchmark entry point: one section per paper table/figure.

Prints ``name,...`` CSV blocks.  The TPU roofline table (from the dry-run
artifacts) is emitted by ``benchmarks.roofline`` when the JSON exists.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import json

    from . import (autotune_bench, bottleneck_bench, fig3_layout,
                   fig6_distribution, fig7_cv, fig8_residency, fig10_reorder,
                   fig12_cache, hetero_bench, kernels_bench)
    sections = [
        ("Fig.3 cyclic-vs-block", fig3_layout.run),
        # fast=True keeps the all-sections sweep snappy; run the fig6/fig8
        # modules standalone for the full synthetic matrix sizes.
        ("Fig.6 row-vs-nonzero", lambda: fig6_distribution.run(fast=True)),
        ("Fig.7 mem-instr CV", fig7_cv.run),
        ("Fig.8/11 residency", lambda: fig8_residency.run(fast=True)),
        ("Fig.10 reorderings (Emu)", fig10_reorder.run),
        ("Fig.12 reorderings (cache CPU)", fig12_cache.run),
        ("kernel microbench", kernels_bench.run),
        ("Autotuner chosen-vs-best-static", autotune_bench.run),
        ("Per-shard program vs best global (hetero)",
         lambda: print(json.dumps(hetero_bench.run_hetero_bench(fast=True),
                                  indent=2))),
        ("Bottleneck oracle: gated vs always-re-plan",
         lambda: print(json.dumps(
             bottleneck_bench.run_bottleneck_bench(scale=0.003, window=16),
             indent=2))),
    ]
    try:
        from . import roofline
        sections.append(("TPU roofline (dry-run)", roofline.run))
    except Exception:
        pass
    failures = 0
    for title, fn in sections:
        print(f"# === {title} ===")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
