"""§Perf H3 reproduction: SpMV exchange strategy on the production mesh.

Standalone (needs 512 fake devices — do not import from benchmarks.run):

    PYTHONPATH=src python -m benchmarks.spmv_exchange

For each (matrix, reordering): lower the all-gather and halo-exchange
distributed SpMV programs on the 16x16 mesh and report compiled collective
bytes per shard — the ICI version of the paper's migration counts.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()


def run():
    import jax
    import jax.numpy as jnp

    from repro.core.spmv import (SpmvPlan, build_distributed, build_halo,
                                 make_halo_spmv_fn, make_spmv_fn)
    from repro.data.matrices import make_matrix
    from repro.launch.dryrun import collective_bytes_from_hlo
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    print("matrix,reorder,allgather_bytes,halo_bytes,halo_advantage,halo_H")
    for mname, sc in (("ford1", 1.0), ("cop20k_A", 0.2), ("audikw_1", 0.2)):
        A = make_matrix(mname, scale=sc)
        for reord in ("none", "bfs", "random"):
            plan = SpmvPlan(layout="block", distribution="nonzero",
                            reordering=reord, num_shards=16)
            d = build_distributed(A, plan)
            h = build_halo(d)
            per = d.x_layout.padded_length() // 16
            res = {}
            for name in ("allgather", "halo"):
                if name == "allgather":
                    fn = make_spmv_fn(d, mesh)
                    args = (jax.ShapeDtypeStruct(d.data.shape, jnp.float32),
                            jax.ShapeDtypeStruct(d.cols.shape, jnp.int32),
                            jax.ShapeDtypeStruct((16, per), jnp.float32))
                else:
                    fn = make_halo_spmv_fn(d, h, mesh)
                    args = (jax.ShapeDtypeStruct(d.data.shape, jnp.float32),
                            jax.ShapeDtypeStruct(h.cols_remap.shape, jnp.int32),
                            jax.ShapeDtypeStruct(h.send_idx.shape, jnp.int32),
                            jax.ShapeDtypeStruct((16, per), jnp.float32))
                with mesh:
                    comp = fn.lower(*args).compile()
                res[name] = collective_bytes_from_hlo(comp.as_text())["total"]
            adv = res["allgather"] / max(res["halo"], 1)
            print(f"{mname},{reord},{res['allgather']:.0f},{res['halo']:.0f},"
                  f"{adv:.2f},{h.halo}")


if __name__ == "__main__":
    run()
