"""xLSTM blocks (mLSTM + sLSTM) — the [ssm] architecture (arXiv:2405.04517).

mLSTM: matrix-memory LSTM ≈ gated linear attention.  Trained with a
chunkwise-parallel form (intra-chunk quadratic, inter-chunk recurrent state
(B, H, Dk, Dv)); decoded with the O(1) recurrent step.  Gates are sigmoid
(the paper's exp-input-gate needs log-space stabilization; the sigmoid
variant is the numerically-plain equivalent also used by its official
simplified kernels — noted in docs/ARCHITECTURE.md#design-xlstm).

sLSTM: scalar-memory LSTM with exp input gating + stabilizer state, true
recurrence (lax.scan over time), block-diagonal recurrent matrices per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_chunked(q, k, v, i_gate, f_gate, state=None, *, chunk: int = 256):
    """Chunkwise-parallel mLSTM.

    q/k: (B, S, H, Dk); v: (B, S, H, Dv); gates: (B, S, H) in (0, 1).
    state: optional (C, n) with C: (B, H, Dk, Dv), n: (B, H, Dk).
    Returns h: (B, S, H, Dv), new state.
    """
    B, S, H, Dk = q.shape
    Dv = v.shape[-1]
    W = min(chunk, S)
    if S % W:
        raise ValueError(f"seq {S} not divisible by chunk {W}")
    nch = S // W
    qc = jnp.moveaxis(q.reshape(B, nch, W, H, Dk), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nch, W, H, Dk), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nch, W, H, Dv), 1, 0)
    ic = jnp.moveaxis(i_gate.reshape(B, nch, W, H), 1, 0)
    fc = jnp.moveaxis(f_gate.reshape(B, nch, W, H), 1, 0)

    C0 = jnp.zeros((B, H, Dk, Dv), F32) if state is None else state[0].astype(F32)
    n0 = jnp.zeros((B, H, Dk), F32) if state is None else state[1].astype(F32)

    def body(carry, inp):
        C, n = carry
        qw, kw, vw, iw, fw = inp
        qw = qw.astype(F32); kw = kw.astype(F32); vw = vw.astype(F32)
        iw = iw.astype(F32); fw = fw.astype(F32)
        # log-cumulative decay within the chunk: g[t] = prod_{s<=t} f[s]
        logf = jnp.log(fw + 1e-12)                       # (B, W, H)
        csum = jnp.cumsum(logf, axis=1)
        g = jnp.exp(csum)                                # (B, W, H)
        g_total = jnp.exp(csum[:, -1])                   # (B, H)
        # inter-chunk contribution: q_t (g_t) @ C_prev
        inter = jnp.einsum("bwhk,bhkv->bwhv", qw * g[..., None], C)
        # intra-chunk: scores (t, s) masked causal with decay g_t / g_s
        ratio = jnp.exp(csum[:, :, None, :] - csum[:, None, :, :])  # (B,t,s,H)
        causal = jnp.tril(jnp.ones((W, W), bool))
        wts = jnp.where(causal[None, :, :, None], ratio, 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qw, kw) * wts * \
            iw[:, None, :, :]
        intra = jnp.einsum("btsh,bshv->bthv", scores, vw)
        # normalizer: same recurrences with k instead of k v^T
        n_inter = jnp.einsum("bwhk,bhk->bwh", qw * g[..., None], n)
        n_intra = scores.sum(axis=2)                     # (B, W, H)
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), 1.0)
        h = (inter + intra) / denom[..., None]
        # state update
        decay_s = jnp.exp(csum[:, -1, None, :] - csum)   # (B, W, H)
        kv = jnp.einsum("bwhk,bwhv->bhkv", kw * (iw * decay_s)[..., None], vw)
        C_new = C * g_total[..., None, None] + kv
        n_new = n * g_total[..., None] + jnp.einsum(
            "bwhk->bhk", kw * (iw * decay_s)[..., None])
        return (C_new, n_new), h

    from .layers import ANALYSIS_UNROLL
    (C, n), hs = jax.lax.scan(body, (C0, n0), (qc, kc, vc, ic, fc),
                              unroll=nch if ANALYSIS_UNROLL else 1)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, Dv)
    return h.astype(jnp.bfloat16), (C, n)


def mlstm_step(q, k, v, i_gate, f_gate, state):
    """O(1) decode step.  q/k: (B, 1, H, Dk); v: (B, 1, H, Dv)."""
    C, n = state
    qs = q[:, 0].astype(F32); ks = k[:, 0].astype(F32); vs = v[:, 0].astype(F32)
    i = i_gate[:, 0].astype(F32)[..., None]
    f = f_gate[:, 0].astype(F32)[..., None]
    C = C * f[..., None] + i[..., None] * ks[..., :, None] * vs[..., None, :]
    n = n * f + i * ks
    num = jnp.einsum("bhk,bhkv->bhv", qs, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)), 1.0)
    h = (num / den[..., None])[:, None]
    return h.astype(jnp.bfloat16), (C, n)


def mlstm_block(params, x, cfg, state=None, *, decode=False):
    """Full mLSTM residual block: up-proj -> mLSTM -> gate -> down-proj."""
    B, S, d = x.shape
    inner = params["w_qkv"].shape[1] // 4          # q, k, v, ogate widths
    H = cfg.num_heads
    proj = jnp.einsum("bsd,dm->bsm", x, params["w_qkv"])
    qkv, og = proj[..., : 3 * inner], proj[..., 3 * inner:]
    Dk = inner // H
    q, k, v = jnp.split(qkv.reshape(B, S, 3, H, Dk), 3, axis=2)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    gates = jnp.einsum("bsd,dg->bsg", x, params["w_gates"])  # (B,S,2H)
    i_gate = jax.nn.sigmoid(gates[..., :H].astype(F32))
    f_gate = jax.nn.sigmoid(gates[..., H:].astype(F32) + 4.0)  # open at init
    if decode:
        h, new_state = mlstm_step(q, k, v, i_gate, f_gate, state)
    else:
        # chunk grows with S so the chunk count stays bounded (compile
        # cost and scan overhead); intra-chunk work is quadratic in chunk
        # but caps at 1024.
        h, new_state = mlstm_chunked(q, k, v, i_gate, f_gate, state,
                                     chunk=min(max(256, S // 32), 1024))
    h = h.reshape(B, S, inner) * jax.nn.silu(og.astype(F32)).astype(h.dtype)
    return jnp.einsum("bsm,md->bsd", h, params["w_out"]), new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_block(params, x, cfg, state=None, *, decode=False):
    """sLSTM with exp input gate + stabilizer, block-diag recurrence.

    state: (h, c, n, m) each (B, H, Dh).
    """
    B, S, d = x.shape
    H = cfg.num_heads
    inner = params["w_in"].shape[1] // 4
    Dh = inner // H
    xg = jnp.einsum("bsd,dg->bsg", x, params["w_in"]).reshape(B, S, 4, H, Dh)
    R = params["r_kernel"]                          # (H, Dh, 4*Dh)

    if state is None:
        z = jnp.zeros((B, H, Dh), F32)
        state = (z, z, z, z - 10.0)

    def step(carry, xt):
        h, c, n, m = carry
        rec = jnp.einsum("bhd,hdg->bhg", h, R).reshape(B, H, 4, Dh)
        rec = jnp.moveaxis(rec, 2, 0)
        zt = jnp.tanh(xt[:, 0].astype(F32) + rec[0])
        it_log = xt[:, 1].astype(F32) + rec[1]               # log input gate
        ft_log = jax.nn.log_sigmoid(xt[:, 2].astype(F32) + rec[2] + 4.0)
        ot = jax.nn.sigmoid(xt[:, 3].astype(F32) + rec[3])
        m_new = jnp.maximum(ft_log + m, it_log)
        i_s = jnp.exp(it_log - m_new)
        f_s = jnp.exp(ft_log + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = ot * (c_new / n_new)
        return (h_new, c_new, n_new, m_new), h_new

    xs = jnp.moveaxis(xg, 1, 0)                     # (S, B, 4, H, Dh)
    (h, c, n, m), hs = jax.lax.scan(step, state, xs)
    out = jnp.moveaxis(hs, 0, 1).reshape(B, S, inner).astype(jnp.bfloat16)
    out = jnp.einsum("bsm,md->bsd", out, params["w_out"])
    return out, (h, c, n, m)
